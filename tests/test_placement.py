"""Placement backends (DESIGN.md §3): HostVmap reference semantics,
MeshShardMap parity across schedules, kmeans edge cases, train CLI spec
validation.

The mesh tests use however many devices the process has; CI's mesh-smoke
job re-runs this file under XLA_FLAGS=--xla_force_host_platform_device_count=8
so the shard_map schedules exercise real (host) collectives.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.streams import kmeans
from repro.data.federated import scenario_label_shift
from repro.fl import (FLConfig, HostVmap, MeshShardMap, SYSTEMS,
                      UniformFraction, get_strategy, run_federated)
from repro.fl.placement import make_client_update, stack_params, where_clients
from repro.fl.placement.host import evaluate
from repro.fl.strategies import RoundContext
from repro.models import lenet
from repro.optim import sgd

KEY = jax.random.PRNGKey(0)
SMALL = FLConfig(rounds=3, local_steps=2, batch_size=16, eval_every=1,
                 cfl_min_rounds=1)
ALL_SPECS = ["fedavg", "local", "oracle", "ucfl", "ucfl_k2", "cfl", "fedfomo"]


@pytest.fixture(scope="module")
def fed():
    return scenario_label_shift(KEY, n=500, m=4)


# ---------------------------------------------------------------------------
# HostVmap == the pre-refactor engine, bit for bit


def _reference_engine(spec, fed, fl, sampler=None, seed=0):
    """The pre-placement `run_federated` round loop, verbatim semantics:
    fresh jit(vmap(client_update)), engine-side masking and eval, strategies
    applying their own mixing math (ctx.placement=None fallback)."""
    strategy = get_strategy(spec)
    m = fed.m
    key = jax.random.PRNGKey(seed)
    key, kinit = jax.random.split(key)
    in_size, channels = fed.x.shape[2], fed.x.shape[4]
    n_classes = int(jnp.max(fed.y)) + 1
    params0 = lenet.init_params(
        kinit, lenet.LeNetConfig(in_size=in_size, in_channels=channels,
                                 n_classes=max(n_classes, 10)))
    opt = sgd(fl.lr, momentum=fl.momentum)
    vmapped_update = jax.jit(jax.vmap(make_client_update(
        lenet.loss_fn, opt, fl)))
    stacked = stack_params(params0, m)
    opt_state = jax.vmap(opt.init)(stacked)
    ctx = RoundContext(fed=fed, fl=fl, loss_fn=lenet.loss_fn,
                       acc_fn=lenet.accuracy, params0=params0, seed=seed)
    state = strategy.setup(ctx)
    mean_accs, worst_accs = [], []
    for rnd in range(fl.rounds):
        ksample = None
        if sampler is not None and sampler.needs_key:
            key, ksample = jax.random.split(key)
        key, kround = jax.random.split(key)
        ckeys = jax.random.split(kround, m)
        prev, prev_opt = stacked, opt_state
        stacked, opt_state = vmapped_update(stacked, opt_state, fed.x, fed.y,
                                            fed.n, ckeys)
        mask = sampler.sample(rnd, m, ksample) if sampler is not None else None
        if mask is not None:
            stacked = where_clients(mask, stacked, prev)
            opt_state = where_clients(mask, opt_state, prev_opt)
        ctx.rnd, ctx.key, ctx.participation = \
            rnd, jax.random.fold_in(kround, 1), mask
        stacked, state = strategy.aggregate(state, stacked, prev, ctx)
        if rnd % fl.eval_every == 0 or rnd == fl.rounds - 1:
            mean_acc, worst_acc = evaluate(lenet.accuracy, stacked, fed)
            mean_accs.append(mean_acc)
            worst_accs.append(worst_acc)
    return mean_accs, worst_accs


@pytest.mark.parametrize("spec", ["fedavg", "ucfl_k2", "cfl"])
def test_hostvmap_bit_identical_to_reference_engine(spec, fed):
    ref_mean, ref_worst = _reference_engine(spec, fed, SMALL)
    h = run_federated(spec, fed, fl=SMALL, placement=HostVmap())
    assert h.mean_acc == ref_mean       # bit-identical, not approx
    assert h.worst_acc == ref_worst


def test_hostvmap_bit_identical_under_sampler(fed):
    ref_mean, _ = _reference_engine("fedavg", fed, SMALL,
                                    sampler=UniformFraction(0.5))
    h = run_federated("fedavg", fed, fl=SMALL, sampler=UniformFraction(0.5),
                      placement=HostVmap())
    assert h.mean_acc == ref_mean


def test_default_placement_is_hostvmap(fed):
    h0 = run_federated("ucfl_k2", fed, fl=SMALL)
    h1 = run_federated("ucfl_k2", fed, fl=SMALL, placement=HostVmap())
    assert h0.mean_acc == h1.mean_acc
    assert h0.comm == h1.comm


# ---------------------------------------------------------------------------
# every strategy on every placement (acceptance criterion)


@pytest.mark.parametrize("spec", ALL_SPECS)
@pytest.mark.parametrize("placement_fn", [
    HostVmap, lambda: MeshShardMap(schedule="gspmd")],
    ids=["host", "mesh"])
def test_every_strategy_on_every_placement(spec, placement_fn, fed):
    h = run_federated(spec, fed, fl=SMALL, system=SYSTEMS["wired"],
                      placement=placement_fn())
    assert len(h.mean_acc) == SMALL.rounds
    assert len(h.comm) == SMALL.rounds
    assert all(c.n_streams >= 0 and c.n_unicasts >= 0 for c in h.comm)
    assert h.time[-1] > 0


# ---------------------------------------------------------------------------
# mesh ≈ host across schedules (exact math modulo reduction order)


@pytest.mark.parametrize("schedule", ["gspmd", "shard_map_streams",
                                      "shard_map_unicast"])
@pytest.mark.parametrize("spec", ["fedavg", "ucfl_k2", "local"])
def test_mesh_matches_host(spec, schedule, fed):
    host = run_federated(spec, fed, fl=SMALL, placement=HostVmap())
    mesh = run_federated(spec, fed, fl=SMALL,
                         placement=MeshShardMap(schedule=schedule))
    np.testing.assert_allclose(host.mean_acc, mesh.mean_acc, atol=2e-2)
    np.testing.assert_allclose(host.worst_acc, mesh.worst_acc, atol=2e-2)
    assert host.comm == mesh.comm


def test_mesh_uses_available_devices(fed):
    p = MeshShardMap()
    run_federated("fedavg", fed, fl=FLConfig(rounds=1, local_steps=1,
                                             batch_size=8, eval_every=1),
                  placement=p)
    n_dev = len(jax.devices())
    expected = max(k for k in range(1, min(n_dev, fed.m) + 1)
                   if fed.m % k == 0)
    assert p.mesh.shape["clients"] == expected


def test_mesh_placement_reusable_across_client_counts(fed):
    """One auto-mesh instance drives sweeps over scenarios with different
    m: the mesh (and the cached mix executables) re-derive per m."""
    p = MeshShardMap(schedule="shard_map_streams")
    fl = FLConfig(rounds=1, local_steps=1, batch_size=8, eval_every=1)
    h1 = run_federated("ucfl_k2", fed, fl=fl, placement=p)
    fed5 = scenario_label_shift(KEY, n=300, m=5)
    h2 = run_federated("ucfl_k2", fed5, fl=fl, placement=p)
    assert len(h1.mean_acc) == 1 and len(h2.mean_acc) == 1


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >1 device to build an indivisible mesh")
def test_mesh_rejects_indivisible_mesh():
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:2]), ("clients",))
    p = MeshShardMap(mesh)
    with pytest.raises(ValueError, match="divisible"):
        p._ensure_mesh(5)


def test_mesh_unknown_schedule_raises():
    with pytest.raises(ValueError, match="schedule"):
        MeshShardMap(schedule="bogus")


# ---------------------------------------------------------------------------
# kmeans / stream-count edge cases


def test_kmeans_k_greater_than_m():
    rows = jnp.asarray(np.random.default_rng(0).random((3, 3)), jnp.float32)
    rows = rows / rows.sum(1, keepdims=True)
    plan = kmeans(rows, 7)              # k clamps to m
    assert plan.centroids.shape == (3, 3)
    assert plan.assignment.shape == (3,)


def test_kmeans_single_client():
    rows = jnp.ones((1, 1), jnp.float32)
    plan = kmeans(rows, 3)
    assert plan.centroids.shape == (1, 1)
    assert int(plan.assignment[0]) == 0


def test_ucfl_k_exceeding_m_runs(fed):
    h = run_federated(f"ucfl_k{fed.m + 3}", fed, fl=SMALL)
    assert len(h.mean_acc) == SMALL.rounds
    # k clamps to m: per-round downlink is at most m streams
    assert all(c.n_streams <= fed.m for c in h.comm)


def test_single_client_run():
    fed1 = scenario_label_shift(KEY, n=200, m=1)
    h = run_federated("ucfl_k2", fed1, fl=FLConfig(
        rounds=2, local_steps=1, batch_size=8, eval_every=1))
    assert len(h.mean_acc) == 2


# ---------------------------------------------------------------------------
# train CLI: registry-validated specs (regression for the old split("_k"))


def test_train_cli_bad_spec_raises_registry_error():
    from repro.launch.train import main
    with pytest.raises(ValueError, match="unknown strategy spec"):
        main(["--algorithm", "ucfl_k"])          # old code: IndexError
    with pytest.raises(ValueError, match="unknown strategy spec"):
        main(["--algorithm", "fedprox"])
    with pytest.raises(ValueError, match="no _k parameter"):
        main(["--algorithm", "local_k2"])


@pytest.mark.slow
def test_train_cli_mesh_smoke():
    from repro.launch.train import main
    loss = main(["--steps", "2", "--clients", "2", "--eval-every", "1",
                 "--algorithm", "fedavg", "--pool", "8", "--seq", "32",
                 "--batch", "2"])
    assert np.isfinite(loss)
