"""Per-kernel allclose vs the pure-jnp oracles (interpret mode), with
hypothesis sweeps over shapes/dtypes per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _hypothesis():
    """Property tests skip cleanly on bare environments without hypothesis;
    the example-based tests in this module still run."""
    st = pytest.importorskip("hypothesis.strategies")
    from hypothesis import given, settings
    return given, settings, st

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    x = jax.random.normal(key, shape, jnp.float32) * scale
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# mixing_aggregate


def test_mixing_aggregate_matches_ref():
    given, settings, st = _hypothesis()

    @settings(max_examples=12, deadline=None)
    @given(k=st.integers(1, 9), m=st.integers(2, 20),
           d=st.sampled_from([64, 777, 2048, 4096 + 13]),
           dtype=st.sampled_from(["float32", "bfloat16"]))
    def prop(k, m, d, dtype):
        dt = jnp.dtype(dtype)
        w = jax.random.uniform(KEY, (k, m), jnp.float32)
        w = w / jnp.sum(w, 1, keepdims=True)
        theta = _rand(jax.random.PRNGKey(k * 31 + m), (m, d), dt)
        got = ops.mixing_aggregate(w, theta)
        want = ref.mixing_aggregate_ref(w, theta)
        tol = 1e-5 if dtype == "float32" else 2e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    prop()


def test_mixing_aggregate_identity():
    m, d = 8, 512
    theta = _rand(KEY, (m, d))
    got = ops.mixing_aggregate(jnp.eye(m), theta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(theta), atol=1e-6)


# ---------------------------------------------------------------------------
# pairwise_sqdist


def test_pairwise_sqdist_matches_ref():
    given, settings, st = _hypothesis()

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(2, 24), d=st.sampled_from([128, 1000, 2048, 5000]))
    def prop(m, d):
        g = _rand(jax.random.PRNGKey(m * 7 + d), (m, d))
        got = ops.pairwise_sqdist(g)
        want = ref.pairwise_sqdist_ref(g)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-2)

    prop()


def test_pairwise_sqdist_properties():
    g = _rand(KEY, (10, 333))
    d = np.asarray(ops.pairwise_sqdist(g))
    assert np.allclose(np.diag(d), 0.0, atol=1e-3)
    assert np.allclose(d, d.T, atol=1e-4)
    assert (d >= -1e-5).all()


# ---------------------------------------------------------------------------
# flash attention


def test_flash_attention_matches_ref():
    given, settings, st = _hypothesis()

    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 2),
        kh=st.sampled_from([1, 2, 4]),
        g=st.sampled_from([1, 2, 4]),
        sq=st.sampled_from([64, 128, 200]),
        extra_k=st.sampled_from([0, 64]),
        hd=st.sampled_from([32, 64]),
        window=st.sampled_from([None, 64]),
        softcap=st.sampled_from([None, 30.0]),
    )
    def prop(b, kh, g, sq, extra_k, hd, window, softcap):
        h = kh * g
        sk = sq + extra_k
        key = jax.random.PRNGKey(b * 97 + h * 13 + sq)
        ks = jax.random.split(key, 3)
        q = _rand(ks[0], (b, h, sq, hd), scale=0.5)
        k = _rand(ks[1], (b, kh, sk, hd), scale=0.5)
        v = _rand(ks[2], (b, kh, sk, hd))
        got = ops.flash_attention(q, k, v, causal=True, window=window,
                                  softcap=softcap, qblk=64, kblk=64)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                       softcap=softcap)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    prop()


def test_flash_attention_noncausal_and_bf16():
    b, h, s, hd = 1, 2, 96, 64
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (b, h, s, hd), jnp.bfloat16, 0.5)
    k = _rand(ks[1], (b, h, s + 32, hd), jnp.bfloat16, 0.5)
    v = _rand(ks[2], (b, h, s + 32, hd), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=False, qblk=64, kblk=64)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_attention_matches_model_sdpa():
    """The kernel agrees with the model-layer chunked SDPA path."""
    from repro.models.attention import _sdpa_chunked
    b, h, s, hd = 1, 4, 128, 32
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (b, h, s, hd), scale=0.5)
    k = _rand(ks[1], (b, h, s, hd), scale=0.5)
    v = _rand(ks[2], (b, h, s, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    got = ops.flash_attention(q, k, v, causal=True, qblk=64, kblk=64)
    want = _sdpa_chunked(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), pos, pos, kind="causal",
                         window=None, prefix_len=0, cap=None,
                         cdtype=jnp.float32, chunk=64)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want.transpose(0, 2, 1, 3)),
                               rtol=1e-5, atol=1e-5)
