"""Personalized-model serving plane (DESIGN.md §3d): DeltaStore
reconstruction contracts, the ServeEngine parity anchor, checkpoint
round-tripping, plus the §3b satellites that ride the same PR —
rate-adaptive codecs and membership-aware broadcast charging.

The §3d anchor, enforced here and inside ``perf_iterations.py --serve``:
for every user the served output equals a direct forward pass through
that user's reconstructed personalized params — bit-identical with the
``identity`` codec on both placements, within the documented codec error
bound (`Codec.store_bound`) for lossy codecs.

CI's serve-smoke job re-runs this file under
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the mesh decode
path exercises real (host) sharding.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.data.federated import scenario_label_shift
from repro.fl import (Channel, DeltaStore, FLConfig, HostVmap, MeshShardMap,
                      ServeEngine, SYSTEMS, check_parity, get_codec,
                      run_federated)
from repro.fl.channel import get_link_profile, stacked_ravel, tree_bits
from repro.fl.channel.codecs import (Adaptive, AdaptiveTopK, BoundAdaptive,
                                     BoundAdaptiveTopK)
from repro.fl.channel.link import round_downlink_time
from repro.fl.strategies import CommCost
from repro.models import lenet

KEY = jax.random.PRNGKey(0)
FL = FLConfig(rounds=3, local_steps=2, batch_size=16, eval_every=3)
CODECS = ["identity", "qsgd:4", "topk:0.25"]


def apply_one(params, x):
    """One user's params x one example -> logits (the engine vmaps it)."""
    return lenet.apply(params, x[None])[0]


def mesh():
    """Collectives pinned: bit-exact on any (forced) device count."""
    return MeshShardMap(schedule="shard_map_streams")


@pytest.fixture(scope="module")
def fed():
    return scenario_label_shift(KEY, n=500, m=4)


@pytest.fixture(scope="module")
def hist(fed):
    return run_federated("ucfl_k2", fed, fl=FL, keep_state=True)


@pytest.fixture(scope="module")
def hist_mesh(fed):
    return run_federated("ucfl_k2", fed, fl=FL, placement=mesh(),
                         keep_state=True)


@pytest.fixture(scope="module")
def hist_full(fed):
    # FULL personalization: every user ends with a distinct model, so a
    # store keyed on the coarse ground-truth clusters has genuinely
    # NONZERO per-user deltas (stream-reduced runs end bit-identical to
    # their base — zero deltas — which would make lossy tests vacuous)
    return run_federated("ucfl", fed, fl=FL, keep_state=True)


# ---------------------------------------------------------------------------
# DeltaStore reconstruction contracts


def test_identity_store_is_lossless(hist):
    store = DeltaStore.from_history(hist, codec="identity")
    true = np.asarray(stacked_ravel(hist.final_params), np.float32)
    got = np.asarray(store.params_flat())
    assert np.array_equal(got, true)
    assert store.recon_err.max() == 0.0


def test_store_uses_strategy_assignment(hist):
    store = DeltaStore.from_history(hist, codec="identity")
    assert store.k == 2                       # ucfl_k2: two streams
    np.testing.assert_array_equal(store.assignment,
                                  hist.extras.assignment)


def test_store_dedup_recovers_plan_without_extras(fed):
    # fedavg records no assignment: byte-level dedup finds the single
    # consensus model; "local" never mixes, so every user is its own base
    h1 = run_federated("fedavg", fed, fl=FL, keep_state=True)
    assert DeltaStore.from_history(h1, codec="identity").k == 1
    h2 = run_federated("local", fed, fl=FL, keep_state=True)
    assert DeltaStore.from_history(h2, codec="identity").k == fed.m


@pytest.mark.parametrize("codec", ["qsgd:4", "topk:0.25"])
def test_lossy_store_within_documented_bound(hist, codec):
    # build() raises when the bound is violated; re-assert it here
    # explicitly against the true trained params
    store = DeltaStore.from_history(hist, codec=codec)
    true = np.asarray(stacked_ravel(hist.final_params), np.float64)
    got = np.asarray(store.params_flat(), np.float64)
    err = np.max(np.abs(got - true), axis=1)
    bound = store.codec.store_bound(
        {k: np.asarray(v) for k, v in store.payload.items()}, store.d)
    slack = 4.0 * np.spacing(np.max(np.abs(true), axis=1))
    assert np.all(err <= bound + slack)


def test_store_bits_accounting(hist):
    m, d = 4, None
    store = DeltaStore.from_history(hist, codec="identity")
    d = store.d
    assert store.bits.base_bits == store.k * tree_bits(store.template)
    # identity deltas are dense f32 (+64 bits per sparse fixup entry)
    assert np.all(store.bits.delta_bits >= d * 32)
    q = DeltaStore.from_history(hist, codec="qsgd:4")
    np.testing.assert_array_equal(q.bits.delta_bits, np.full(m, d * 4 + 32))
    assert q.bits.total_bytes < store.bits.total_bytes


def test_coarse_assignment_identity_still_lossless(hist_full, fed):
    # nonzero deltas force the iterative delta refinement (and, where the
    # one-add f32 grid can't reach, the sparse fixup) — reconstruction
    # must STILL be bit-exact
    asn = np.asarray(fed.group, np.int64)
    store = DeltaStore.build(hist_full.final_params, assignment=asn,
                             codec="identity")
    true = np.asarray(stacked_ravel(hist_full.final_params), np.float32)
    base = np.asarray(store.base_flat)[store.assignment]
    assert np.abs(true - base).max() > 0          # deltas genuinely nonzero
    assert np.array_equal(np.asarray(store.params_flat()), true)
    assert store.recon_err.max() == 0.0


@pytest.mark.parametrize("codec", ["qsgd:4", "topk:0.25"])
def test_coarse_assignment_lossy_bound_nonvacuous(hist_full, fed, codec):
    asn = np.asarray(fed.group, np.int64)
    store = DeltaStore.build(hist_full.final_params, assignment=asn,
                             codec=codec)
    assert store.recon_err.max() > 0.0            # the bound does real work
    true = np.asarray(stacked_ravel(hist_full.final_params), np.float64)
    got = np.asarray(store.params_flat(), np.float64)
    err = np.max(np.abs(got - true), axis=1)
    bound = store.codec.store_bound(
        {k: np.asarray(v) for k, v in store.payload.items()}, store.d)
    slack = 4.0 * np.spacing(np.max(np.abs(true), axis=1))
    assert np.all(err <= bound + slack)


@pytest.mark.parametrize("placement", [None, "mesh"])
@pytest.mark.parametrize("codec", CODECS)
def test_serve_parity_nonzero_deltas(hist_full, fed, codec, placement):
    pl = mesh() if placement else HostVmap()
    asn = np.asarray(fed.group, np.int64)
    store = DeltaStore.build(hist_full.final_params, assignment=asn,
                             codec=codec, backend=pl.codec_backend)
    eng = ServeEngine(store, apply_one, placement=pl, max_batch=4)
    users = [2, 0, 3, 1]
    xs = np.asarray(fed.x_val)[users, 0]
    check_parity(eng, users, xs)


def test_from_history_requires_keep_state(fed):
    h = run_federated("fedavg", fed, fl=FL)
    with pytest.raises(ValueError, match="keep_state"):
        DeltaStore.from_history(h)


# ---------------------------------------------------------------------------
# ServeEngine: the §3d parity anchor


@pytest.mark.parametrize("codec", CODECS)
def test_serve_parity_host(hist, fed, codec):
    store = DeltaStore.from_history(hist, codec=codec)
    eng = ServeEngine(store, apply_one, max_batch=3)
    users = [3, 0, 2, 1, 0]
    xs = np.asarray(fed.x_val)[users, 0]
    check_parity(eng, users, xs)


@pytest.mark.parametrize("codec", CODECS)
def test_serve_parity_mesh(hist_mesh, fed, codec):
    store = DeltaStore.from_history(hist_mesh, codec=codec, backend="jnp")
    eng = ServeEngine(store, apply_one, placement=mesh(), max_batch=4)
    users = [1, 3, 0, 2]
    xs = np.asarray(fed.x_val)[users, 0]
    check_parity(eng, users, xs)


@pytest.mark.parametrize("placement", [None, "mesh"])
def test_identity_serves_true_trained_params(hist, fed, placement):
    # end-to-end: the served logits equal a direct forward through the
    # user's TRUE personalized final params, bit-identical (lossless
    # store + parity anchor composed)
    pl = mesh() if placement else HostVmap()
    store = DeltaStore.from_history(hist, codec="identity",
                                    backend=pl.codec_backend)
    eng = ServeEngine(store, apply_one, placement=pl)
    users = [0, 1, 2, 3]
    xs = np.asarray(fed.x_val)[users, 0]
    served = eng.serve(users, xs)
    true_flat = jnp.asarray(stacked_ravel(hist.final_params))
    ref = eng.forward(
        pl.place_stack(store.unravel_batch(true_flat), len(users)),
        pl.place_stack(jnp.asarray(xs), len(users)))
    assert np.array_equal(np.asarray(served), np.asarray(ref))


def test_microbatcher_submit_order_and_chunking(hist, fed):
    store = DeltaStore.from_history(hist, codec="qsgd:4")
    eng = ServeEngine(store, apply_one, max_batch=2)
    users = [2, 0, 3, 1, 2]
    xs = np.asarray(fed.x_val)[users, 0]
    tickets = [eng.submit(u, x) for u, x in zip(users, xs)]
    outs = eng.flush()
    assert tickets == [0, 1, 2, 3, 4]
    assert eng.last_stats["requests"] == 5
    assert eng.last_stats["batches"] == 3          # ceil(5 / max_batch=2)
    for i, (u, x) in enumerate(zip(users, xs)):
        one = np.asarray(eng.serve([u], x[None]))[0]
        assert np.array_equal(outs[i], one)


def test_engine_validates_max_batch(hist):
    store = DeltaStore.from_history(hist)
    with pytest.raises(ValueError, match="max_batch"):
        ServeEngine(store, apply_one, max_batch=0)


# ---------------------------------------------------------------------------
# keep_state round-tripping: History -> checkpoint -> DeltaStore


@pytest.mark.parametrize("mesh_run", [False, True])
@pytest.mark.parametrize("codec", ["identity", "qsgd:4"])
def test_keep_state_checkpoint_roundtrip(hist, hist_mesh, fed, codec,
                                         mesh_run):
    h = hist_mesh if mesh_run else hist
    backend = "jnp" if mesh_run else "pallas"
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "state.msgpack")
        checkpoint.save_train_state(
            path, FL.rounds, jax.device_get(h.final_params),
            jax.device_get(h.final_opt_state),
            extra={"assignment": np.asarray(h.extras.assignment)})
        step, params, opt_state, extra = checkpoint.restore_train_state(path)
        assert step == FL.rounds
        store = DeltaStore.build(params, codec=codec,
                                 assignment=extra["assignment"],
                                 backend=backend)
        live = DeltaStore.from_history(h, codec=codec, backend=backend)
        # the checkpointed store reconstructs the SAME params as the live
        # one, and (identity) exactly the user's trained personalized model
        assert np.array_equal(np.asarray(store.params_flat()),
                              np.asarray(live.params_flat()))
        if codec == "identity":
            true = np.asarray(stacked_ravel(h.final_params), np.float32)
            assert np.array_equal(np.asarray(store.params_flat()), true)


@pytest.mark.parametrize("codec", CODECS)
def test_store_save_load_roundtrip(hist, fed, codec):
    store = DeltaStore.from_history(hist, codec=codec)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "store.msgpack")
        store.save(path)
        loaded = DeltaStore.load(path)
    assert loaded.codec.spec == store.codec.spec
    np.testing.assert_array_equal(loaded.assignment, store.assignment)
    np.testing.assert_array_equal(loaded.bits.delta_bits,
                                  store.bits.delta_bits)
    assert loaded.bits.total_bytes == store.bits.total_bytes
    assert np.array_equal(np.asarray(loaded.params_flat()),
                          np.asarray(store.params_flat()))
    # a loaded store serves bit-identically
    eng_a = ServeEngine(store, apply_one)
    eng_b = ServeEngine(loaded, apply_one)
    xs = np.asarray(fed.x_val)[[1, 2], 0]
    assert np.array_equal(np.asarray(eng_a.serve([1, 2], xs)),
                          np.asarray(eng_b.serve([1, 2], xs)))


# ---------------------------------------------------------------------------
# satellite: rate-adaptive codec selection (spec "adaptive[:<min>]")


def _tree(d=64):
    return {"w": np.zeros((d,), np.float32)}


def test_adaptive_unbound_raises():
    c = get_codec("adaptive:4")
    assert isinstance(c, Adaptive)
    with pytest.raises(RuntimeError, match="bind_link"):
        c.payload_bits(_tree())
    with pytest.raises(RuntimeError, match="bind_link"):
        c.roundtrip(jnp.zeros((2, 4)), KEY)
    with pytest.raises(ValueError):
        get_codec("adaptive:1")                # below the 2-bit floor


def test_adaptive_uniform_link_collapses_to_min_bits():
    link = get_link_profile("uniform", SYSTEMS["wired"], 64 * 32 + 32, 4)
    bound = get_codec("adaptive:4").bind_link(link, _tree())
    assert isinstance(bound, BoundAdaptive)
    np.testing.assert_array_equal(bound.bits, np.full(4, 4))
    # identical charge to the fixed qsgd:4 codec
    q4 = get_codec("qsgd:4")
    assert bound.payload_bits(_tree()) == q4.payload_bits(_tree())
    np.testing.assert_array_equal(bound.per_client_bits(_tree(), 4),
                                  q4.per_client_bits(_tree(), 4))


def test_adaptive_uniform_run_matches_qsgd_bitwise(fed):
    ha = run_federated("ucfl_k2", fed, fl=FL,
                       channel=Channel(codec="adaptive:4"),
                       system=SYSTEMS["wired"])
    hq = run_federated("ucfl_k2", fed, fl=FL,
                       channel=Channel(codec="qsgd:4"),
                       system=SYSTEMS["wired"])
    assert ha.mean_acc == hq.mean_acc
    assert ha.comm_bits == hq.comm_bits
    assert ha.time == hq.time


def test_adaptive_tiered_spends_headroom_within_budget():
    m, d = 8, 64
    link = get_link_profile("tiered:4", SYSTEMS["wired"], d * 32 + 32, m)
    bound = get_codec("adaptive:4").bind_link(link, _tree(d))
    pc = bound.per_client_bits(_tree(d), m)
    fixed = get_codec("qsgd:4").payload_bits(_tree(d))
    # faster clients carry MORE bits than the fixed-codec charge...
    assert int(pc.sum()) > m * fixed
    assert bound.bits.min() == 4 and bound.bits.max() > 4
    # ...but the round's uplink TIME never exceeds the qsgd:<min> budget
    # (the slowest client transmitting the minimum spec)
    assert (link.max_uplink_time(pc)
            <= link.max_uplink_time(fixed) * (1 + 1e-12))
    # per-client: every upload fits that same budget
    t_budget = max(link.uplink_time(i, fixed) for i in range(m))
    for i in range(m):
        assert link.uplink_time(i, int(pc[i])) <= t_budget * (1 + 1e-12)


def test_adaptive_charge_recorded_per_client(fed):
    h = run_federated("ucfl_k2", fed, fl=FL,
                      channel=Channel(codec="adaptive:4", link="tiered:4"),
                      system=SYSTEMS["wired"])
    hq = run_federated("ucfl_k2", fed, fl=FL,
                       channel=Channel(codec="qsgd:4", link="tiered:4"),
                       system=SYSTEMS["wired"])
    # strictly more uplink bits (headroom spent); the broadcast is
    # charged at the LARGEST assigned width (BoundAdaptive.payload_bits),
    # so downlink bits can only grow — the budget rule binds the uplink
    # TIME, which test_adaptive_tiered_spends_headroom_within_budget pins
    assert h.comm_bits[-1].ul_bits > hq.comm_bits[-1].ul_bits
    assert h.comm_bits[-1].dl_bits >= hq.comm_bits[-1].dl_bits


# ---------------------------------------------------------------------------
# satellite: rate-adaptive SPARSITY ("adaptive_topk[:<min>[:<max>]]") —
# the top-k sibling of the adaptive bit-width tests above


def test_adaptive_topk_unbound_raises():
    c = get_codec("adaptive_topk:0.1")
    assert isinstance(c, AdaptiveTopK)
    with pytest.raises(RuntimeError, match="bind_link"):
        c.payload_bits(_tree())
    with pytest.raises(RuntimeError, match="bind_link"):
        c.roundtrip(jnp.zeros((2, 4)), KEY)
    with pytest.raises(ValueError):
        get_codec("adaptive_topk:0")           # frac floor is exclusive
    with pytest.raises(ValueError):
        get_codec("adaptive_topk:1.5")
    with pytest.raises(ValueError):
        get_codec("adaptive_topk:0.5:0.2")     # min > max
    with pytest.raises(ValueError):
        get_codec("adaptive_topk:0.1:0.5:0.9")  # too many params


def test_adaptive_topk_uniform_link_collapses_to_min_frac():
    link = get_link_profile("uniform", SYSTEMS["wired"], 64 * 32 + 32, 4)
    bound = get_codec("adaptive_topk:0.25").bind_link(link, _tree())
    assert isinstance(bound, BoundAdaptiveTopK)
    np.testing.assert_array_equal(bound.ks, np.full(4, 16))
    tk = get_codec("topk:0.25")
    assert bound.payload_bits(_tree()) == tk.payload_bits(_tree())
    np.testing.assert_array_equal(bound.per_client_bits(_tree(), 4),
                                  tk.per_client_bits(_tree(), 4))


def test_adaptive_topk_uniform_run_matches_topk_bitwise(fed):
    ha = run_federated("ucfl_k2", fed, fl=FL,
                       channel=Channel(codec="adaptive_topk:0.25"),
                       system=SYSTEMS["wired"])
    ht = run_federated("ucfl_k2", fed, fl=FL,
                       channel=Channel(codec="topk:0.25"),
                       system=SYSTEMS["wired"])
    assert ha.mean_acc == ht.mean_acc
    assert ha.comm_bits == ht.comm_bits
    assert ha.time == ht.time


def test_adaptive_topk_tiered_spends_headroom_within_budget():
    m, d = 8, 64
    link = get_link_profile("tiered:4", SYSTEMS["wired"], d * 32 + 32, m)
    bound = get_codec("adaptive_topk:0.25").bind_link(link, _tree(d))
    pc = bound.per_client_bits(_tree(d), m)
    fixed = get_codec("topk:0.25").payload_bits(_tree(d))
    # faster clients keep MORE coordinates than the fixed-frac charge...
    assert int(pc.sum()) > m * fixed
    assert bound.ks.min() == 16 and bound.ks.max() > 16
    # ...capped at max_frac (here the default 1.0 -> k <= d)
    assert bound.ks.max() <= d
    # ...and the round's uplink TIME never exceeds the topk:<min> budget
    assert (link.max_uplink_time(pc)
            <= link.max_uplink_time(fixed) * (1 + 1e-12))
    t_budget = max(link.uplink_time(i, fixed) for i in range(m))
    for i in range(m):
        assert link.uplink_time(i, int(pc[i])) <= t_budget * (1 + 1e-12)
    # an explicit max_frac binds before the budget does
    capped = get_codec("adaptive_topk:0.25:0.5").bind_link(link, _tree(d))
    assert capped.ks.max() <= 32
    assert capped.spec == "adaptive_topk:0.25:0.5"


def test_adaptive_topk_charge_recorded_per_client(fed):
    h = run_federated("ucfl_k2", fed, fl=FL,
                      channel=Channel(codec="adaptive_topk:0.25",
                                      link="tiered:4"),
                      system=SYSTEMS["wired"])
    ht = run_federated("ucfl_k2", fed, fl=FL,
                       channel=Channel(codec="topk:0.25", link="tiered:4"),
                       system=SYSTEMS["wired"])
    # headroom spent on extra kept coordinates; the broadcast charges the
    # LARGEST assigned k, so downlink bits can only grow — the budget
    # rule binds the uplink TIME (pinned above)
    assert h.comm_bits[-1].ul_bits > ht.comm_bits[-1].ul_bits
    assert h.comm_bits[-1].dl_bits >= ht.comm_bits[-1].dl_bits


# ---------------------------------------------------------------------------
# satellite: membership-aware broadcast charging


def _tiered_link(m=4):
    return get_link_profile("tiered:4", SYSTEMS["wired"], 1000, m)


def test_membership_charge_tighter_and_bounded_by_legacy():
    link = _tiered_link()
    cost, bits = CommCost(2, 0), 1000
    asn = np.asarray([0, 0, 1, 1])
    legacy = round_downlink_time(link, cost, bits)
    aware = round_downlink_time(link, cost, bits, assignment=asn)
    # regression pin: the legacy charge is an UPPER BOUND on the
    # membership-aware charge, strictly tighter on a tiered profile
    # whenever some stream avoids the slowest subscriber
    assert aware <= legacy * (1 + 1e-12)
    fast_stream = round_downlink_time(link, cost, bits,
                                      assignment=np.asarray([0, 1, 1, 1]))
    if link.dl_rate[0] != link.dl_rate[-1]:
        assert fast_stream < legacy


def test_membership_charge_uniform_profile_is_bit_identical():
    link = get_link_profile("uniform", SYSTEMS["wired"], 1000, 4)
    cost, bits = CommCost(2, 0), 1000
    legacy = round_downlink_time(link, cost, bits)
    aware = round_downlink_time(link, cost, bits,
                                assignment=np.asarray([0, 0, 1, 1]))
    assert aware == legacy


def test_membership_charge_respects_participants():
    link = _tiered_link()
    cost, bits = CommCost(2, 0), 1000
    asn = np.asarray([0, 0, 1, 1])
    # cohort excludes the slowest subscribers of stream 0
    aware = round_downlink_time(link, cost, bits, participants=[1, 2, 3],
                                assignment=asn)
    legacy = round_downlink_time(link, cost, bits, participants=[1, 2, 3])
    assert aware <= legacy * (1 + 1e-12)


def test_membership_run_time_never_exceeds_legacy(fed):
    # engine-level regression: ucfl_k2 (which exposes its StreamPlan
    # assignment) on a tiered profile clocks <= the legacy upper bound,
    # here reproduced by fedavg-style single-stream accounting equality:
    # identical configs modulo the membership map can only speed up
    h = run_federated("ucfl_k2", fed, fl=FL,
                      channel=Channel(link="tiered:4"),
                      system=SYSTEMS["wired"])
    link = _tiered_link()
    payload = h.extra["channel"]["payload_bits"]
    legacy_t = sum(
        SYSTEMS["wired"].compute_time(fed.m)
        + link.max_uplink_time(payload)
        + round_downlink_time(link, c, payload) for c in h.comm)
    assert h.time[-1] <= legacy_t * (1 + 1e-9)
