"""Async round runtime (DESIGN.md §3a): virtual-clock determinism,
staleness reweighting, sync↔async lockstep bit-equivalence, buffer
semantics, engine buffer donation, and a mesh async smoke.

CI's async-smoke job re-runs this file under
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the mesh tests
exercise real (host) collectives.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.federated import scenario_label_shift
from repro.fl import (AsyncConfig, FLConfig, HostVmap, MeshShardMap,
                      SystemModel, UniformFraction, VirtualClock,
                      run_federated)
from repro.fl.strategies import STRATEGIES
from repro.fl.strategies.base import staleness_reweight

KEY = jax.random.PRNGKey(0)
SMALL = FLConfig(rounds=3, local_steps=2, batch_size=16, eval_every=1,
                 cfl_min_rounds=1)
RELIABLE = SystemModel(rho=2.0, t_min=1.0, inv_mu=0.0, name="reliable")
STRAGGLER = SystemModel(rho=2.0, t_min=1.0, inv_mu=1.0, name="straggler")


@pytest.fixture(scope="module")
def fed():
    return scenario_label_shift(KEY, n=500, m=4)


# ---------------------------------------------------------------------------
# virtual clock


def test_clock_deterministic_given_seed():
    a, b = VirtualClock(STRAGGLER, seed=7), VirtualClock(STRAGGLER, seed=7)
    for i in range(5):
        assert a.schedule(i, 0.0) == b.schedule(i, 0.0)
    assert [a.pop() for _ in range(5)] == [b.pop() for _ in range(5)]


def test_clock_lockstep_pops_in_client_order():
    """inv_mu=0: every draw is exactly t_min + rho, ties break on index."""
    c = VirtualClock(RELIABLE, seed=0)
    for i in reversed(range(4)):
        c.schedule(i, 0.0)
    assert [c.pop() for _ in range(4)] == [(3.0, i) for i in range(4)]
    assert c.now == 3.0


def test_clock_serialized_downlink():
    c = VirtualClock(RELIABLE, seed=0)
    assert c.serve(2.0) == 2.0
    assert c.serve(1.0) == 3.0          # queues behind the first broadcast
    c.now = 10.0
    assert c.serve(1.0) == 11.0         # idle downlink starts at `now`


def test_clock_now_monotone_under_stragglers():
    c = VirtualClock(STRAGGLER, seed=3)
    for i in range(8):
        c.schedule(i, 0.0)
    times = [c.pop()[0] for _ in range(8)]
    assert times == sorted(times)


# ---------------------------------------------------------------------------
# staleness reweighting (Strategy.reweight default)


def test_reweight_zero_age_is_identity():
    w = jnp.asarray(np.random.default_rng(0).random((3, 5)), jnp.float32)
    out = staleness_reweight(w, jnp.zeros(5), 0.5)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(w))


def test_reweight_discounts_stale_columns_mass_preserving():
    w = jnp.full((2, 4), 0.25, jnp.float32)
    age = jnp.asarray([0.0, 0.0, 1.0, 2.0])
    out = np.asarray(staleness_reweight(w, age, 0.5))
    # columns scaled by 0.5**age then rows rescaled to their original mass
    raw = 0.25 * np.asarray([1.0, 1.0, 0.5, 0.25])
    expect = raw / raw.sum()
    np.testing.assert_allclose(out, np.tile(expect, (2, 1)), rtol=1e-6)
    np.testing.assert_allclose(out.sum(1), [1.0, 1.0], rtol=1e-6)


def test_reweight_preserves_substochastic_row_mass():
    """FedFOMO rows don't sum to 1 — their self-residual must survive."""
    w = jnp.asarray([[0.2, 0.3, 0.0]], jnp.float32)
    out = np.asarray(staleness_reweight(w, jnp.asarray([0.0, 2.0, 5.0]), 0.5))
    np.testing.assert_allclose(out.sum(), 0.5, rtol=1e-6)
    assert out[0, 1] < out[0, 0]        # the stale column lost weight


def test_reweight_zero_row_stays_zero():
    w = jnp.zeros((2, 3), jnp.float32)
    out = np.asarray(staleness_reweight(w, jnp.asarray([0.0, 1.0, 2.0]), 0.5))
    np.testing.assert_array_equal(out, np.zeros((2, 3)))


# ---------------------------------------------------------------------------
# lockstep equivalence: inv_mu=0, K=m, tau=inf  ==  the synchronous engine


@pytest.mark.parametrize("spec", ["fedavg", "ucfl_k2", "cfl", "fedfomo"])
def test_async_lockstep_bit_identical_to_sync(spec, fed):
    sync = run_federated(spec, fed, fl=SMALL, system=RELIABLE,
                         placement=HostVmap())
    a = run_federated(spec, fed, fl=SMALL, system=RELIABLE,
                      placement=HostVmap(),
                      async_cfg=AsyncConfig(buffer_k=fed.m))
    assert a.mean_acc == sync.mean_acc          # bit-identical, not approx
    assert a.worst_acc == sync.worst_acc
    assert a.comm == sync.comm
    # in lockstep the virtual clock reproduces the analytic clock too
    assert a.time == pytest.approx(sync.time)


def test_async_records_event_metadata(fed):
    h = run_federated("fedavg", fed, fl=SMALL, system=STRAGGLER,
                      async_cfg=AsyncConfig(buffer_k=2, max_staleness=3.0,
                                            staleness_discount=0.8))
    assert h.extra["async"] == {"buffer_k": 2, "max_staleness": 3.0,
                                "staleness_schedule": "exp",
                                "staleness_discount": 0.8,
                                "staleness_alpha": 0.5,
                                "max_retries": 3, "retry_backoff": 1.0,
                                "events": SMALL.rounds}


# ---------------------------------------------------------------------------
# buffered semantics under stragglers


def test_async_buffer_runs_all_strategies(fed):
    cfg = AsyncConfig(buffer_k=2, max_staleness=4.0, staleness_discount=0.8)
    for spec in sorted(STRATEGIES):
        h = run_federated(spec, fed, fl=SMALL, system=STRAGGLER,
                          async_cfg=cfg, seed=1)
        assert len(h.mean_acc) == SMALL.rounds, spec
        assert all(np.isfinite(h.mean_acc)), spec
        assert h.time == sorted(h.time), spec


def test_hostvmap_cohort_update_matches_masked_full_update(fed):
    """HostVmap's O(k) gather/scatter cohort step must equal the default
    run-every-slot-and-mask path (same per-client math, same keys)."""
    from repro.fl.placement import Placement
    from repro.models import lenet
    p = HostVmap()
    opt, update = p.build_update(lenet.loss_fn, SMALL)
    m = fed.m
    from repro.fl.simulator import default_model_init
    stacked = p.stack(default_model_init(fed)(KEY), m)
    opt_state = p.init_opt(opt, stacked)
    ckeys = jax.random.split(jax.random.PRNGKey(3), m)
    idx = jnp.asarray([2, 0])
    keep = jnp.asarray([True, False])
    args = (update, idx, keep, stacked, opt_state,
            fed.x, fed.y, fed.n, ckeys)
    fast = p.update_cohort(*args)
    ref = Placement.update_cohort(p, *args)
    for a, b in zip(jax.tree_util.tree_leaves(fast),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=0)


def test_async_charges_cohort_level_downlink(fed):
    """Only the K buffered clients download: a unicast strategy (ucfl,
    m streams) is charged K streams per event, and FedFOMO's per-client
    unicasts scale by K/m."""
    cfg = AsyncConfig(buffer_k=2)
    h = run_federated("ucfl", fed, fl=SMALL, system=STRAGGLER,
                      async_cfg=cfg)
    assert all(c.n_streams == 2 for c in h.comm)
    h = run_federated("fedfomo", fed, fl=SMALL, system=STRAGGLER,
                      async_cfg=cfg)
    full = 4 * SMALL.fomo_candidates
    assert all(c.n_unicasts == full // 2 for c in h.comm)
    # broadcast strategies are unaffected: one stream serves everyone
    h = run_federated("fedavg", fed, fl=SMALL, system=STRAGGLER,
                      async_cfg=cfg)
    assert all(c.n_streams == 1 for c in h.comm)


def test_async_beats_sync_wall_clock_under_stragglers(fed):
    """K < m: events wait for the K-th earliest arrival, not the max."""
    fl = FLConfig(rounds=6, local_steps=2, batch_size=16, eval_every=1)
    sync = run_federated("fedavg", fed, fl=fl, system=STRAGGLER)
    a = run_federated("fedavg", fed, fl=fl, system=STRAGGLER,
                      async_cfg=AsyncConfig(buffer_k=2))
    assert a.time[-1] < sync.time[-1]


def test_async_max_staleness_zero_still_progresses(fed):
    """tau=0 drops every update that spans an aggregation; the run must
    still complete (dropped clients re-download and restart)."""
    h = run_federated("fedavg", fed, fl=SMALL, system=STRAGGLER,
                      async_cfg=AsyncConfig(buffer_k=2, max_staleness=0.0))
    assert len(h.mean_acc) == SMALL.rounds


def test_async_rejects_sampler(fed):
    with pytest.raises(TypeError, match="sampler|Sampler"):
        run_federated("fedavg", fed, fl=SMALL,
                      sampler=UniformFraction(0.5),
                      async_cfg=AsyncConfig(buffer_k=2))


def test_async_config_validation():
    with pytest.raises(ValueError, match="buffer_k"):
        AsyncConfig(buffer_k=0)
    with pytest.raises(ValueError, match="staleness_discount"):
        AsyncConfig(staleness_discount=0.0)
    with pytest.raises(ValueError, match="max_staleness"):
        AsyncConfig(max_staleness=-1.0)


# ---------------------------------------------------------------------------
# mesh async smoke (8 forced host devices in CI's async-smoke job)


@pytest.mark.parametrize("schedule", ["gspmd", "shard_map_streams"])
def test_mesh_async_smoke(schedule):
    fed8 = scenario_label_shift(KEY, n=640, m=8)
    h = run_federated("ucfl_k2", fed8, fl=SMALL, system=STRAGGLER,
                      placement=MeshShardMap(schedule=schedule),
                      async_cfg=AsyncConfig(buffer_k=4, max_staleness=3.0,
                                            staleness_discount=0.8))
    assert len(h.mean_acc) == SMALL.rounds
    assert all(np.isfinite(h.mean_acc))


# ---------------------------------------------------------------------------
# satellites: engine buffer donation, UniformFraction explicit count


def test_reads_prev_declarations():
    assert not STRATEGIES["fedavg"].reads_prev
    assert not STRATEGIES["local"].reads_prev
    assert not STRATEGIES["oracle"].reads_prev
    assert not STRATEGIES["ucfl"].reads_prev
    assert STRATEGIES["cfl"].reads_prev
    assert STRATEGIES["fedfomo"].reads_prev


def test_donating_run_keeps_state_finite(fed):
    """fedavg + no sampler hits the donated update step; the results and
    the kept final state must be intact."""
    h = run_federated("fedavg", fed, fl=SMALL, keep_state=True)
    assert all(np.isfinite(h.mean_acc))
    leaves = jax.tree_util.tree_leaves(h.final_params)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)


def test_uniform_fraction_explicit_count(fed):
    s = UniformFraction(count=2)
    mask = s.sample(0, fed.m, jax.random.PRNGKey(1))
    assert int(mask.sum()) == 2
    assert UniformFraction(count=10).sample(0, 4, KEY) is None  # >= m: all
    with pytest.raises(ValueError, match="exactly one"):
        UniformFraction(0.5, count=2)
    with pytest.raises(ValueError, match="exactly one"):
        UniformFraction()
    with pytest.raises(ValueError, match="count"):
        UniformFraction(count=0)


def test_sync_cost_charges_participants_only(fed):
    """Satellite fix: with a sampler the analytic clock uses H_|S|, not
    H_m — a partial-participation round must be cheaper than a full one."""
    fl = FLConfig(rounds=2, local_steps=1, batch_size=8, eval_every=1)
    full = run_federated("fedavg", fed, fl=fl, system=STRAGGLER)
    part = run_federated("fedavg", fed, fl=fl, system=STRAGGLER,
                         sampler=UniformFraction(count=2), seed=0)
    expect_delta = STRAGGLER.compute_time(fed.m) - STRAGGLER.compute_time(2)
    assert full.time[-1] - part.time[-1] == \
        pytest.approx(fl.rounds * expect_delta)
