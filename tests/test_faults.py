"""Fault-injection + resilient-runtime subsystem (DESIGN.md §3g).

What is pinned here:
  * spec grammar — `parse_fault_spec` roundtrips and dies with pointed
    errors; `resolve_fault_plan` draws the same static Byzantine set for
    the same seed and normalizes all-zero rates to None
  * faults-off parity — faults=None / zero-rate specs / robust_agg="none"
    are BITWISE identical to the clean engines, on the fused superstep,
    the eventful loop, the async runtime and the paging engine
  * fused == eventful bitwise with faults ON (same key derivation)
  * crash semantics — crash:1.0 leaves the global model at init
  * screening — NaN uploads warn (`NonFiniteEvalWarning`) undefended and
    stay finite + quarantined under a defense
  * robust aggregators — unit transforms on hand-built delta stacks plus
    end-to-end Byzantine recovery (honest-client accuracy)
  * quorum — below-quorum rounds move no downlink and book skipped_rounds
  * async retries — deterministic backoff, dead clients, early-end warning
  * verified checkpoints — crc32 envelope catches truncation and
    bit-flips, legacy pre-envelope files still load, and a paged run
    whose newest snapshot is corrupt resumes from the previous intact
    one bit-identically
"""
import os
import pathlib
import warnings

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptError, paged_checkpoints,
                              restore, save)
from repro.data.federated import scenario_label_shift
from repro.fl import (AsyncConfig, FLConfig, FaultConfig, FixedCohort,
                      HostVmap, PagingConfig, SYSTEMS, parse_fault_spec,
                      resolve_fault_plan, run_federated)
from repro.fl.faults import get_robust_aggregator
from repro.fl.faults.defense import screen_and_defend
from repro.fl.faults.runtime import FaultMeter, pop_with_retries
from repro.fl.simulator import NonFiniteEvalWarning, default_model_init
from repro.fl.strategies import quarantine_reweight
from repro.models import lenet
from test_population import assert_history_equal, assert_params_equal

KEY = jax.random.PRNGKey(0)
FL = FLConfig(rounds=5, local_steps=2, batch_size=16, eval_every=2)


@pytest.fixture(scope="module")
def fed():
    return scenario_label_shift(KEY, n=400, m=8)


@pytest.fixture(scope="module")
def model_init(fed):
    return default_model_init(fed)


def run(fed, model_init, spec="fedavg", fl=FL, **kw):
    return run_federated(spec, fed, fl=fl, model_init=model_init,
                         system=SYSTEMS["wired"], placement=HostVmap(),
                         keep_state=True, seed=0, **kw)


# ---------------------------------------------------------------------------
# spec grammar + plan resolution


def test_fault_spec_roundtrip():
    cfg = parse_fault_spec("crash:0.1,nan:0.05,byz:0.25:scale:5,"
                           "bitrot:0.2:0.001,seed:7")
    assert cfg == FaultConfig(crash=0.1, nan=0.05, byz=0.25,
                              byz_mode="scale", byz_scale=5.0, bitrot=0.2,
                              bitrot_density=0.001, seed=7)
    assert parse_fault_spec(cfg.spec) == cfg
    assert parse_fault_spec("none") == FaultConfig()
    assert FaultConfig().spec == "none"


@pytest.mark.parametrize("bad", ["crash", "crash:2.0", "byz:0.2:evil",
                                 "byz:0.2:scale:0", "gamma:0.1",
                                 "bitrot:0.1:0", "seed:x"])
def test_fault_spec_errors(bad):
    with pytest.raises(ValueError):
        resolve_fault_plan(bad, 8)


def test_fault_plan_resolution():
    assert resolve_fault_plan(None, 8) is None
    assert resolve_fault_plan("crash:0.0,byz:0", 8) is None
    a = resolve_fault_plan("byz:0.25,seed:3", 8)
    b = resolve_fault_plan("byz:0.25,seed:3", 8)
    assert a.byz_mask.sum() == 2          # round(0.25 * 8)
    assert (a.byz_mask == b.byz_mask).all()
    c = resolve_fault_plan("byz:0.25,seed:4", 8)
    assert a.cfg != c.cfg
    # cohort gather of the static adversary row
    idx = np.array([1, 0, 3])
    assert (a.byz_row(idx) == a.byz_mask[idx].astype(np.float32)).all()


def test_robust_agg_registry():
    assert get_robust_aggregator(None) is None
    assert get_robust_aggregator("none") is None
    assert get_robust_aggregator("clip:2.5").c == 2.5
    assert get_robust_aggregator("trimmed_mean:0.2").f == 0.2
    assert get_robust_aggregator("krum:0.3").frac == 0.3
    assert get_robust_aggregator("median").spec == "median"
    for bad in ["huber", "median:0.2", "trimmed_mean:0.7", "clip:-1",
                "none:1"]:
        with pytest.raises(ValueError):
            get_robust_aggregator(bad)


# ---------------------------------------------------------------------------
# defense unit tests on hand-built stacks


def _stack(delta):
    """(m, d) delta matrix -> (stacked, prev) param-shaped pytrees."""
    delta = jnp.asarray(delta, jnp.float32)
    prev = {"w": jnp.zeros_like(delta)}
    return {"w": delta}, prev


def test_screen_quarantines_nonfinite():
    stacked, prev = _stack([[1., 1.], [jnp.nan, 1.], [1., jnp.inf],
                            [2., 2.]])
    out, keep = screen_and_defend(get_robust_aggregator("median"),
                                  stacked, prev)
    assert np.asarray(keep).tolist() == [1.0, 0.0, 0.0, 1.0]
    assert np.isfinite(np.asarray(out["w"])).all()
    # nan-aware median of the two survivors
    assert np.allclose(np.asarray(out["w"]), 1.5)


def test_clip_bounds_row_norms():
    stacked, prev = _stack([[3., 4.], [0.3, 0.4]])
    out, keep = screen_and_defend(get_robust_aggregator("clip:1"),
                                  stacked, prev)
    norms = np.linalg.norm(np.asarray(out["w"]), axis=1)
    assert np.allclose(norms, [1.0, 0.5])        # clipped / untouched
    assert np.asarray(keep).tolist() == [1.0, 1.0]


def test_trimmed_mean_clamps_outliers():
    honest = np.ones((6, 3), np.float32) + 0.1 * np.arange(6)[:, None]
    delta = np.concatenate([honest, [[-50.] * 3], [[80.] * 3]])
    stacked, prev = _stack(delta)
    out, _ = screen_and_defend(get_robust_aggregator("trimmed_mean:0.25"),
                               stacked, prev)
    w = np.asarray(out["w"])
    assert w.min() >= honest.min() and w.max() <= honest.max()
    # defended mean lands inside the honest range
    assert honest.min() <= w.mean() <= honest.max()


def test_krum_quarantines_outlier():
    honest = np.random.default_rng(0).normal(1.0, 0.05, (7, 4))
    delta = np.concatenate([honest[:3], [[-40.] * 4], honest[3:]])
    stacked, prev = _stack(delta)
    out, keep = screen_and_defend(get_robust_aggregator("krum:0.2"),
                                  stacked, prev)
    keep = np.asarray(keep)
    # multi-Krum quarantines f = round(0.2 * 8) = 2 rows, the planted
    # outlier among them; the deltas themselves are untouched
    assert keep[3] == 0.0 and keep.sum() == 6.0
    assert np.allclose(np.asarray(out["w"]), delta)


def test_quarantine_reweight_preserves_mass():
    w = jnp.asarray([[0.5, 0.3, 0.2], [0.2, 0.2, 0.6]], jnp.float32)
    q = jnp.asarray([1.0, 0.0, 1.0])
    rw = np.asarray(quarantine_reweight(w, q))
    assert np.allclose(rw[:, 1], 0.0)
    assert np.allclose(rw.sum(axis=1), np.asarray(w).sum(axis=1))
    # all mass quarantined: fall back to the undefended row
    q0 = jnp.zeros(3)
    assert np.allclose(np.asarray(quarantine_reweight(w, q0)), np.asarray(w))


# ---------------------------------------------------------------------------
# faults-off parity: the knobs' None/zero path is the clean engine, bitwise


def test_faults_off_parity_fused(fed, model_init):
    h0 = run(fed, model_init)
    h1 = run(fed, model_init, faults=None, robust_agg="none",
             min_quorum=None)
    h2 = run(fed, model_init, faults="crash:0.0,byz:0,nan:0")
    for h in (h1, h2):
        assert_history_equal(h0, h)
        assert_params_equal(h0.final_params, h.final_params)
    assert "faults" not in h1.extra


def test_faults_off_parity_eventful_and_async(fed, model_init):
    e0 = run(fed, model_init, superstep=False)
    e1 = run(fed, model_init, superstep=False, faults="none",
             robust_agg=None)
    assert_history_equal(e0, e1)
    assert_params_equal(e0.final_params, e1.final_params)
    a0 = run(fed, model_init, async_cfg=AsyncConfig(buffer_k=4))
    a1 = run(fed, model_init, async_cfg=AsyncConfig(
        buffer_k=4, max_retries=7, retry_backoff=3.0), faults=None)
    assert_history_equal(a0, a1)
    assert_params_equal(a0.final_params, a1.final_params)


def test_faults_off_parity_paged(fed, model_init):
    pg = PagingConfig(cohort=4, schedule=FixedCohort(list(range(4))))
    p0 = run(fed, model_init, paging=pg)
    p1 = run(fed, model_init, paging=pg, faults="crash:0", robust_agg="none")
    assert_history_equal(p0, p1)
    assert_params_equal(p0.final_params, p1.final_params)


# ---------------------------------------------------------------------------
# engine agreement with faults ON: the fused superstep replays the
# eventful loop's exact key chain, so histories match bitwise


@pytest.mark.parametrize("kw", [
    dict(faults="byz:0.25:sign_flip", robust_agg="trimmed_mean:0.25"),
    dict(faults="crash:0.3,nan:0.2", robust_agg="median"),
    dict(faults="crash:0.5", min_quorum=6),
    dict(faults="bitrot:0.3,seed:2", robust_agg="krum:0.25"),
])
def test_fused_matches_eventful_with_faults(fed, model_init, kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        a = run(fed, model_init, superstep=True, **kw)
        b = run(fed, model_init, superstep=False, **kw)
    assert_history_equal(a, b)
    assert_params_equal(a.final_params, b.final_params)
    assert a.extra["faults"] == b.extra["faults"]


# ---------------------------------------------------------------------------
# crash semantics: everyone crashing every round = nothing ever learns


def test_all_crash_keeps_init_params(fed, model_init):
    h = run(fed, model_init, faults="crash:1.0")
    assert h.extra["faults"]["crashed_total"] == fed.m * FL.rounds
    _, kinit = jax.random.split(jax.random.PRNGKey(0))
    p0 = model_init(kinit)
    rows = jax.tree_util.tree_leaves(h.final_params)
    init = jax.tree_util.tree_leaves(p0)
    for got, want in zip(rows, init):
        # every round every row rolls back to prev; re-mixing identical
        # rows is an identity up to float reassociation (~1 ulp/round)
        assert np.allclose(np.asarray(got), np.asarray(want)[None],
                           rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# screening: NaN uploads poison the run undefended, warn at eval, and are
# quarantined + kept finite under any defense


def test_nan_warns_undefended_and_screened_defended(fed, model_init):
    # argmax-accuracy maps NaN logits to a finite score, so score the
    # model by negative loss instead — THAT goes NaN when the aggregated
    # params do, which is exactly what the eval guard must catch
    def neg_loss(params, batch):
        return -lenet.loss_fn(params, batch)[0]

    with pytest.warns(NonFiniteEvalWarning):
        bad = run(fed, model_init, faults="nan:1.0", acc_fn=neg_loss)
    assert bad.extra["nonfinite_evals"] > 0
    with warnings.catch_warnings():
        warnings.simplefilter("error", NonFiniteEvalWarning)
        ok = run(fed, model_init, faults="nan:1.0", robust_agg="median",
                 acc_fn=neg_loss)
    assert np.isfinite(ok.mean_acc).all()
    assert ok.extra["faults"]["quarantined_total"] == fed.m * FL.rounds
    assert "nonfinite_evals" not in ok.extra


# ---------------------------------------------------------------------------
# Byzantine recovery: sign-flip adversaries wreck the undefended run;
# trimmed_mean / krum recover honest-client accuracy


@pytest.mark.parametrize("spec", ["fedavg", "ucfl_k2"])
def test_byzantine_defense_recovers(fed, model_init, spec):
    fl = FLConfig(rounds=8, local_steps=2, batch_size=16, eval_every=4)
    peracc = jax.jit(jax.vmap(
        lambda p, x, y: lenet.accuracy(p, {"x": x, "y": y})))

    def honest_acc(h, byz):
        accs = np.asarray(peracc(h.final_params, fed.x_val, fed.y_val))
        keep = np.ones(len(accs), bool)
        keep[list(byz)] = False
        return float(accs[keep].mean())

    clean = run(fed, model_init, spec, fl=fl)
    atk = run(fed, model_init, spec, fl=fl, faults="byz:0.25:sign_flip")
    byz = atk.extra["faults"]["byzantine_clients"]
    assert len(byz) == 2
    defended = run(fed, model_init, spec, fl=fl,
                   faults="byz:0.25:sign_flip", robust_agg="krum:0.25")
    c, n, d = (honest_acc(clean, byz), honest_acc(atk, byz),
               honest_acc(defended, byz))
    assert n < 0.6 * c          # the attack demonstrably degrades
    assert d >= 0.9 * c         # the defense recovers


# ---------------------------------------------------------------------------
# quorum: below-quorum rounds move no downlink, book skipped_rounds, and
# the model carries forward


def test_min_quorum_skips_rounds(fed, model_init):
    h = run(fed, model_init, faults="crash:1.0", min_quorum=1)
    fx = h.extra["faults"]
    assert fx["skipped_rounds"] == FL.rounds
    assert all(c.n_streams == 0 and c.n_unicasts == 0 for c in h.comm)
    ok = run(fed, model_init, min_quorum=fed.m)       # always met
    base = run(fed, model_init)
    assert_history_equal(ok, base)


def test_min_quorum_validation(fed, model_init):
    with pytest.raises(ValueError, match="min_quorum"):
        run(fed, model_init, min_quorum=0)


# ---------------------------------------------------------------------------
# async retries: deterministic backoff, booked retries, dead clients and
# the early-end warning when every client exhausts its cap


def test_async_crash_retry_deterministic(fed, model_init):
    acfg = AsyncConfig(buffer_k=4, max_retries=3, retry_backoff=0.5)
    a = run(fed, model_init, async_cfg=acfg, faults="crash:0.3")
    b = run(fed, model_init, async_cfg=acfg, faults="crash:0.3")
    assert_history_equal(a, b)
    assert_params_equal(a.final_params, b.final_params)
    assert a.extra["faults"]["retries"] > 0
    assert a.extra["async"]["max_retries"] == 3


def test_async_all_crash_ends_early(fed, model_init):
    acfg = AsyncConfig(buffer_k=4, max_retries=0)
    with pytest.warns(RuntimeWarning, match="exhausted its crash retries"):
        h = run(fed, model_init, async_cfg=acfg, faults="crash:1.0")
    assert h.extra["faults"]["dead_clients"] == list(range(fed.m))
    assert len(h.comm) == 0


def test_pop_with_retries_backoff_ladder():
    class FakeClock:
        def __init__(self):
            self.heap = [(1.0, 5)]
            self.requeued = []

        def __len__(self):
            return len(self.heap)

        def pop(self):
            return self.heap.pop(0)

        def requeue(self, c, at):
            self.requeued.append((c, at))
            self.heap.append((at, c))

    class AlwaysCrash:
        cfg = type("C", (), {"crash": 1.0})()

        def arrival_crash(self):
            return True

    clock, meter = FakeClock(), FaultMeter(None, "none", None)
    out = pop_with_retries(clock, AlwaysCrash(), 2, 1.0, {}, meter)
    assert out is None                      # cap exhausted -> heap drained
    # backoff ladder: t+1·2^0, then t'+1·2^1
    assert clock.requeued == [(5, 2.0), (5, 4.0)]
    assert meter.retries == 2 and meter.dead == {5}


def test_async_config_validation():
    with pytest.raises(ValueError, match="max_retries"):
        AsyncConfig(max_retries=-1)
    with pytest.raises(ValueError, match="retry_backoff"):
        AsyncConfig(retry_backoff=0.0)


# ---------------------------------------------------------------------------
# verified checkpoints: crc32 envelope + atomic replace


def test_checkpoint_roundtrip_and_corruption(tmp_path):
    path = str(tmp_path / "ck.msgpack")
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "step": 3, "name": "x"}
    save(path, tree)
    assert not [p for p in os.listdir(tmp_path) if ".tmp." in p]
    out = restore(path)
    assert out["step"] == 3 and out["name"] == "x"
    assert np.asarray(out["w"] == tree["w"]).all()

    blob = pathlib.Path(path).read_bytes()
    # truncation
    pathlib.Path(path).write_bytes(blob[: len(blob) // 2])
    with pytest.raises(CheckpointCorruptError):
        restore(path)
    # single bit flip in the payload
    flipped = bytearray(blob)
    flipped[len(flipped) // 2] ^= 0x10
    pathlib.Path(path).write_bytes(bytes(flipped))
    with pytest.raises(CheckpointCorruptError, match="checksum mismatch"):
        restore(path)


def test_checkpoint_legacy_pre_envelope_load(tmp_path):
    # a pre-PR9 checkpoint: the bare encoded tree, no envelope
    path = str(tmp_path / "old.msgpack")
    legacy = {"step": 7, "w": {"__nd__": {
        "dtype": "float32", "shape": [2],
        "data": np.asarray([1.5, 2.5], np.float32).tobytes()}}}
    pathlib.Path(path).write_bytes(msgpack.packb(legacy, use_bin_type=True))
    out = restore(path)
    assert out["step"] == 7
    assert np.allclose(np.asarray(out["w"]), [1.5, 2.5])


def test_paged_resume_falls_back_past_corrupt_checkpoint(fed, model_init,
                                                         tmp_path):
    ck, st = str(tmp_path / "ck"), str(tmp_path / "store")
    base = dict(cohort=4, schedule="sweep", checkpoint_dir=ck, store_dir=st)
    kw = dict(fl=FL, model_init=model_init, system=SYSTEMS["wired"],
              keep_state=True)
    h_full = run_federated("fedavg", fed,
                           paging=PagingConfig(cohort=4, schedule="sweep"),
                           **kw)
    run_federated("fedavg", fed, paging=PagingConfig(max_chunks=2, **base),
                  **kw)
    chain = paged_checkpoints(ck)
    assert len(chain) == 2
    # tear the NEWEST snapshot; resume must fall back to the previous one
    with open(chain[0], "r+b") as f:
        f.truncate(os.path.getsize(chain[0]) // 3)
    with pytest.warns(RuntimeWarning, match="failed its integrity check"):
        h_res = run_federated("fedavg", fed,
                              paging=PagingConfig(resume=True, **base), **kw)
    assert h_res.extra["paging"]["resumed_at"] == 1
    assert_history_equal(h_res, h_full)
    assert_params_equal(h_res.final_params, h_full.final_params)


# ---------------------------------------------------------------------------
# CLI validation: typos die at parse time with pointed errors


@pytest.mark.parametrize("argv", [
    ["--faults", "crash:2.0"],
    ["--faults", "gamma:0.1"],
    ["--robust-agg", "huber"],
    ["--robust-agg", "trimmed_mean:0.9"],
    ["--min-quorum", "0"],
    ["--max-retries", "-1"],
    ["--retry-backoff", "0"],
])
def test_train_cli_rejects_bad_fault_flags(argv, capsys):
    from repro.launch.train import main
    with pytest.raises(SystemExit) as e:
        main(argv)
    assert e.value.code == 2
    err = capsys.readouterr().err
    assert argv[0] in err
