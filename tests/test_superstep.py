"""Superstep execution (DESIGN.md §3c): scan-compiled multi-round fusion.

Bit-parity anchors: a fused run must reproduce the eventful per-round
engine EXACTLY — accuracy history, comm, clock, comm_bits and final
params — for every traceable strategy, on both placements, with samplers
and lossy codecs on or off.  Two documented multi-device-emulation
exceptions (histories stay bit-exact in both): the mesh ``gspmd``
schedule lets XLA own the einsum partitioning and may reassociate the
mix reduction between the fused and eventful programs (the pinned
``shard_map`` schedules are bit-exact, which is what CI's 8-device job
asserts); and under ``--xla_force_host_platform_device_count`` the split
thread pool makes XLA:CPU pick different conv schedules per program
shape, so FINAL PARAMS can drift by an ulp between the two program
structures — exact on the default single-device env, allclose under
forced multi-device.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.federated import scenario_label_shift
from repro.fl import (Channel, FLConfig, HostVmap, MeshShardMap, SYSTEMS,
                      UniformFraction, run_federated, superstep_support)
from repro.fl.simulator import _eval_rounds
from repro.fl.strategies import FullParticipation, get_strategy

KEY = jax.random.PRNGKey(0)
FL = FLConfig(rounds=5, local_steps=2, batch_size=16, eval_every=2)
TRACEABLE = ["fedavg", "local", "oracle", "ucfl", "ucfl_k2", "fedfomo"]
EVENTFUL = ["cfl"]


@pytest.fixture(scope="module")
def fed():
    return scenario_label_shift(KEY, n=500, m=4)


def _mesh_exact():
    """A mesh placement whose collectives are pinned (bit-exact parity
    on any device count)."""
    return MeshShardMap(schedule="shard_map_streams")


def assert_history_equal(h_ss, h_ev, *, exact=True):
    assert h_ss.rounds == h_ev.rounds
    if exact:
        assert h_ss.mean_acc == h_ev.mean_acc
        assert h_ss.worst_acc == h_ev.worst_acc
    else:
        np.testing.assert_allclose(h_ss.mean_acc, h_ev.mean_acc, atol=1e-5)
        np.testing.assert_allclose(h_ss.worst_acc, h_ev.worst_acc, atol=1e-5)
    assert h_ss.comm == h_ev.comm
    assert h_ss.time == h_ev.time
    assert h_ss.comm_bits == h_ev.comm_bits


def assert_params_equal(a, b, *, lossy=False):
    # exact on the default single-device env — the branch the tier-1 job
    # (no forced devices) enforces for every anchor below.  The
    # forced-multi-device emulation makes XLA:CPU schedule convs
    # differently per program shape (ulp drift between the fused and
    # eventful programs) even though the evaluated histories above stay
    # bit-exact; a lossy codec amplifies one such ulp discontinuously —
    # stochastic rounding `floor(y + u)` near a boundary jumps a full
    # quantization level (~scale/7 at qsgd:4) — hence its looser atol.
    exact = len(jax.devices()) == 1
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        if exact:
            assert jnp.array_equal(la, lb)
        else:
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       atol=1e-2 if lossy else 1e-5)


# ---------------------------------------------------------------------------
# bit-parity anchors: every traceable strategy × placement


@pytest.mark.parametrize("spec", TRACEABLE)
@pytest.mark.parametrize("placement_fn", [HostVmap, _mesh_exact],
                         ids=["host", "mesh"])
def test_superstep_bit_parity(spec, placement_fn, fed):
    h_ev = run_federated(spec, fed, fl=FL, system=SYSTEMS["wired"],
                         placement=placement_fn(), superstep=False,
                         keep_state=True)
    h_ss = run_federated(spec, fed, fl=FL, system=SYSTEMS["wired"],
                         placement=placement_fn(), superstep=True,
                         keep_state=True)
    assert_history_equal(h_ss, h_ev)
    assert_params_equal(h_ss.final_params, h_ev.final_params)


@pytest.mark.parametrize("placement_fn", [HostVmap, _mesh_exact],
                         ids=["host", "mesh"])
@pytest.mark.parametrize("codec", [None, "qsgd:4"], ids=["raw", "qsgd4"])
@pytest.mark.parametrize("use_sampler", [False, True],
                         ids=["full", "sampler"])
def test_superstep_parity_sampler_codec(placement_fn, codec, use_sampler,
                                        fed):
    """The sampler × codec corner matrix on ucfl_k2 (the paper's main
    configuration): masks, EF residuals and the clock must all replay
    bit-identically through the fused path."""
    kw = dict(fl=FL, system=SYSTEMS["wireless_slow"],
              channel=None if codec is None else Channel(codec=codec),
              sampler=UniformFraction(0.5) if use_sampler else None,
              keep_state=True)
    h_ev = run_federated("ucfl_k2", fed, placement=placement_fn(),
                         superstep=False, **kw)
    h_ss = run_federated("ucfl_k2", fed, placement=placement_fn(),
                         superstep=True, **kw)
    assert_history_equal(h_ss, h_ev)
    assert_params_equal(h_ss.final_params, h_ev.final_params,
                        lossy=codec is not None)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="gspmd reassociation only appears multi-device")
def test_superstep_mesh_gspmd_close(fed):
    """gspmd leaves the mix collectives to XLA: fused vs eventful may
    differ in the last ulp on >1 devices (the pinned shard_map schedules
    are exact — asserted above); anchor the histories at tight
    tolerance."""
    fed8 = scenario_label_shift(KEY, n=500, m=8)
    h_ev = run_federated("ucfl_k2", fed8, fl=FL, superstep=False,
                         placement=MeshShardMap(schedule="gspmd"))
    h_ss = run_federated("ucfl_k2", fed8, fl=FL, superstep=True,
                         placement=MeshShardMap(schedule="gspmd"))
    assert_history_equal(h_ss, h_ev, exact=False)


def test_superstep_mesh_gspmd_exact_single_device(fed):
    if len(jax.devices()) > 1:
        pytest.skip("exact gspmd parity is a single-device property")
    h_ev = run_federated("ucfl_k2", fed, fl=FL, superstep=False,
                         placement=MeshShardMap(schedule="gspmd"))
    h_ss = run_federated("ucfl_k2", fed, fl=FL, superstep=True,
                         placement=MeshShardMap(schedule="gspmd"))
    assert_history_equal(h_ss, h_ev)


# ---------------------------------------------------------------------------
# engine dispatch: auto-fusion, fallback, forcing


def test_superstep_support_matrix():
    for spec in TRACEABLE:
        ok, _ = superstep_support(get_strategy(spec), None)
        assert ok
        ok, _ = superstep_support(get_strategy(spec), UniformFraction(0.5))
        assert ok
        ok, _ = superstep_support(get_strategy(spec), FullParticipation())
        assert ok
    for spec in EVENTFUL:
        ok, why = superstep_support(get_strategy(spec), None)
        assert not ok and spec in why


def test_superstep_subclass_override_falls_back(fed):
    """A subclass of a traceable strategy that overrides the EVENTFUL
    hooks without re-implementing aggregate_traced must not silently fuse
    with the parent's traced rule."""
    from repro.fl.strategies import FedAvg

    class ScaledAvg(FedAvg):
        name = "scaled_avg_test"

        def aggregate(self, state, stacked, prev, ctx):
            return ctx.mix(stacked, 0.5 * state), state

    ok, why = superstep_support(ScaledAvg(), None)
    assert not ok and "aggregate" in why
    # the engine transparently runs it eventful under the default ...
    h = run_federated(strategy=ScaledAvg(), fed=fed, fl=FLConfig(
        rounds=2, local_steps=1, batch_size=8, eval_every=1))
    assert len(h.mean_acc) == 2
    # ... and refuses to force-fuse
    with pytest.raises(ValueError, match="cannot fuse"):
        run_federated(strategy=ScaledAvg(), fed=fed, fl=FL, superstep=True)
    # a subclass that re-implements BOTH hooks stays fusible
    class BothAvg(FedAvg):
        name = "both_avg_test"

        def aggregate(self, state, stacked, prev, ctx):
            return ctx.mix(stacked, state), state

        def aggregate_traced(self, arrays, stacked, prev, tmix):
            return tmix.mix(stacked, arrays)

    ok, _ = superstep_support(BothAvg(), None)
    assert ok


def test_superstep_default_fuses_traceable(fed, monkeypatch):
    """superstep=None must take the fused path for traceable configs."""
    import repro.fl.simulator as sim
    calls = []
    orig = sim._run_superstep

    def spy(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(sim, "_run_superstep", spy)
    sim.run_federated("fedavg", fed, fl=FL)
    assert calls, "traceable run did not auto-fuse"


def test_superstep_fallback_eventful_strategies(fed):
    """cfl transparently runs the eventful loop under the default
    (and matches an explicit superstep=False run exactly)."""
    fl = FLConfig(rounds=3, local_steps=1, batch_size=16, eval_every=1,
                  cfl_min_rounds=1)
    for spec in EVENTFUL:
        h_auto = run_federated(spec, fed, fl=fl)        # superstep=None
        h_ev = run_federated(spec, fed, fl=fl, superstep=False)
        assert h_auto.mean_acc == h_ev.mean_acc


def test_superstep_true_raises_for_eventful(fed):
    with pytest.raises(ValueError, match="cannot fuse"):
        run_federated("cfl", fed, fl=FL, superstep=True)


def test_superstep_rejected_under_async(fed):
    from repro.fl import AsyncConfig
    with pytest.raises(TypeError, match="async"):
        run_federated("fedavg", fed, fl=FL, superstep=True,
                      async_cfg=AsyncConfig(buffer_k=2))


# ---------------------------------------------------------------------------
# scan plumbing


def test_eval_rounds_match_eventful_schedule():
    for rounds, ee in [(60, 5), (5, 2), (1, 1), (3, 10), (8, 8), (9, 4)]:
        chunks = list(_eval_rounds(rounds, ee))
        # chunk ends are exactly the eventful eval rounds, in order
        want = [r for r in range(rounds) if r % ee == 0 or r == rounds - 1]
        assert [nxt for _, nxt in chunks] == want
        # chunks tile [0, rounds) without gap or overlap
        covered = [r for rnd, nxt in chunks for r in range(rnd, nxt + 1)]
        assert covered == list(range(rounds))


def test_superstep_donation_smoke(fed):
    """Donated carry under the scan (reads_prev=False, no sampler): the
    fused run donates the whole (key, stacked, opt, ef) carry at each
    superstep boundary and must still reproduce the eventful history."""
    fl = FLConfig(rounds=6, local_steps=1, batch_size=16, eval_every=3)
    h_ev = run_federated("fedavg", fed, fl=fl, superstep=False,
                         keep_state=True)
    h_ss = run_federated("fedavg", fed, fl=fl, superstep=True,
                         keep_state=True)
    assert_history_equal(h_ss, h_ev)
    assert_params_equal(h_ss.final_params, h_ev.final_params)
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree_util.tree_leaves(h_ss.final_params))


def test_superstep_compiled_cache_reused(fed):
    """Two runs with identical configs share the compiled superstep."""
    import repro.fl.simulator as sim
    before = {k: dict(v) for k, v in sim._SUPERSTEP_FNS.items()}
    run_federated("ucfl_k2", fed, fl=FL)
    sizes = {k: len(v) for k, v in sim._SUPERSTEP_FNS.items()}
    run_federated("ucfl_k2", fed, fl=FL)
    assert {k: len(v) for k, v in sim._SUPERSTEP_FNS.items()} == sizes
    del before


# ---------------------------------------------------------------------------
# FedFOMO (m, m) candidate-loss orientation (regression for the batched
# eval replacing the per-candidate pull loop)


def test_fedfomo_candidate_loss_orientation(fed):
    """losses[i, j] must be candidate j's loss on client i's OWN val set,
    prev_losses[i] client i's model on its own set — pinned against a
    per-model reference loop."""
    from repro.fl.strategies import RoundContext
    from repro.fl.strategies.fedfomo import FedFOMO
    from repro.fl.placement import stack_params
    from repro.models import lenet

    m = fed.m
    strat = FedFOMO()
    fl = FLConfig()
    ctx = RoundContext(fed=fed, fl=fl, loss_fn=lenet.loss_fn,
                       acc_fn=lenet.accuracy, params0=None, seed=0)
    state = strat.setup(ctx)
    p0 = lenet.init_params(
        KEY, lenet.LeNetConfig(in_size=fed.x.shape[2],
                               in_channels=fed.x.shape[4],
                               n_classes=int(jnp.max(fed.y)) + 1))
    stacked = stack_params(p0, m)
    stacked = jax.tree_util.tree_map(
        lambda l: l + 0.01 * jax.random.normal(jax.random.PRNGKey(7),
                                               l.shape), stacked)
    got = np.asarray(state.cand_loss_fn(stacked, fed.x_val, fed.y_val)).T
    ref = np.zeros((m, m), np.float32)
    one_model = jax.vmap(lambda p, x, y: lenet.loss_fn(p, {"x": x, "y": y})[0],
                         in_axes=(None, 0, 0))
    for j in range(m):
        pj = jax.tree_util.tree_map(lambda l: l[j], stacked)
        ref[:, j] = np.asarray(one_model(pj, fed.x_val, fed.y_val))
    np.testing.assert_allclose(got, ref, atol=1e-6)
    diag = np.asarray(state.self_loss_fn(stacked, fed.x_val, fed.y_val))
    np.testing.assert_allclose(diag, np.diag(got), atol=1e-6)
