"""Wireless channel subsystem (DESIGN.md §3b): payload bit accounting,
codec registry + properties (hypothesis), error-feedback algebra, Pallas
kernel parity, link profiles, identity-codec bit-parity with the seed
engines on both placements (sync + async), the FedAsync poly staleness
schedule, and the async overlap-downlink charging fix.

CI's channel-smoke job re-runs this file under
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the mesh codec path
exercises real (host) sharding.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.federated import scenario_label_shift
from repro.fl import (AsyncConfig, Channel, ChannelCost, FLConfig, HostVmap,
                      LinkProfile, MeshShardMap, SystemModel, VirtualClock,
                      get_codec, run_federated)
from repro.fl.channel import (apply_uplink, get_link_profile, tree_bits,
                              stacked_ravel, stacked_unravel, tree_size,
                              zeros_like_stack)
from repro.fl.channel.link import round_downlink_time
from repro.fl.strategies import CommCost
from repro.fl.strategies.base import staleness_factors, staleness_reweight
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)
SMALL = FLConfig(rounds=3, local_steps=2, batch_size=16, eval_every=1,
                 cfl_min_rounds=1)
STRAGGLER = SystemModel(rho=2.0, t_min=1.0, inv_mu=1.0, name="straggler")
RELIABLE = SystemModel(rho=2.0, t_min=1.0, inv_mu=0.0, name="reliable")


def _hypothesis():
    """Property tests skip cleanly on bare environments without hypothesis;
    the example-based tests in this module still run."""
    st = pytest.importorskip("hypothesis.strategies")
    from hypothesis import given, settings
    return given, settings, st


@pytest.fixture(scope="module")
def fed():
    return scenario_label_shift(KEY, n=500, m=4)


# ---------------------------------------------------------------------------
# payload accounting


def test_tree_bits_exact_from_dtypes():
    tree = {"w": np.zeros((3, 5), np.float32), "b": np.zeros((7,), np.bfloat16)
            if hasattr(np, "bfloat16") else np.zeros((7,), np.float16),
            "i": np.zeros((2,), np.int8)}
    assert tree_bits(tree) == 3 * 5 * 32 + 7 * 16 + 2 * 8
    assert tree_size(tree) == 15 + 7 + 2


def test_codec_payload_bits():
    tree = {"a": np.zeros((100,), np.float32)}
    assert get_codec("identity").payload_bits(tree) == 3200
    assert get_codec("qsgd:8").payload_bits(tree) == 100 * 8 + 32
    assert get_codec("qsgd:2").payload_bits(tree) == 100 * 2 + 32
    # topk: k = ceil(frac·d) (value, index) pairs of 32 bits each
    assert get_codec("topk:0.1").payload_bits(tree) == 10 * 64
    assert get_codec("topk:0.001").payload_bits(tree) == 1 * 64  # k >= 1


def test_codec_registry_spec_grammar():
    assert get_codec("qsgd:4").spec == "qsgd:4"
    assert get_codec("topk:0.25").spec == "topk:0.25"
    assert get_codec(get_codec("identity")).is_identity
    for bad in ("nope", "qsgd:1", "qsgd:9", "qsgd:x", "topk:0", "topk:1.5"):
        with pytest.raises(ValueError):
            get_codec(bad)


def test_stacked_ravel_roundtrip():
    stacked = {"w": jax.random.normal(KEY, (4, 3, 2)),
               "b": jax.random.normal(KEY, (4, 5))}
    flat = stacked_ravel(stacked)
    assert flat.shape == (4, 11)
    back = stacked_unravel(flat, stacked)
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(stacked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# codec properties (hypothesis where available)


def test_qsgd_unbiased_over_noise_grid():
    """E_u[floor(y+u)] = y: averaging the roundtrip over a fine uniform
    noise grid recovers x to within the grid spacing — deterministic, no
    statistical flakiness."""
    x = jnp.asarray([[0.83, -0.41, 0.07, -0.99, 0.55, 0.0, 1.0, -1.0]],
                    jnp.float32)
    n = 1024
    acc = np.zeros_like(np.asarray(x), np.float64)
    for i in range(n):
        noise = jnp.full(x.shape, (i + 0.5) / n, jnp.float32)
        acc += np.asarray(ref.qsgd_roundtrip_ref(x, noise, 4), np.float64)
    scale = float(jnp.max(jnp.abs(x))) / 7.0
    np.testing.assert_allclose(acc / n, np.asarray(x), atol=1.5 * scale / n)


def test_qsgd_quantization_error_bounded():
    given, settings, st = _hypothesis()

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(1, 6), d=st.sampled_from([32, 257, 2048]),
           bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 99))
    def prop(m, d, bits, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (m, d), jnp.float32)
        noise = jax.random.uniform(jax.random.PRNGKey(seed + 1), (m, d))
        out = ref.qsgd_roundtrip_ref(x, noise, bits)
        scale = np.abs(np.asarray(x)).max(1, keepdims=True) / \
            (2 ** (bits - 1) - 1)
        assert np.all(np.abs(np.asarray(out - x)) <= scale + 1e-6)

    prop()


def test_topk_error_feedback_residual_conservation():
    """decode(v) + residual == v EXACTLY for top-k: kept coordinates are
    transmitted verbatim, dropped ones land whole in the residual."""
    given, settings, st = _hypothesis()

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(1, 5), d=st.sampled_from([16, 100, 513]),
           frac=st.sampled_from([0.05, 0.25, 1.0]), seed=st.integers(0, 99))
    def prop(m, d, frac, seed):
        v = jax.random.normal(jax.random.PRNGKey(seed), (m, d), jnp.float32)
        codec = get_codec(f"topk:{frac}")
        dec = codec.roundtrip(v, KEY, backend="jnp")
        residual = v - dec
        np.testing.assert_array_equal(np.asarray(dec + residual),
                                      np.asarray(v))
        # survivors per row == k (no ties in continuous draws)
        k = codec.k(d)
        assert np.all((np.asarray(dec) != 0).sum(1) <= k)

    prop()


def test_apply_uplink_ef_masking():
    stacked = {"w": jax.random.normal(KEY, (4, 6, 2))}
    prev = jax.tree_util.tree_map(lambda l: l * 0.5, stacked)
    ef = zeros_like_stack(stacked)
    codec = get_codec("topk:0.25")
    mask = jnp.asarray([True, False, True, False])
    new, ef2 = apply_uplink(codec, stacked, prev, ef, KEY, mask)
    # masked-out rows: model and residual untouched
    np.testing.assert_array_equal(np.asarray(new["w"][1]),
                                  np.asarray(stacked["w"][1]))
    np.testing.assert_array_equal(np.asarray(ef2["w"][3]), 0.0)
    # participating rows changed and carry a non-zero residual
    assert bool(jnp.any(new["w"][0] != stacked["w"][0]))
    assert bool(jnp.any(ef2["w"][0] != 0))


def test_identity_uplink_is_noop():
    stacked = {"w": jax.random.normal(KEY, (3, 4))}
    ef = zeros_like_stack(stacked)
    new, ef2 = apply_uplink(get_codec("identity"), stacked, stacked, ef, KEY)
    assert new is stacked and ef2 is ef


# ---------------------------------------------------------------------------
# Pallas kernels vs jnp oracles (interpret mode)


def test_qsgd_kernels_match_ref_exactly():
    for bits in (2, 4, 8):
        x = jax.random.normal(jax.random.fold_in(KEY, bits), (5, 1000))
        noise = jax.random.uniform(jax.random.PRNGKey(1), x.shape)
        got = ops.qsgd_roundtrip(x, noise, bits=bits)
        want = ref.qsgd_roundtrip_ref(x, noise, bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qsgd_quantize_levels_in_range():
    x = jax.random.normal(KEY, (4, 300)) * 10.0
    noise = jax.random.uniform(jax.random.PRNGKey(2), x.shape)
    q, amax = ops.qsgd_quantize(x, noise, bits=4)
    assert q.dtype == jnp.int32
    assert int(jnp.max(jnp.abs(q))) <= 7
    np.testing.assert_allclose(np.asarray(amax[:, 0]),
                               np.abs(np.asarray(x)).max(1), rtol=0)


def test_topk_threshold_kernel_matches_exact_kth():
    x = jax.random.normal(KEY, (6, 777))
    absx = jnp.abs(x)
    for k in (1, 10, 200):
        th = ops.topk_threshold(absx, k=k)
        want = ref.topk_threshold_ref(absx, k)
        # the f32 bisection lands within one ulp BELOW the exact k-th
        # magnitude; what the codec needs is exact survivor counts
        np.testing.assert_allclose(np.asarray(th), np.asarray(want),
                                   rtol=3e-7)
        assert np.all(np.asarray(th) <= np.asarray(want))
        assert np.all((np.asarray(absx) >= np.asarray(th)).sum(1) == k)
    # k >= D keeps everything; all-zero rows threshold at 0
    assert np.all(np.asarray(ops.topk_threshold(absx, k=1000)) == 0)
    assert np.all(np.asarray(ops.topk_threshold(jnp.zeros((3, 256)),
                                                k=5)) == 0)


# ---------------------------------------------------------------------------
# link profiles


def test_link_profile_from_system_is_exact():
    bits = 1522272
    lp = LinkProfile.from_system(STRAGGLER, bits, 8)
    assert lp.downlink_time(bits) == 1.0
    assert lp.max_uplink_time(bits) == STRAGGLER.rho
    assert lp.uplink_time(3, bits) == STRAGGLER.rho
    cost = CommCost(3, 2)
    assert round_downlink_time(lp, cost, bits) == 5.0


def test_link_profile_tiered_and_specs():
    lp = get_link_profile("tiered:4", STRAGGLER, 1000, 6)
    assert lp.downlink_time(1000, [0]) == 1.0
    assert lp.downlink_time(1000, [1]) == 4.0
    assert lp.downlink_time(1000, [0, 1]) == 4.0    # slowest subscriber
    # a unicast reaches ONE receiver: batches are charged the cohort MEAN
    # per-client time, not the slowest subscriber's
    assert lp.mean_unicast_time(1000, [0, 1]) == 2.5
    assert round_downlink_time(lp, CommCost(1, 2), 1000,
                                    [0, 1]) == 4.0 + 2 * 2.5
    assert get_link_profile("lognormal:0.5", STRAGGLER, 1000, 6).m == 6
    with pytest.raises(ValueError):
        get_link_profile("warp", STRAGGLER, 1000, 6)
    with pytest.raises(ValueError):
        LinkProfile(dl_rate=np.ones(3), ul_ratio=-np.ones(3))


def test_link_profile_empty_cohort(fed):
    """A sampler round with ZERO participants must not crash the link
    clock: nobody uploads (0 uplink), the broadcast still goes out at the
    full-profile rate."""
    lp = get_link_profile("tiered:4", STRAGGLER, 1000, 6)
    assert lp.max_uplink_time(1000, []) == 0.0
    assert lp.downlink_time(1000, []) == 4.0
    assert lp.mean_unicast_time(1000, []) == lp.mean_unicast_time(1000)
    from repro.fl import UniformFraction
    h = run_federated("fedavg", fed, fl=SMALL, system=STRAGGLER,
                      sampler=UniformFraction(0.05, min_clients=0),
                      channel=Channel())
    assert all(np.isfinite(h.time))


def test_compressed_payload_shrinks_round_time(fed):
    """qsgd:8 moves ~1/4 the bits of identity: with a link profile the
    analytic clock must get strictly faster."""
    h_id = run_federated("fedavg", fed, fl=SMALL, system=STRAGGLER,
                         channel=Channel())
    h_q = run_federated("fedavg", fed, fl=SMALL, system=STRAGGLER,
                        channel=Channel(codec="qsgd:8"))
    assert h_q.time[-1] < h_id.time[-1]


# ---------------------------------------------------------------------------
# identity-codec bit-parity with the seed engines (the §3b anchor)


@pytest.mark.parametrize("spec", ["fedavg", "ucfl_k2", "cfl", "fedfomo"])
def test_sync_identity_channel_bit_parity(spec, fed):
    base = run_federated(spec, fed, fl=SMALL, system=STRAGGLER,
                         placement=HostVmap())
    ch = run_federated(spec, fed, fl=SMALL, system=STRAGGLER,
                       placement=HostVmap(), channel=Channel())
    assert ch.mean_acc == base.mean_acc        # bit-identical, not approx
    assert ch.worst_acc == base.worst_acc
    assert ch.comm == base.comm
    assert ch.time == base.time                # uniform link: exact clock
    assert len(ch.comm_bits) == SMALL.rounds   # the new axis is populated
    assert base.comm_bits == []                # legacy runs carry no bits


def test_async_identity_channel_bit_parity(fed):
    cfg = AsyncConfig(buffer_k=2, max_staleness=3.0)
    base = run_federated("ucfl_k2", fed, fl=SMALL, system=STRAGGLER,
                         async_cfg=cfg)
    ch = run_federated("ucfl_k2", fed, fl=SMALL, system=STRAGGLER,
                       async_cfg=cfg, channel=Channel())
    assert ch.mean_acc == base.mean_acc
    assert ch.comm == base.comm
    assert ch.time == base.time


def test_mesh_identity_channel_bit_parity(fed):
    base = run_federated("ucfl_k2", fed, fl=SMALL, system=STRAGGLER,
                         placement=MeshShardMap())
    ch = run_federated("ucfl_k2", fed, fl=SMALL, system=STRAGGLER,
                       placement=MeshShardMap(), channel=Channel())
    assert ch.mean_acc == base.mean_acc
    assert ch.time == base.time


def test_sync_lossy_codecs_run_both_placements(fed):
    for placement in (HostVmap(), MeshShardMap()):
        for codec in ("qsgd:8", "topk:0.25"):
            h = run_federated("ucfl_k2", fed, fl=SMALL, system=STRAGGLER,
                              placement=placement,
                              channel=Channel(codec=codec))
            assert all(np.isfinite(h.mean_acc)), (placement.name, codec)
            assert h.extra["channel"]["codec"] == codec
            # compressed payload strictly under the raw model bits
            assert h.extra["channel"]["payload_bits"] < \
                h.extra["channel"]["model_bits"]


def test_async_lossy_codec_runs(fed):
    h = run_federated("ucfl_k2", fed, fl=SMALL, system=STRAGGLER,
                      async_cfg=AsyncConfig(buffer_k=2),
                      channel=Channel(codec="qsgd:8", link="tiered:4"))
    assert all(np.isfinite(h.mean_acc))
    assert len(h.comm_bits) == SMALL.rounds
    # every buffered client uploads one compressed payload per event
    payload = h.extra["channel"]["payload_bits"]
    assert all(c.ul_bits == 2 * payload for c in h.comm_bits)


def test_qsgd8_tracks_identity_accuracy(fed):
    """8-bit quantization with error feedback should stay close to the
    uncompressed run on the miniature (sanity of the value path)."""
    fl = FLConfig(rounds=6, local_steps=2, batch_size=16, eval_every=2)
    a = run_federated("fedavg", fed, fl=fl, channel=Channel())
    b = run_federated("fedavg", fed, fl=fl, channel=Channel(codec="qsgd:8"))
    assert abs(a.mean_acc[-1] - b.mean_acc[-1]) < 0.1


def test_donation_disabled_under_lossy_codec(fed):
    """fedavg declares reads_prev=False (donation), but the codec needs
    prev for Δ — the run must still be correct (prev defined)."""
    h = run_federated("fedavg", fed, fl=SMALL, keep_state=True,
                      channel=Channel(codec="qsgd:8"))
    assert all(np.isfinite(h.mean_acc))
    leaves = jax.tree_util.tree_leaves(h.final_params)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)


def test_error_feedback_improves_topk(fed):
    """Aggressive top-k without EF loses the dropped mass forever; with EF
    it is retransmitted — accuracy must not degrade when EF is on."""
    fl = FLConfig(rounds=8, local_steps=2, batch_size=16, eval_every=7)
    on = run_federated("fedavg", fed, fl=fl,
                       channel=Channel(codec="topk:0.05",
                                       error_feedback=True))
    off = run_federated("fedavg", fed, fl=fl,
                        channel=Channel(codec="topk:0.05",
                                        error_feedback=False))
    assert on.mean_acc[-1] >= off.mean_acc[-1] - 0.02


# ---------------------------------------------------------------------------
# FedAsync polynomial staleness schedule (satellite)


def test_staleness_factors_schedules():
    age = jnp.asarray([0.0, 1.0, 3.0])
    np.testing.assert_allclose(
        np.asarray(staleness_factors(age, schedule="exp", discount=0.5)),
        [1.0, 0.5, 0.125])
    np.testing.assert_allclose(
        np.asarray(staleness_factors(age, schedule="poly", alpha=1.0)),
        [1.0, 0.5, 0.25])
    with pytest.raises(ValueError, match="schedule"):
        staleness_factors(age, schedule="cubic")


def test_poly_reweight_mass_preserving():
    w = jnp.full((2, 4), 0.25, jnp.float32)
    age = jnp.asarray([0.0, 0.0, 1.0, 3.0])
    out = np.asarray(staleness_reweight(w, age, 1.0, schedule="poly",
                                        alpha=1.0))
    raw = 0.25 * np.asarray([1.0, 1.0, 0.5, 0.25])
    np.testing.assert_allclose(out, np.tile(raw / raw.sum(), (2, 1)),
                               rtol=1e-6)
    np.testing.assert_allclose(out.sum(1), [1.0, 1.0], rtol=1e-6)


def test_async_poly_schedule_runs_and_differs(fed):
    exp = run_federated("fedavg", fed, fl=SMALL, system=STRAGGLER,
                        async_cfg=AsyncConfig(buffer_k=2,
                                              staleness_discount=0.5))
    poly = run_federated("fedavg", fed, fl=SMALL, system=STRAGGLER,
                         async_cfg=AsyncConfig(buffer_k=2,
                                               staleness_schedule="poly",
                                               staleness_alpha=2.0))
    assert all(np.isfinite(poly.mean_acc))
    assert poly.extra["async"]["staleness_schedule"] == "poly"
    # different discount laws must actually change the trajectory
    assert poly.mean_acc != exp.mean_acc


def test_async_config_validates_schedule():
    with pytest.raises(ValueError, match="staleness_schedule"):
        AsyncConfig(staleness_schedule="cubic")
    with pytest.raises(ValueError, match="staleness_alpha"):
        AsyncConfig(staleness_schedule="poly", staleness_alpha=-1.0)


# ---------------------------------------------------------------------------
# async overlap-downlink charging fix (satellite)


def test_serve_overlap_concurrent_streams():
    c = VirtualClock(RELIABLE, seed=0)
    assert c.serve(2.0, overlap=True) == 2.0
    # second transmission starts at now (0.0) on its own carrier: it does
    # NOT queue behind the first — completion is max-style, not sum
    assert c.serve(1.0, overlap=True) == 1.0
    c.now = 10.0
    assert c.serve(1.0, overlap=True) == 11.0   # idle downlink: unchanged
    # legacy serialized behaviour still queues
    c2 = VirtualClock(RELIABLE, seed=0)
    assert c2.serve(2.0) == 2.0
    assert c2.serve(1.0) == 3.0


def test_overlap_fix_preserves_lockstep_anchor(fed):
    """Regression on the lockstep anchor: in lockstep every client
    re-downloads before the next event, the downlink is idle, and the
    overlap fix is exactly a no-op — async must still be bit-identical to
    the sync engine."""
    sync = run_federated("ucfl_k2", fed, fl=SMALL, system=RELIABLE,
                         placement=HostVmap())
    a = run_federated("ucfl_k2", fed, fl=SMALL, system=RELIABLE,
                      placement=HostVmap(),
                      async_cfg=AsyncConfig(buffer_k=fed.m))
    assert a.mean_acc == sync.mean_acc
    assert a.time == pytest.approx(sync.time)


def test_overlap_fix_never_charges_more_than_serialized(fed):
    """Under stragglers the overlapped timeline is pointwise <= the
    serialized one (same arrivals, downlink only ever starts earlier)."""
    fl = FLConfig(rounds=6, local_steps=1, batch_size=8, eval_every=1)
    h = run_federated("ucfl", fed, fl=fl, system=STRAGGLER,
                      async_cfg=AsyncConfig(buffer_k=2))
    assert h.time == sorted(h.time)     # reported clock stays monotone


# ---------------------------------------------------------------------------
# History bits axes


def test_history_comm_bits_accounting(fed):
    h = run_federated("ucfl", fed, fl=SMALL, channel=Channel(codec="qsgd:4"))
    payload = h.extra["channel"]["payload_bits"]
    # ucfl unicasts one stream per client: m payloads down, m up per round
    assert all(c == ChannelCost(fed.m * payload, fed.m * payload)
               for c in h.comm_bits)
    assert h.extra["channel"]["dl_bits_total"] == \
        sum(c.dl_bits for c in h.comm_bits)
