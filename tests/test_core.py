"""Unit + property tests for the paper's core: similarity, mixing, streams,
aggregation, theory."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# similarity


def test_delta_matrix_matches_direct():
    g = jax.random.normal(KEY, (7, 300))
    d = C.delta_matrix(g)
    direct = jnp.sum((g[:, None, :] - g[None, :, :]) ** 2, axis=-1)
    np.testing.assert_allclose(np.asarray(d), np.asarray(direct),
                               rtol=1e-4, atol=1e-2)


def test_similarity_round_shapes():
    def loss(p, data):
        pred = data["x"] @ p["w"]
        return jnp.mean((pred - data["y"]) ** 2)

    params = {"w": jnp.ones((5,))}
    ks = jax.random.split(KEY, 6)
    datasets = [{"x": jax.random.normal(ks[i], (20 + i, 5)),
                 "y": jax.random.normal(ks[i + 3], (20 + i,))}
                for i in range(3)]
    delta, sigma2, n = C.similarity_round(loss, params, datasets)
    assert delta.shape == (3, 3) and sigma2.shape == (3,)
    np.testing.assert_allclose(np.asarray(n), [20, 21, 22])
    assert float(jnp.max(jnp.abs(jnp.diag(delta)))) < 1e-5
    assert (np.asarray(sigma2) >= 0).all()


# ---------------------------------------------------------------------------
# mixing (Eq. 6) properties


def test_mixing_matrix_row_stochastic():
    # property test: skips cleanly on bare environments without hypothesis
    st = pytest.importorskip("hypothesis.strategies")
    from hypothesis import given, settings

    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(2, 12), seed=st.integers(0, 1000))
    def prop(m, seed):
        key = jax.random.PRNGKey(seed)
        g = jax.random.normal(key, (m, 50))
        delta = C.delta_matrix(g)
        sigma2 = jax.random.uniform(key, (m,), minval=0.1, maxval=2.0)
        n = jax.random.randint(key, (m,), 10, 1000).astype(jnp.float32)
        w = C.mixing_matrix(delta, sigma2, n)
        np.testing.assert_allclose(np.asarray(jnp.sum(w, 1)), np.ones(m),
                                   rtol=1e-5)
        assert (np.asarray(w) >= 0).all()

    prop()


def test_mixing_homogeneous_equals_fedavg():
    """Paper: homogeneous clients => UCFL degenerates to FedAvg (exactly)."""
    m = 6
    n = jnp.full((m,), 100.0)
    w = C.mixing_matrix(jnp.zeros((m, m)), jnp.ones((m,)), n)
    np.testing.assert_allclose(np.asarray(w), np.full((m, m), 1 / m),
                               atol=1e-7)
    params = {"a": jax.random.normal(KEY, (m, 3, 4))}
    np.testing.assert_allclose(
        np.asarray(C.user_centric_aggregate(params, w)["a"]),
        np.asarray(C.fedavg_aggregate(params, n)["a"]), atol=1e-6)


def test_mixing_infinite_data_goes_local():
    """Paper: n_i -> inf degenerates to local learning for client i."""
    m = 5
    g = jax.random.normal(KEY, (m, 64))
    delta = C.delta_matrix(g)
    n = jnp.ones((m,)).at[2].set(1e12)
    w = C.mixing_matrix(delta, jnp.ones((m,)), n)
    assert float(w[2, 2]) > 0.999


def test_dissimilar_clients_downweighted():
    g = jnp.zeros((4, 32)).at[3].set(100.0)   # client 3 is an outlier
    delta = C.delta_matrix(g)
    w = C.mixing_matrix(delta, jnp.ones((4,)), jnp.full((4,), 10.0))
    assert float(w[0, 3]) < float(w[0, 1]) * 1e-3


# ---------------------------------------------------------------------------
# streams


def test_kmeans_recovers_clusters():
    key = jax.random.PRNGKey(1)
    c0 = jax.random.normal(key, (6, 8)) * 0.05 + 5
    c1 = jax.random.normal(key, (6, 8)) * 0.05 - 5
    rows = jnp.concatenate([c0, c1])
    plan = C.kmeans(rows, 2, key=key)
    a = np.asarray(plan.assignment)
    assert len(set(a[:6])) == 1 and len(set(a[6:])) == 1 and a[0] != a[6]
    s = C.silhouette_score(rows, plan.assignment, 2)
    assert float(s) > 0.9


def test_stream_aggregate_group_broadcast():
    """All clients in a cluster receive the SAME model (group broadcast)."""
    m = 8
    params = {"a": jax.random.normal(KEY, (m, 10))}
    w = C.mixing_matrix(C.delta_matrix(jax.random.normal(KEY, (m, 20))),
                        jnp.ones((m,)), jnp.full((m,), 10.0))
    plan = C.kmeans(w, 3, key=KEY)
    agg = C.stream_aggregate(params, plan)
    a = np.asarray(plan.assignment)
    out = np.asarray(agg["a"])
    for i in range(m):
        for j in range(m):
            if a[i] == a[j]:
                np.testing.assert_allclose(out[i], out[j])
    assert C.downlink_models(plan) == 3
    assert C.downlink_models(w) == m


def test_kmeans_centroids_row_stochastic():
    w = jax.nn.softmax(jax.random.normal(KEY, (10, 10)), axis=1)
    plan = C.kmeans(w, 4, key=KEY)
    np.testing.assert_allclose(np.asarray(jnp.sum(plan.centroids, 1)),
                               np.ones(4), rtol=1e-5)


# ---------------------------------------------------------------------------
# theory


def test_theorem1_bound_tradeoff():
    """Uniform weights win when distributions match; local wins when they
    clash — the bound exposes the paper's collaboration trade-off."""
    m = 4
    n = jnp.full((m,), 20.0)
    uniform = jnp.full((m, m), 1 / m)
    local = jnp.eye(m)
    no_disc = jnp.zeros((m, m))
    big_disc = 10.0 * (1 - jnp.eye(m))
    b_u = C.theorem1_bound(uniform, n, no_disc)
    b_l = C.theorem1_bound(local, n, no_disc)
    assert (np.asarray(b_u) < np.asarray(b_l)).all()
    b_u2 = C.theorem1_bound(uniform, n, big_disc)
    b_l2 = C.theorem1_bound(local, n, big_disc)
    assert (np.asarray(b_l2) < np.asarray(b_u2)).all()


def test_bound_minimizing_weights_beat_heuristic_on_bound():
    m = 6
    key = jax.random.PRNGKey(3)
    disc = jnp.abs(jax.random.normal(key, (m, m)))
    disc = (disc + disc.T) * (1 - jnp.eye(m)) * 0.05
    n = jax.random.randint(key, (m,), 10, 200).astype(jnp.float32)
    w_h = C.mixing_matrix(disc, jnp.ones((m,)), n)
    w_star, b_star = C.bound_minimizing_weights(n, disc, steps=300)
    b_h = C.theorem1_bound(w_h, n, disc)
    assert float(jnp.sum(b_star)) <= float(jnp.sum(b_h)) + 1e-3
