"""Scan-over-layers: exact equivalence with the python-loop stack."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import scan as SC
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
ARCHS = ["stablelm-3b", "gemma2-27b", "olmoe-1b-7b", "deepseek-v3-671b",
         "zamba2-2.7b", "mamba2-780m", "paligemma-3b"]


def _setup(arch, B=2, S=16):
    cfg = get_smoke_config(arch)
    params = T.init_params(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            KEY, (B, cfg.vision.n_tokens, cfg.vision.embed_dim))
    return cfg, params, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_scan_loss_equals_loop(arch):
    cfg, params, batch = _setup(arch)
    l1, _ = T.loss_fn(params, cfg, batch)
    sp = SC.stack_layer_params(params, cfg)
    l2, _ = SC.loss_fn(sp, cfg, batch)
    l3, _ = SC.loss_fn(sp, cfg, batch, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    np.testing.assert_allclose(float(l1), float(l3), rtol=1e-5)


@pytest.mark.parametrize("arch", ["stablelm-3b", "zamba2-2.7b"])
def test_scan_grads_equal_loop(arch):
    cfg, params, batch = _setup(arch)
    g1 = jax.grad(lambda p: T.loss_fn(p, cfg, batch)[0])(params)
    sp = SC.stack_layer_params(params, cfg)
    g2 = jax.grad(lambda p: SC.loss_fn(p, cfg, batch, remat=True)[0])(sp)
    g2u = SC.unstack_layer_params(g2, cfg)
    flat1 = jnp.concatenate([x.ravel() for x in jax.tree_util.tree_leaves(g1)])
    flat2 = jnp.concatenate([x.ravel() for x in jax.tree_util.tree_leaves(g2u)])
    np.testing.assert_allclose(np.asarray(flat1), np.asarray(flat2),
                               rtol=2e-4, atol=2e-5)


def test_stack_roundtrip_identity():
    cfg, params, _ = _setup("gemma2-27b")
    sp = SC.stack_layer_params(params, cfg)
    rt = SC.unstack_layer_params(sp, cfg)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(rt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ["stablelm-3b", "mamba2-780m", "zamba2-2.7b"])
def test_scan_decode_equals_loop(arch):
    cfg, params, batch = _setup(arch, B=2, S=12)
    B, S = batch["tokens"].shape
    caches = T.make_caches(cfg, B, 32, jnp.float32)
    _, c1 = T.prefill(params, cfg, {"tokens": batch["tokens"][:, :-1]}, caches)
    d1, _ = T.decode_step(params, cfg, batch["tokens"][:, -1:], c1,
                          jnp.full((B,), S - 1, jnp.int32))
    sp = SC.stack_layer_params(params, cfg)
    sc = SC.stack_caches(caches, cfg)
    _, c2 = SC.prefill(sp, cfg, {"tokens": batch["tokens"][:, :-1]}, sc)
    d2, _ = SC.decode_step(sp, cfg, batch["tokens"][:, -1:], c2,
                           jnp.full((B,), S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=2e-4,
                               atol=2e-4)


def test_layer_grouping_covers_all_layers():
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        n_pre, period, groups = SC.layer_grouping(cfg)
        assert n_pre + period * groups == cfg.n_layers
