"""End-to-end system behaviour tests for the paper's technique.

These assert the paper's qualitative claims on miniature versions of its
experiments (DESIGN.md §8), plus framework-level integration invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.data.federated import scenario_concept_shift, scenario_label_shift
from repro.fl import FLConfig, run_federated
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (build_train_step, init_stacked_params,
                                make_optimizer)
from repro.configs import get_smoke_config

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# paper claim: UCFL with uniform data == FedAvg exactly (aggregation level)


def test_ucfl_equals_fedavg_under_homogeneity():
    m = 8
    params = {"w": jax.random.normal(KEY, (m, 32, 4)),
              "b": jax.random.normal(KEY, (m, 7))}
    n = jnp.full((m,), 64.0)
    w = C.mixing_matrix(jnp.zeros((m, m)), jnp.ones((m,)), n)
    a1 = C.user_centric_aggregate(params, w)
    a2 = C.fedavg_aggregate(params, n)
    for k in params:
        np.testing.assert_allclose(np.asarray(a1[k]), np.asarray(a2[k]),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# paper claim: under concept shift, local > fedavg; personalization recovers


@pytest.mark.slow
def test_concept_shift_orderings():
    """Paper claims under concept shift, plus the Eq.6 fallback property.

    At this reduced scale (n_i≈187, K=5 ⇒ large Eq.7 σ²) the similarity
    kernel temperature exceeds the Δ separation and W degenerates toward
    FedAvg weights — the method's documented fallback (EXPERIMENTS.md
    §Paper findings).  So the robust assertions are: conflicting tasks
    hurt FedAvg, the oracle recovers, and UCFL is never *worse* than
    FedAvg (it interpolates between FedAvg and local as signal/noise
    allows)."""
    fed = scenario_concept_shift(KEY, n=1500, m=8, n_groups=2)
    fl = FLConfig(rounds=12, local_steps=5, batch_size=32, eval_every=11)
    acc = {alg: run_federated(alg, fed, fl=fl).mean_acc[-1]
           for alg in ["fedavg", "local", "ucfl_k2", "oracle"]}
    assert acc["local"] > acc["fedavg"]        # conflicting tasks
    assert acc["ucfl_k2"] >= acc["fedavg"] - 5e-3   # never worse (fallback)
    assert acc["oracle"] > acc["fedavg"]


@pytest.mark.slow
def test_label_shift_collaboration_helps():
    fed = scenario_label_shift(KEY, n=1200, m=8)
    fl = FLConfig(rounds=12, local_steps=5, batch_size=32, eval_every=11)
    acc = {alg: run_federated(alg, fed, fl=fl).mean_acc[-1]
           for alg in ["fedavg", "local", "ucfl"]}
    assert acc["fedavg"] > acc["local"]        # moderate heterogeneity
    assert acc["ucfl"] >= acc["local"]


# ---------------------------------------------------------------------------
# mesh-level train_step: schedules agree, mixing semantics correct


def _mesh_setup(m=4):
    cfg = get_smoke_config("stablelm-3b")
    mesh = make_host_mesh()
    params = init_stacked_params(KEY, cfg, m)
    opt = make_optimizer(cfg)
    opt_state = opt.init(params)
    batch = {"tokens": jax.random.randint(KEY, (m, 2, 32), 0,
                                          cfg.vocab_size)}
    return cfg, mesh, params, opt_state, batch


def test_train_step_fedavg_synchronizes_clients():
    m = 4
    cfg, mesh, params, opt_state, batch = _mesh_setup(m)
    w = jnp.full((1, m), 1.0 / m)
    assignment = jnp.zeros((m,), jnp.int32)
    step = build_train_step(cfg, mesh, remat=False)
    params, _, metrics = jax.jit(step)(params, opt_state, batch, w, assignment)
    # after a FedAvg round every client holds the same model
    for leaf in jax.tree_util.tree_leaves(params):
        ref = np.asarray(leaf[0])
        for i in range(1, m):
            np.testing.assert_allclose(np.asarray(leaf[i]), ref, atol=1e-6)
    assert np.isfinite(float(metrics["loss"]))


def test_train_step_local_keeps_clients_distinct():
    m = 4
    cfg, mesh, params, opt_state, batch = _mesh_setup(m)
    w = jnp.eye(m)
    assignment = jnp.arange(m, dtype=jnp.int32)
    step = build_train_step(cfg, mesh, remat=False)
    params, _, _ = jax.jit(step)(params, opt_state, batch, w, assignment)
    emb = np.asarray(params["embed"])
    assert not np.allclose(emb[0], emb[1])


def test_train_step_streams_group_broadcast():
    m = 4
    cfg, mesh, params, opt_state, batch = _mesh_setup(m)
    w = jnp.array([[0.5, 0.5, 0.0, 0.0], [0.0, 0.0, 0.5, 0.5]])
    assignment = jnp.array([0, 0, 1, 1], jnp.int32)
    step = build_train_step(cfg, mesh, remat=False)
    params, _, _ = jax.jit(step)(params, opt_state, batch, w, assignment)
    emb = np.asarray(params["embed"], np.float32)
    np.testing.assert_allclose(emb[0], emb[1], atol=1e-6)
    np.testing.assert_allclose(emb[2], emb[3], atol=1e-6)
    assert not np.allclose(emb[0], emb[2])


def test_microbatch_accumulation_matches_full_batch():
    """microbatch=K gradient accumulation == one full-batch step (the
    HBM-fit knob for the giants must not change semantics)."""
    m = 4
    cfg, mesh, params, opt_state, batch = _mesh_setup(m)
    w = jnp.full((1, m), 1.0 / m)
    assignment = jnp.zeros((m,), jnp.int32)
    full = build_train_step(cfg, mesh, remat=False)
    micro = build_train_step(cfg, mesh, remat=False, microbatch=2)
    p1, _, m1 = jax.jit(full)(params, opt_state, batch, w, assignment)
    p2, _, m2 = jax.jit(micro)(params, opt_state, batch, w, assignment)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_gspmd_and_shard_map_schedules_agree():
    """The explicit shard_map collective schedules compute the same round."""
    m = 4
    cfg, mesh, params, opt_state, batch = _mesh_setup(m)
    mesh1 = make_host_mesh()   # 1 device: shard_map degenerate but exercised
    w = jnp.array([[0.7, 0.1, 0.1, 0.1], [0.1, 0.1, 0.1, 0.7]])
    assignment = jnp.array([0, 0, 1, 1], jnp.int32)
    outs = {}
    for schedule in ["gspmd", "shard_map_streams"]:
        step = build_train_step(cfg, mesh1, schedule=schedule, remat=False)
        with mesh1:
            p, _, _ = jax.jit(step)(params, opt_state, batch, w, assignment)
        outs[schedule] = np.asarray(p["embed"], np.float32)
    np.testing.assert_allclose(outs["gspmd"], outs["shard_map_streams"],
                               rtol=2e-2, atol=2e-2)
