"""Optimizer + checkpoint substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (restore, restore_train_state, save,
                              save_train_state)
from repro.optim import (adamw, apply_updates, clip_by_global_norm, constant,
                         cosine_decay, global_norm, sgd, warmup_cosine)

KEY = jax.random.PRNGKey(0)


def _quad_problem():
    target = jax.random.normal(KEY, (10,))
    params = {"w": jnp.zeros((10,))}
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    return params, loss


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.1), lambda: sgd(0.05, momentum=0.9),
    lambda: sgd(0.05, momentum=0.9, nesterov=True),
    lambda: adamw(0.1, weight_decay=0.0)])
def test_optimizers_converge_on_quadratic(make_opt):
    params, loss = _quad_problem()
    opt = make_opt()
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-3


def test_sgd_momentum_matches_manual():
    opt = sgd(0.1, momentum=0.9)
    params = {"w": jnp.array([1.0])}
    state = opt.init(params)
    g = {"w": jnp.array([2.0])}
    upd, state = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.2])   # mu = g
    upd, state = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.38])  # mu = .9*2+2


def test_sgd_param_dtype_state():
    opt = sgd(0.1, momentum=0.9, state_dtype="param")
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["mu"]["w"].dtype == jnp.bfloat16


def test_schedules():
    assert float(constant(0.5)(jnp.asarray(10))) == 0.5
    cd = cosine_decay(1.0, 100, final_frac=0.1)
    assert float(cd(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cd(jnp.asarray(100))) == pytest.approx(0.1)
    wc = warmup_cosine(1.0, 10, 110)
    assert float(wc(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(wc(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(6.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jax.random.normal(KEY, (3, 4)),
                       "b": jnp.arange(5, dtype=jnp.int32)},
            "meta": {"name": "x", "n": 3, "f": 1.5, "flag": True,
                     "none": None},
            "list": [jnp.ones((2,), jnp.bfloat16), "s"]}
    p = str(tmp_path / "ckpt.msgpack")
    save(p, tree)
    back = restore(p)
    np.testing.assert_allclose(np.asarray(back["params"]["w"]),
                               np.asarray(tree["params"]["w"]))
    assert back["params"]["b"].dtype == jnp.int32
    assert back["list"][0].dtype == jnp.bfloat16
    assert back["meta"] == tree["meta"]


def test_train_state_roundtrip(tmp_path):
    params = {"w": jax.random.normal(KEY, (6,))}
    opt = sgd(0.1, momentum=0.9)
    state = opt.init(params)
    p = str(tmp_path / "state.msgpack")
    save_train_state(p, 7, params, state, extra={"arch": "x"})
    step, params2, state2, extra = restore_train_state(p)
    assert step == 7 and extra == {"arch": "x"}
    np.testing.assert_allclose(np.asarray(params2["w"]),
                               np.asarray(params["w"]))
    # restored state is usable
    g = {"w": jnp.ones((6,))}
    upd, _ = opt.update(g, state2, params2)
    assert upd["w"].shape == (6,)
