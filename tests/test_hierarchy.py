"""Hierarchical edge-aggregation tier (DESIGN.md §3f).

The §3f flat-parity anchor: ``HierarchyConfig(devices_per_user=1)`` with
the identity edge codec, mean edge aggregation and zero edge latency must
be BIT-IDENTICAL to the flat engine — accuracy history, clock, comm_bits
and final params — for every traceable strategy on both placements, on
the fused, eventful and async paths.  Two-level runs then layer on:
ragged fleets, edge codecs with error feedback, per-device links charged
on BOTH hops, straggler dropping and the strategy edge hook.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.federated import scenario_label_shift
from repro.fl import (AsyncConfig, Channel, FLConfig, HierarchyConfig,
                      HostVmap, MeshShardMap, SYSTEMS, UniformFraction,
                      run_async, run_federated, superstep_support)
from repro.fl.hierarchy import (EdgeAggregator, fleet_plan,
                                get_edge_aggregator, partition_fleet_data,
                                register_edge_aggregator,
                                resolve_fleet_spec, resolve_hierarchy)
from repro.fl.strategies import get_strategy

KEY = jax.random.PRNGKey(0)
FL = FLConfig(rounds=4, local_steps=2, batch_size=16, eval_every=2)
TRACEABLE = ["fedavg", "local", "oracle", "ucfl", "ucfl_k2", "fedfomo"]
FLAT = HierarchyConfig(devices_per_user=1)
TWO_LEVEL = HierarchyConfig(devices_per_user="ragged:2-4",
                            edge_codec="qsgd:4", edge_link="tiered:4",
                            edge_latency=0.5)


@pytest.fixture(scope="module")
def fed():
    return scenario_label_shift(KEY, n=500, m=4)


def _mesh_exact():
    return MeshShardMap(schedule="shard_map_streams")


def assert_history_equal(h_a, h_b, *, exact=True):
    assert h_a.rounds == h_b.rounds
    if exact:
        assert h_a.mean_acc == h_b.mean_acc
        assert h_a.worst_acc == h_b.worst_acc
    else:
        np.testing.assert_allclose(h_a.mean_acc, h_b.mean_acc, atol=1e-5)
        np.testing.assert_allclose(h_a.worst_acc, h_b.worst_acc, atol=1e-5)
    assert h_a.comm == h_b.comm
    assert h_a.time == h_b.time
    assert h_a.comm_bits == h_b.comm_bits


def assert_params_equal(a, b, *, lossy=False):
    # same tolerance policy as test_superstep: exact on the tier-1
    # single-device env, allclose under forced multi-device emulation
    exact = len(jax.devices()) == 1
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        if exact:
            assert jnp.array_equal(la, lb)
        else:
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       atol=1e-2 if lossy else 1e-5)


# ---------------------------------------------------------------------------
# the flat-parity anchor: degenerate hierarchy == flat engine, bit for bit


@pytest.mark.parametrize("spec", TRACEABLE)
@pytest.mark.parametrize("placement_fn", [HostVmap, _mesh_exact],
                         ids=["host", "mesh"])
def test_flat_parity_traceable(spec, placement_fn, fed):
    h0 = run_federated(spec, fed, fl=FL, system=SYSTEMS["wired"],
                       placement=placement_fn(), keep_state=True)
    h1 = run_federated(spec, fed, fl=FL, system=SYSTEMS["wired"],
                       placement=placement_fn(), keep_state=True,
                       hierarchy=FLAT)
    assert_history_equal(h1, h0)
    assert_params_equal(h1.final_params, h0.final_params)
    assert h1.extra["hierarchy"]["d_max"] == 1


def test_flat_parity_eventful_cfl(fed):
    fl = dataclasses.replace(FL, cfl_min_rounds=1)
    h0 = run_federated("cfl", fed, fl=fl, keep_state=True)
    h1 = run_federated("cfl", fed, fl=fl, keep_state=True, hierarchy=FLAT)
    assert_history_equal(h1, h0)
    assert_params_equal(h1.final_params, h0.final_params)


def test_flat_parity_sampler_and_channel(fed):
    """Participation rollback (EdgeState rides `placement.select`) and the
    server-hop codec both preserve the anchor."""
    kw = dict(fl=FL, sampler=UniformFraction(0.5),
              channel=Channel(codec="qsgd:4"),
              system=SYSTEMS["wireless_slow"], keep_state=True)
    h0 = run_federated("ucfl_k2", fed, **kw)
    h1 = run_federated("ucfl_k2", fed, hierarchy=FLAT, **kw)
    assert_history_equal(h1, h0)
    assert_params_equal(h1.final_params, h0.final_params, lossy=True)


@pytest.mark.parametrize("buffer_k", [4, 2], ids=["lockstep", "partial"])
def test_flat_parity_async(buffer_k, fed):
    """Async flat parity — including partial events, where EdgeState rows
    ride HostVmap's cohort gather/scatter."""
    kw = dict(fl=FL, async_cfg=AsyncConfig(buffer_k=buffer_k),
              keep_state=True)
    h0 = run_async("fedavg", fed, **kw)
    h1 = run_async("fedavg", fed, hierarchy=FLAT, **kw)
    assert_history_equal(h1, h0)
    assert_params_equal(h1.final_params, h0.final_params)


def test_flat_latency_shifts_clock_only(fed):
    """D=1 with edge latency: values stay bit-identical to flat (latency
    is meter-only) and every eval point's clock gains exactly
    rounds_elapsed · latency."""
    lat = 0.5
    h0 = run_federated("fedavg", fed, fl=FL, system=SYSTEMS["wired"],
                       keep_state=True)
    h1 = run_federated("fedavg", fed, fl=FL, system=SYSTEMS["wired"],
                       keep_state=True,
                       hierarchy=HierarchyConfig(devices_per_user=1,
                                                 edge_latency=lat))
    assert h1.mean_acc == h0.mean_acc
    assert_params_equal(h1.final_params, h0.final_params)
    for rnd, t0, t1 in zip(h0.rounds, h0.time, h1.time):
        np.testing.assert_allclose(t1 - t0, (rnd + 1) * lat, rtol=1e-12)


# ---------------------------------------------------------------------------
# two-level rounds: values, engines, and the per-hop books


def test_two_level_fused_matches_eventful(fed):
    h_ev = run_federated("ucfl_k2", fed, fl=FL, superstep=False,
                         keep_state=True, hierarchy=TWO_LEVEL)
    h_ss = run_federated("ucfl_k2", fed, fl=FL, superstep=True,
                         keep_state=True, hierarchy=TWO_LEVEL)
    assert_history_equal(h_ss, h_ev)
    assert_params_equal(h_ss.final_params, h_ev.final_params, lossy=True)


def test_two_level_host_mesh_agree(fed):
    """qsgd's jnp path is bit-identical to the kernel path, so the edge
    sub-round agrees across placements (the same §3b guarantee)."""
    h_h = run_federated("ucfl_k2", fed, fl=FL, hierarchy=TWO_LEVEL)
    h_m = run_federated("ucfl_k2", fed, fl=FL, hierarchy=TWO_LEVEL,
                        placement=_mesh_exact())
    np.testing.assert_allclose(h_h.mean_acc, h_m.mean_acc, atol=1e-5)


def test_two_level_extra_books(fed):
    h = run_federated("fedavg", fed, fl=FL, system=SYSTEMS["wired"],
                      hierarchy=TWO_LEVEL)
    ex = h.extra["hierarchy"]
    counts = ex["devices_per_user"]
    assert len(counts) == fed.m and all(2 <= c <= 4 for c in counts)
    assert ex["d_max"] == max(counts)
    assert ex["edge_codec"] == "qsgd:4"
    assert ex["edge_aggregator"] == "mean"
    assert len(ex["comm_bits"]) == FL.rounds      # one entry per round
    assert ex["edge_dl_bits_total"] > 0 and ex["edge_ul_bits_total"] > 0
    assert all(t >= 0.5 for t in ex["user_edge_time"])


def test_two_level_clock_charges_edge_hop(fed):
    """Identity edge codec + uniform edge link: every device's hop is
    exactly (1 + ρ)·T_dl, so each round's clock gains latency + 1 + ρ on
    top of the flat run — the two-hop charging pin."""
    lat, rho = 0.25, SYSTEMS["wired"].rho
    hc = HierarchyConfig(devices_per_user=2, edge_link="uniform",
                         edge_latency=lat)
    h0 = run_federated("fedavg", fed, fl=FL, system=SYSTEMS["wired"])
    h1 = run_federated("fedavg", fed, fl=FL, system=SYSTEMS["wired"],
                       hierarchy=hc)
    for rnd, t0, t1 in zip(h0.rounds, h0.time, h1.time):
        np.testing.assert_allclose(t1 - t0, (rnd + 1) * (lat + 1.0 + rho),
                                   rtol=1e-9)


def test_edge_error_feedback_changes_values(fed):
    base = dict(devices_per_user=3, edge_codec="qsgd:2")
    h_ef = run_federated("fedavg", fed, fl=FL,
                         hierarchy=HierarchyConfig(**base))
    h_no = run_federated("fedavg", fed, fl=FL,
                         hierarchy=HierarchyConfig(
                             edge_error_feedback=False, **base))
    assert h_ef.mean_acc != h_no.mean_acc
    assert all(np.isfinite(h_ef.mean_acc)) and all(np.isfinite(h_no.mean_acc))


def test_device_dropout_runs_and_differs(fed):
    base = dict(devices_per_user=3)
    h0 = run_federated("fedavg", fed, fl=FL,
                       hierarchy=HierarchyConfig(**base))
    h1 = run_federated("fedavg", fed, fl=FL,
                       hierarchy=HierarchyConfig(device_dropout=0.5, **base))
    assert h0.mean_acc != h1.mean_acc
    assert all(np.isfinite(h1.mean_acc))


# ---------------------------------------------------------------------------
# edge aggregators


def test_drop_stragglers_static_keep(fed):
    hc = HierarchyConfig(devices_per_user=3,
                         edge_aggregator="drop_stragglers:0.4",
                         edge_link="tiered:4")
    plan = fleet_plan(hc, fed.m, {"w": np.zeros(8, np.float32)},
                      SYSTEMS["wired"])
    assert not plan.row_local
    # 3 devices · frac 0.4 -> exactly one dropped per user, the slowest
    assert (plan.participating.sum(axis=1) == 2).all()
    h_drop = run_federated("fedavg", fed, fl=FL, hierarchy=hc)
    h_mean = run_federated("fedavg", fed, fl=FL, hierarchy=HierarchyConfig(
        devices_per_user=3, edge_link="tiered:4"))
    # one less uplink per user per round
    assert (h_drop.extra["hierarchy"]["edge_ul_bits_total"]
            < h_mean.extra["hierarchy"]["edge_ul_bits_total"])
    assert all(np.isfinite(h_drop.mean_acc))


def test_drop_stragglers_async_partial_full_width(fed):
    """row_local=False routes async partial events through the base
    full-width cohort path — the run must stay finite and charge books."""
    hc = HierarchyConfig(devices_per_user=3,
                         edge_aggregator="drop_stragglers:0.4")
    h = run_async("fedavg", fed, fl=FL, async_cfg=AsyncConfig(buffer_k=2),
                  hierarchy=hc)
    assert all(np.isfinite(h.mean_acc))
    assert len(h.extra["hierarchy"]["comm_bits"]) == FL.rounds


def test_non_traceable_aggregator_falls_back_eventful(fed):
    """A host-side aggregator blocks fusion (superstep_support names it),
    runs eventful transparently, and — when its host weights equal the
    traced mean's — reproduces the mean run exactly."""

    @register_edge_aggregator
    class HostMean(EdgeAggregator):
        name = "host_mean_test"
        traceable = False

        def weights_host(self, n, mask):
            wn = np.asarray(n, np.float64) * mask
            s = wn.sum(axis=1, keepdims=True)
            return np.where(s > 0, wn / np.maximum(s, 1e-12),
                            0.0).astype(np.float32)

    hc = HierarchyConfig(devices_per_user=2,
                         edge_aggregator="host_mean_test")
    ok, why = superstep_support(get_strategy("fedavg"), None, hierarchy=hc)
    assert not ok and "host_mean_test" in why
    with pytest.raises(ValueError, match="cannot fuse"):
        run_federated("fedavg", fed, fl=FL, superstep=True, hierarchy=hc)
    h_host = run_federated("fedavg", fed, fl=FL, hierarchy=hc,
                           keep_state=True)
    h_mean = run_federated("fedavg", fed, fl=FL, superstep=False,
                           keep_state=True,
                           hierarchy=HierarchyConfig(devices_per_user=2))
    assert h_host.mean_acc == h_mean.mean_acc
    assert_params_equal(h_host.final_params, h_mean.final_params)


def test_strategy_edge_weights_hook(fed):
    """An overridden `Strategy.edge_weights` is threaded into the edge
    combine; the identity override reproduces the default weighting."""
    from repro.fl.strategies.fedavg import FedAvg

    class EdgeAware(FedAvg):
        name = "edge_aware_test"

        def edge_weights(self, w, n):
            return w

    h_hook = run_federated(strategy=EdgeAware(), fed=fed, fl=FL,
                           hierarchy=HierarchyConfig(devices_per_user=2))
    h_base = run_federated("fedavg", fed, fl=FL,
                           hierarchy=HierarchyConfig(devices_per_user=2))
    assert h_hook.mean_acc == h_base.mean_acc

    class UniformEdge(FedAvg):
        name = "uniform_edge_test"

        def edge_weights(self, w, n):
            mask = (w > 0).astype(jnp.float32)
            s = mask.sum(axis=1, keepdims=True)
            return mask / jnp.maximum(s, 1.0)

    # uneven strided shards (e.g. 42/42/41) make sample- vs uniform-
    # weighting numerically distinct; accuracy is too coarse to always
    # register that, so the discriminator is the final params bitwise
    h_uni = run_federated(strategy=UniformEdge(), fed=fed, fl=FL,
                          hierarchy=HierarchyConfig(devices_per_user=3),
                          keep_state=True)
    h_def = run_federated("fedavg", fed, fl=FL,
                          hierarchy=HierarchyConfig(devices_per_user=3),
                          keep_state=True)
    assert all(np.isfinite(h_uni.mean_acc))
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(h_uni.final_params),
                        jax.tree_util.tree_leaves(h_def.final_params)))


def test_edge_aggregator_registry():
    assert get_edge_aggregator("mean").spec == "mean"
    agg = get_edge_aggregator("drop_stragglers:0.25")
    assert agg.spec == "drop_stragglers:0.25" and agg.traceable
    with pytest.raises(ValueError, match="mean"):
        get_edge_aggregator("meann")
    with pytest.raises(ValueError):
        get_edge_aggregator("drop_stragglers:1.5")


# ---------------------------------------------------------------------------
# fleet resolution + data partitioning


def test_resolve_fleet_spec():
    np.testing.assert_array_equal(resolve_fleet_spec(3, 4), [3, 3, 3, 3])
    np.testing.assert_array_equal(resolve_fleet_spec("uniform:2", 3),
                                  [2, 2, 2])
    counts = resolve_fleet_spec("ragged:2-5", 16, seed=1)
    assert counts.shape == (16,) and counts.min() >= 2 and counts.max() <= 5
    assert counts.max() > counts.min()          # actually ragged
    np.testing.assert_array_equal(resolve_fleet_spec((1, 2, 3), 3),
                                  [1, 2, 3])
    with pytest.raises(ValueError):
        resolve_fleet_spec((1, 2), 3)           # wrong length
    with pytest.raises(ValueError):
        resolve_fleet_spec(0, 2)
    with pytest.raises(ValueError):
        resolve_fleet_spec("ragged:5", 2)


def test_hierarchy_config_validation():
    with pytest.raises(ValueError):
        HierarchyConfig(device_dropout=1.0)
    with pytest.raises(ValueError):
        HierarchyConfig(edge_latency=-1.0)
    with pytest.raises(ValueError, match="mean"):
        HierarchyConfig(edge_aggregator="nope")
    assert resolve_hierarchy(None) is None
    assert resolve_hierarchy(2).devices_per_user == 2
    assert resolve_hierarchy("uniform:3").devices_per_user == "uniform:3"
    cfg = HierarchyConfig(devices_per_user=1)
    assert resolve_hierarchy(cfg) is cfg
    with pytest.raises(TypeError):
        resolve_hierarchy(2.5)


def test_partition_fleet_data(fed):
    counts = np.array([1, 2, 3, 2])
    x, y, n = partition_fleet_data(fed, counts, 3)
    m, n_max = fed.x.shape[0], fed.x.shape[1]
    assert x.shape[:2] == (m, 3) and y.shape[:2] == (m, 3)
    # true sizes shard without loss: sum over devices == flat size
    np.testing.assert_array_equal(np.asarray(n).sum(axis=1),
                                  np.asarray(fed.n))
    # invalid device slots carry zero true samples
    assert np.asarray(n)[0, 1:].sum() == 0
    # every device's real rows are a strided shard of the user's data
    n0 = int(fed.n[1])
    dev0 = np.asarray(x[1, 0])[: int(n[1, 0])]
    np.testing.assert_array_equal(dev0, np.asarray(fed.x[1])[:n0][0::2])
    # d_max == 1 degenerates to exact views of the flat arrays
    x1, y1, n1 = partition_fleet_data(fed, np.ones(m, np.int64), 1)
    np.testing.assert_array_equal(np.asarray(x1[:, 0]), np.asarray(fed.x))
    np.testing.assert_array_equal(np.asarray(n1[:, 0]), np.asarray(fed.n))


# ---------------------------------------------------------------------------
# async two-level + composition guards


def test_async_two_level(fed):
    kw = dict(fl=FL, async_cfg=AsyncConfig(buffer_k=2),
              system=SYSTEMS["wired"])
    h2 = run_async("fedavg", fed, hierarchy=TWO_LEVEL, **kw)
    h0 = run_async("fedavg", fed, **kw)
    # both hops charged: every arrival carries its edge sub-round time
    assert h2.time[-1] > h0.time[-1]
    ex = h2.extra["hierarchy"]
    assert len(ex["comm_bits"]) == FL.rounds
    assert ex["edge_ul_bits_total"] > 0


def test_hierarchy_rejects_paging(fed):
    from repro.fl import PagingConfig
    with pytest.raises(TypeError, match="paging"):
        run_federated("fedavg", fed, fl=FL, hierarchy=FLAT,
                      paging=PagingConfig(cohort=2))
    with pytest.raises(TypeError, match="paging"):
        run_async("fedavg", fed, fl=FL, hierarchy=FLAT,
                  paging=PagingConfig(cohort=2))


def test_run_federated_accepts_bare_fleet_specs(fed):
    h = run_federated("fedavg", fed, fl=FL, hierarchy=2)
    assert h.extra["hierarchy"]["d_max"] == 2
    h = run_federated("fedavg", fed, fl=FL, hierarchy="uniform:2")
    assert h.extra["hierarchy"]["d_max"] == 2
