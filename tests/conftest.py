import os

# keep CPU tests deterministic and single-device (the dry-run, and only the
# dry-run, forces 512 host devices in its own process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
