"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward + one train step on CPU, asserting output
shapes and finiteness, plus decode-consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, param_count
from repro.models import transformer as T
from repro.optim import apply_updates, sgd

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        b["audio_embeds"] = jax.random.normal(
            KEY, (B, cfg.encoder.n_ctx, cfg.d_model))
    if cfg.family == "vlm":
        b["vision_embeds"] = jax.random.normal(
            KEY, (B, cfg.vision.n_tokens, cfg.vision.embed_dim))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = T.init_params(KEY, cfg)
    batch = _batch(cfg)
    if cfg.family == "audio":
        from repro.models.encdec import decode, encode
        enc = encode(params, cfg, batch["audio_embeds"])
        logits = decode(params, cfg, batch["tokens"], enc)
        expect_s = batch["tokens"].shape[1]
    else:
        logits, _ = T.forward(params, cfg, batch)
        expect_s = batch["tokens"].shape[1] + (
            cfg.vision.n_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(KEY, cfg)
    batch = _batch(cfg)
    opt = sgd(0.1, momentum=0.9)
    state = opt.init(params)

    def loss(p):
        return T.loss_fn(p, cfg, batch)[0]

    l0, grads = jax.value_and_grad(loss)(params)
    upd, state = opt.update(grads, state, params)
    params = apply_updates(params, upd)
    l1 = loss(params)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    assert float(l1) < float(l0)   # one SGD step reduces loss on same batch


@pytest.mark.parametrize("arch", ["stablelm-3b", "gemma2-27b", "mamba2-780m",
                                  "zamba2-2.7b", "deepseek-v3-671b",
                                  "whisper-tiny", "paligemma-3b"])
def test_decode_matches_forward(arch):
    """Prefill+decode at the last position == full forward (high capacity
    MoE so routing is drop-free and deterministic)."""
    cfg = get_smoke_config(arch)
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = T.init_params(KEY, cfg)
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    prefix = cfg.vision.n_tokens if cfg.family == "vlm" else 0
    if cfg.family == "audio":
        from repro.models.encdec import decode, encode
        enc = encode(params, cfg, batch["audio_embeds"])
        full = decode(params, cfg, batch["tokens"], enc)[:, -1]
    else:
        full = T.forward(params, cfg, batch)[0][:, -1]
    caches = T.make_caches(cfg, B, 32, jnp.float32)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    _, caches = T.prefill(params, cfg, pre, caches)
    pos = jnp.full((B,), prefix + S - 1, jnp.int32)
    d, _ = T.decode_step(params, cfg, batch["tokens"][:, -1:], caches, pos)
    np.testing.assert_allclose(np.asarray(d[:, 0]), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_windowed_ring_prefill_matches_full_cache():
    """Prefill longer than a sliding-window ring (gemma2 local layers under
    prefill_32k — regression for the S > cache_len bug): prefill logits and
    3 subsequent decode steps must match a full-length-cache oracle."""
    from repro.models.attention import init_cache
    cfg = get_smoke_config("gemma2-27b")
    assert cfg.attn_window(0) == 64 and cfg.attn_window(1) is None
    params = T.init_params(KEY, cfg)
    B, S = 2, 96                       # S > window=64 -> ring truncation
    batch = _batch(cfg, B, S)
    caches = T.make_caches(cfg, B, S + 4, jnp.float32)   # local layer -> 64
    assert caches[0].pos.shape[1] == 64
    logits, caches = T.prefill(params, cfg, batch, caches)
    oracle = [init_cache(cfg, B, S + 4, jnp.float32)
              for _ in range(cfg.n_layers)]
    logits_f, oracle = T.prefill(params, cfg, batch, oracle)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_f),
                               rtol=2e-4, atol=2e-4)
    tok = batch["tokens"][:, -1:]
    for step in range(3):
        pos = jnp.full((B,), S + step, jnp.int32)
        a, caches = T.decode_step(params, cfg, tok, caches, pos)
        b, oracle = T.decode_step(params, cfg, tok, oracle, pos)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_full_configs_match_assignment():
    """The exact assigned hyperparameters (spot checks per arch)."""
    c = get_config("olmoe-1b-7b")
    assert (c.n_layers, c.d_model, c.attn.n_heads) == (16, 2048, 16)
    assert (c.moe.n_experts, c.moe.top_k) == (64, 8)
    c = get_config("gemma-2b")
    assert (c.n_layers, c.d_ff, c.attn.n_kv_heads, c.attn.head_dim) == \
        (18, 16384, 1, 256)
    c = get_config("mamba2-780m")
    assert (c.n_layers, c.d_model, c.ssm.d_state) == (48, 1536, 128)
    assert c.attn is None
    c = get_config("zamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm.d_state) == (54, 2560, 64)
    c = get_config("stablelm-3b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == \
        (32, 2560, 6912, 50304)
    c = get_config("deepseek-v3-671b")
    assert (c.n_layers, c.d_model, c.moe.n_experts) == (61, 7168, 256)
    assert c.attn.mla is not None and c.moe.n_shared_experts == 1
    c = get_config("gemma2-27b")
    assert (c.n_layers, c.d_model, c.d_ff) == (46, 4608, 36864)
    assert c.attn.attn_logit_softcap == 50.0
    assert c.attn.layer_pattern == ("local", "global")
    c = get_config("nemotron-4-340b")
    assert (c.n_layers, c.d_model, c.d_ff, c.attn.n_kv_heads) == \
        (96, 18432, 73728, 8)
    assert c.activation == "relu2"
    c = get_config("whisper-tiny")
    assert (c.n_layers, c.d_model, c.encoder.n_layers) == (4, 384, 4)
    c = get_config("paligemma-3b")
    assert (c.vocab_size, c.vision.n_tokens) == (257216, 256)


def test_param_counts_in_expected_range():
    """Analytic param counts land near the named model sizes."""
    expected = {
        "olmoe-1b-7b": (6e9, 8.5e9),
        "gemma-2b": (2.0e9, 3.0e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        # single shared attn block (vs the real model's two + LoRA
        # per-invocation adapters) undersizes zamba2 slightly
        "zamba2-2.7b": (1.8e9, 3.4e9),
        "stablelm-3b": (2.4e9, 3.4e9),
        "deepseek-v3-671b": (6.0e11, 7.4e11),
        "gemma2-27b": (2.3e10, 3.1e10),
        "nemotron-4-340b": (3.0e11, 3.8e11),
        "whisper-tiny": (2e7, 6e7),
        "paligemma-3b": (2.0e9, 3.2e9),
    }
    for arch, (lo, hi) in expected.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, (arch, n)
