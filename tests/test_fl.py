"""FL runtime: data partitioners, simulator rounds, baselines, comm model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.federated import (scenario_concept_shift,
                                  scenario_covariate_shift,
                                  scenario_label_shift)
from repro.data.synthetic import synthetic_emnist, synthetic_lm_tokens
from repro.fl import (FLConfig, SYSTEMS, downlink_cost, harmonic,
                      run_federated)
from repro.fl.comm import SystemModel

KEY = jax.random.PRNGKey(0)
SMALL = FLConfig(rounds=3, local_steps=2, batch_size=16, eval_every=1,
                 cfl_min_rounds=1)


def _tiny_fed(m=6, n=600):
    return scenario_label_shift(KEY, n=n, m=m)


def test_synthetic_emnist_shapes():
    d = synthetic_emnist(KEY, 100)
    assert d["x"].shape == (100, 28, 28, 1)
    assert int(jnp.max(d["y"])) < 47


def test_lm_tokens_learnable_structure():
    toks = synthetic_lm_tokens(KEY, 4, 128, 97)
    assert toks.shape == (4, 128)
    assert int(jnp.max(toks)) < 97
    # deterministic rule => repeated contexts repeat targets (mostly)
    assert len(np.unique(np.asarray(toks))) > 5


def test_label_shift_partition_heterogeneous():
    fed = _tiny_fed()
    assert fed.x.shape[0] == 6
    # Dirichlet(0.4): client label histograms should differ
    h = [np.bincount(np.asarray(fed.y[i]), minlength=47) for i in range(6)]
    corr = np.corrcoef(np.stack(h))
    assert corr.min() < 0.9


def test_covariate_shift_groups_rotate():
    fed = scenario_covariate_shift(KEY, n=800, m=8)
    assert set(np.asarray(fed.group)) == {0, 1, 2, 3}


def test_concept_shift_permutes_labels():
    fed = scenario_concept_shift(KEY, n=600, m=8)
    assert fed.x.shape[-1] == 3
    assert set(np.asarray(fed.group)) == {0, 1, 2, 3}


@pytest.mark.parametrize("alg", ["fedavg", "local", "ucfl", "ucfl_k2",
                                 "oracle", "cfl", "fedfomo"])
def test_all_algorithms_run(alg):
    fed = _tiny_fed()
    h = run_federated(alg, fed, fl=SMALL, system=SYSTEMS["wired"])
    assert len(h.mean_acc) == 3
    assert all(0.0 <= a <= 1.0 for a in h.mean_acc)
    assert h.time[-1] > 0


def test_ucfl_mixing_matrix_recorded():
    fed = _tiny_fed()
    h = run_federated("ucfl", fed, fl=SMALL)
    w = h.extra["mixing_matrix"]
    assert w.shape == (6, 6)
    np.testing.assert_allclose(w.sum(1), np.ones(6), rtol=1e-4)


def test_training_improves_over_init():
    fed = _tiny_fed(m=4, n=500)
    fl = FLConfig(rounds=8, local_steps=5, batch_size=32, eval_every=7)
    h = run_federated("fedavg", fed, fl=fl)
    assert h.mean_acc[-1] > h.mean_acc[0] + 0.05


# ---------------------------------------------------------------------------
# comm model (paper §IV-C)


def test_harmonic_and_compute_time():
    assert abs(harmonic(3) - (1 + 0.5 + 1 / 3)) < 1e-9
    s = SystemModel(rho=4.0, t_min=1.0, inv_mu=1.0)
    assert s.compute_time(3) == pytest.approx(1.0 + harmonic(3))
    r = SystemModel(rho=2.0, t_min=1.0, inv_mu=0.0)
    assert r.compute_time(100) == 1.0


def test_harmonic_asymptotic_matches_exact_at_crossover():
    """Above the cutoff H_m switches to ln(m)+γ+1/(2m)−1/(12m²); the two
    forms must agree to 1e-6 where they meet (and well beyond)."""
    from repro.fl.comm import _HARMONIC_EXACT_MAX as cut
    for m in (cut - 1, cut, cut + 1, cut + 9, 10 * cut):
        exact = sum(1.0 / i for i in range(1, m + 1))
        assert abs(harmonic(m) - exact) < 1e-6, m
    # monotone through the crossover
    assert harmonic(cut) < harmonic(cut + 1) < harmonic(cut + 2)


def test_round_time_orderings():
    """FedAvg round < UCFL-k round < UCFL-full round < FedFOMO round."""
    m = 20
    s = SYSTEMS["wired"]
    t = {}
    for alg, ns in [("fedavg", 1), ("ucfl_k4", 4), ("ucfl", m)]:
        streams, uni = downlink_cost(alg.split("_k")[0], m, n_streams=ns)
        t[alg] = s.round_time(m, n_streams=streams, n_unicasts=uni)
    streams, uni = downlink_cost("fedfomo", m)
    t["fedfomo"] = s.round_time(m, n_streams=streams, n_unicasts=uni)
    assert t["fedavg"] < t["ucfl_k4"] < t["ucfl"] < t["fedfomo"]


def test_asymmetric_ul_dl_shrinks_personalization_penalty():
    """Paper Fig.3: with slow UL (rho=4) + stragglers the extra DL streams
    are relatively cheaper than in the wired system."""
    m = 20
    slow, wired = SYSTEMS["wireless_slow"], SYSTEMS["wired"]
    def rel_penalty(sys_):
        t1 = sys_.round_time(m, n_streams=1)
        tm = sys_.round_time(m, n_streams=m)
        return (tm - t1) / t1
    assert rel_penalty(slow) < rel_penalty(wired)
