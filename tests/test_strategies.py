"""Strategy API: registry spec grammar, string/instance path parity,
client samplers, typed extras, comm-cost accounting."""
import jax
import numpy as np
import pytest

from repro.data.federated import scenario_label_shift
from repro.fl import (CommCost, FLConfig, FullParticipation, MixingExtras,
                      SYSTEMS, UniformFraction, downlink_cost, get_strategy,
                      get_strategy_class, run_federated)
from repro.fl.strategies import (CFL, ClusterExtras, FedAvg, FedFOMO, Local,
                                 Oracle, Strategy, UCFL, available_strategies,
                                 register)

KEY = jax.random.PRNGKey(0)
SMALL = FLConfig(rounds=2, local_steps=2, batch_size=16, eval_every=1,
                 cfl_min_rounds=1)
ALL_SPECS = ["fedavg", "local", "oracle", "ucfl", "ucfl_k2", "cfl", "fedfomo"]


@pytest.fixture(scope="module")
def fed():
    return scenario_label_shift(KEY, n=500, m=5)


# ---------------------------------------------------------------------------
# registry


def test_registry_round_trip():
    s = get_strategy("ucfl_k3")
    assert isinstance(s, UCFL) and s.k == 3 and s.spec == "ucfl_k3"
    assert get_strategy("ucfl").k is None
    assert get_strategy("ucfl", k=4).spec == "ucfl_k4"
    for spec, cls in [("fedavg", FedAvg), ("local", Local), ("oracle", Oracle),
                      ("cfl", CFL), ("fedfomo", FedFOMO)]:
        assert isinstance(get_strategy(spec), cls)
        assert get_strategy_class(spec) is cls
    assert get_strategy_class("ucfl_k7") is UCFL


def test_registry_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown strategy"):
        get_strategy("fedprox")
    with pytest.raises(ValueError):
        get_strategy("ucfl_k")        # parameter grammar needs an integer
    with pytest.raises(ValueError, match="no _k parameter"):
        get_strategy("local_k2")      # family does not take k
    with pytest.raises(ValueError):
        downlink_cost("not_an_alg", 10)


def test_all_seed_algorithms_registered():
    assert set(available_strategies()) == {"fedavg", "local", "oracle",
                                           "ucfl", "cfl", "fedfomo"}


def test_register_rejects_non_strategy():
    with pytest.raises(TypeError):
        register(dict)


# ---------------------------------------------------------------------------
# parity: spec-string path == explicit Strategy instance path


@pytest.mark.parametrize("spec", ALL_SPECS)
def test_string_and_strategy_paths_bit_identical(spec, fed):
    h1 = run_federated(spec, fed, fl=SMALL, system=SYSTEMS["wired"])
    h2 = run_federated(strategy=get_strategy(spec), fed=fed, fl=SMALL,
                       system=SYSTEMS["wired"])
    assert h1.mean_acc == h2.mean_acc        # bit-identical, not approx
    assert h1.worst_acc == h2.worst_acc
    assert h1.time == h2.time
    assert h1.comm == h2.comm


def test_strategy_instance_positional(fed):
    h = run_federated(UCFL(k=2), fed, fl=SMALL)
    assert len(h.mean_acc) == SMALL.rounds


def test_algorithm_and_strategy_mutually_exclusive(fed):
    with pytest.raises(TypeError):
        run_federated("fedavg", fed, strategy=get_strategy("fedavg"))
    with pytest.raises(TypeError):
        run_federated(fed=fed)


# ---------------------------------------------------------------------------
# client samplers


def test_uniform_fraction_sampler_end_to_end(fed):
    h = run_federated("fedavg", fed, fl=SMALL, sampler=UniformFraction(0.5),
                      system=SYSTEMS["wired"])
    assert len(h.mean_acc) == SMALL.rounds
    assert all(0.0 <= a <= 1.0 for a in h.mean_acc)


def test_full_participation_matches_default(fed):
    h1 = run_federated("fedavg", fed, fl=SMALL)
    h2 = run_federated("fedavg", fed, fl=SMALL, sampler=FullParticipation())
    assert h1.mean_acc == h2.mean_acc


def test_uniform_fraction_mask_size():
    s = UniformFraction(0.5)
    mask = s.sample(0, 8, jax.random.PRNGKey(0))
    assert mask.shape == (8,) and int(mask.sum()) == 4
    assert UniformFraction(1.0).sample(0, 8, jax.random.PRNGKey(0)) is None


def test_uniform_fraction_validates():
    with pytest.raises(ValueError):
        UniformFraction(0.0)
    with pytest.raises(ValueError):
        UniformFraction(1.5)


# ---------------------------------------------------------------------------
# comm accounting + typed extras


def test_comm_costs_typed_and_match_shim(fed):
    m = fed.m
    h = run_federated("ucfl_k2", fed, fl=SMALL)
    assert all(isinstance(c, CommCost) for c in h.comm)
    assert h.comm[-1] == downlink_cost("ucfl", m, n_streams=2)
    h = run_federated("fedfomo", fed, fl=SMALL)
    assert h.comm[-1] == downlink_cost(
        "fedfomo", m, fomo_candidates=SMALL.fomo_candidates)
    assert h.comm[-1].n_unicasts == m * SMALL.fomo_candidates


def test_typed_extras_and_legacy_dict(fed):
    h = run_federated("ucfl", fed, fl=SMALL)
    assert isinstance(h.extras, MixingExtras)
    np.testing.assert_array_equal(h.extra["mixing_matrix"],
                                  h.extras.mixing_matrix)
    assert h.extra["comm_per_round"] == h.comm
    h = run_federated("cfl", fed, fl=SMALL)
    assert isinstance(h.extras, ClusterExtras)
    assert h.extras.clusters.shape == (fed.m,)
    h = run_federated("local", fed, fl=SMALL)
    assert h.extras is None and list(h.extra) == ["comm_per_round"]


# ---------------------------------------------------------------------------
# extensibility: a new rule is a class + registry entry, no engine edits


def test_custom_strategy_plugs_in(fed):
    class EveryOther(Strategy):
        """FedAvg on even rounds, local on odd — inexpressible as a seed
        algorithm string; needs only the hook surface."""
        name = "every_other_test"

        def setup(self, ctx):
            from repro.core import fedavg_weights
            return fedavg_weights(ctx.fed.n)

        def aggregate(self, state, stacked, prev, ctx):
            if ctx.rnd % 2 == 0:
                from repro.core import user_centric_aggregate
                return user_centric_aggregate(stacked, state), state
            return stacked, state

        def comm(self, state):
            return CommCost(1, 0)

    h = run_federated(strategy=EveryOther(), fed=fed, fl=SMALL)
    assert len(h.mean_acc) == SMALL.rounds
