"""Cohort paging engine (DESIGN.md §3e): store, schedules, paged runs.

The load-bearing anchors:

  * paged-vs-resident bit parity — a paged run over a `FixedCohort` must
    reproduce a resident run on that sub-population EXACTLY (same seed,
    same compiled superstep executable), on both placements, with lossy
    codecs and samplers on or off;
  * checkpoint-resume parity — a run interrupted mid-sweep and resumed
    from its superstep snapshot must finish bit-identical to an
    uninterrupted run;
  * executable reuse across population sizes — the superstep cache is
    keyed on the COHORT shape, so runs differing only in population size
    share one compiled program (the S3 regression).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_paged_checkpoint
from repro.data.federated import FederatedData, scenario_label_shift
from repro.fl import (AsyncConfig, Channel, FLConfig, FixedCohort, HostVmap,
                      MeshShardMap, PagingConfig, RandomCohorts, SYSTEMS,
                      SequentialSweep, UniformFraction, run_async,
                      run_federated, sub_federated)
from repro.fl.population import ClientStateStore
from repro.fl.simulator import default_model_init

KEY = jax.random.PRNGKey(0)
FL = FLConfig(rounds=5, local_steps=2, batch_size=16, eval_every=2)
IDX = np.array([1, 3, 5, 7])


@pytest.fixture(scope="module")
def fed():
    return scenario_label_shift(KEY, n=500, m=8)


@pytest.fixture(scope="module")
def model_init(fed):
    # the population-sized head for BOTH runs of every parity pair: a
    # cohort may miss high labels, so the resident reference must not
    # re-derive n_classes from the sub-population
    return default_model_init(fed)


def _mesh_exact():
    return MeshShardMap(schedule="shard_map_streams")


def assert_history_equal(h_a, h_b):
    assert h_a.rounds == h_b.rounds
    assert h_a.mean_acc == h_b.mean_acc
    assert h_a.worst_acc == h_b.worst_acc
    assert h_a.comm == h_b.comm
    assert h_a.time == h_b.time
    assert h_a.comm_bits == h_b.comm_bits


def assert_params_equal(a, b):
    # the paged run re-executes the RESIDENT run's cached superstep on
    # bitwise-equal staged inputs, so parity is exact even under forced
    # multi-device emulation (unlike fused-vs-eventful program pairs)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert jnp.array_equal(la, lb)


def take_rows(tree, idx):
    return jax.tree_util.tree_map(lambda l: l[idx], tree)


# ---------------------------------------------------------------------------
# the store


def test_store_roundtrip(tmp_path):
    template = {"params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                           "b": np.ones((4,), np.float32)},
                "opt": {"step": np.zeros((), np.int32)}}
    for directory in (None, str(tmp_path / "rows")):
        store = ClientStateStore.create(template, 16, directory=directory)
        assert store.n == 16
        rows = store.gather(np.array([0, 5, 9]))
        np.testing.assert_array_equal(rows["params"]["w"][1],
                                      template["params"]["w"])
        new = jax.tree_util.tree_map(lambda l: l + 1.0
                                     if l.dtype == np.float32 else l + 1,
                                     rows)
        store.scatter(np.array([0, 5, 9]), new)
        back = store.gather(np.array([5]))
        np.testing.assert_array_equal(back["params"]["w"][0],
                                      template["params"]["w"] + 1.0)
        # untouched rows keep the template
        np.testing.assert_array_equal(store.gather(np.array([1]))
                                      ["params"]["w"][0],
                                      template["params"]["w"])
        store.flush()
        # checkpoint round trip is bitwise
        clone = ClientStateStore.from_state_dict(store.state_dict())
        for a, b in zip(jax.tree_util.tree_leaves(store.tree),
                        jax.tree_util.tree_leaves(clone.tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # memmap leaves persist on disk
    assert any(f.endswith(".npy") for f in os.listdir(tmp_path / "rows"))


def test_store_rejects_bad_leading_dim():
    with pytest.raises(ValueError, match="leading dim"):
        ClientStateStore({"x": np.zeros((4, 2))}, 8)


# ---------------------------------------------------------------------------
# schedules: pure functions of the superstep index (resume contract)


def test_sequential_sweep_covers_population():
    s = SequentialSweep(4)
    seen = np.concatenate([s.indices(t, 16) for t in range(4)])
    np.testing.assert_array_equal(np.sort(seen), np.arange(16))
    # periodic, pure in the step
    np.testing.assert_array_equal(s.indices(5, 16), s.indices(1, 16))
    with pytest.raises(ValueError, match="divisible"):
        s.indices(0, 10)


def test_random_cohorts_pure_in_step():
    s = RandomCohorts(4, seed=7)
    a = s.indices(3, 32)
    np.testing.assert_array_equal(a, s.indices(3, 32))  # pure in the step
    assert np.unique(a).size == 4
    draws = {s.indices(t, 32).tobytes() for t in range(8)}
    assert len(draws) > 1                               # steps vary
    assert s.spec != RandomCohorts(4, seed=8).spec      # seed in identity
    with pytest.raises(ValueError, match="> population"):
        s.indices(0, 3)


def test_fixed_cohort_validation():
    with pytest.raises(ValueError, match="unique"):
        FixedCohort([1, 1, 2])
    with pytest.raises(ValueError, match="out of range"):
        FixedCohort([9]).indices(0, 8)
    np.testing.assert_array_equal(FixedCohort([5, 1, 3]).indices(0, 8),
                                  [1, 3, 5])


# ---------------------------------------------------------------------------
# paged-vs-resident bit parity (the tentpole anchor)


@pytest.mark.parametrize("placement_fn", [HostVmap, _mesh_exact],
                         ids=["host", "mesh"])
@pytest.mark.parametrize("codec", [None, "qsgd:4"], ids=["raw", "qsgd4"])
def test_paged_matches_resident(placement_fn, codec, fed, model_init):
    kw = dict(fl=FL, system=SYSTEMS["wired"], model_init=model_init,
              channel=None if codec is None else Channel(codec=codec),
              keep_state=True)
    h_res = run_federated("ucfl_k2", sub_federated(fed, IDX),
                          placement=placement_fn(), superstep=True, **kw)
    h_pag = run_federated("ucfl_k2", fed, placement=placement_fn(),
                          paging=PagingConfig(schedule=FixedCohort(IDX)),
                          **kw)
    assert_history_equal(h_pag, h_res)
    assert_params_equal(take_rows(h_pag.final_params, IDX),
                        h_res.final_params)
    assert_params_equal(take_rows(h_pag.final_opt_state, IDX),
                        h_res.final_opt_state)
    assert h_pag.extra["paging"]["population"] == fed.m


@pytest.mark.parametrize("spec", ["fedavg", "local", "fedfomo"])
def test_paged_matches_resident_strategies(spec, fed, model_init):
    kw = dict(fl=FL, system=SYSTEMS["wired"], model_init=model_init,
              keep_state=True)
    h_res = run_federated(spec, sub_federated(fed, IDX), superstep=True,
                          **kw)
    h_pag = run_federated(spec, fed,
                          paging=PagingConfig(schedule=FixedCohort(IDX)),
                          **kw)
    assert_history_equal(h_pag, h_res)
    assert_params_equal(take_rows(h_pag.final_params, IDX),
                        h_res.final_params)


def test_paged_sampler_parity(fed, model_init):
    """Participation masks replay bit-identically through the paged
    superstep (sampler + lossy codec corner)."""
    kw = dict(fl=FL, system=SYSTEMS["wireless_slow"], model_init=model_init,
              channel=Channel(codec="qsgd:4"),
              sampler=UniformFraction(0.5), keep_state=True)
    h_res = run_federated("ucfl_k2", sub_federated(fed, IDX),
                          superstep=True, **kw)
    h_pag = run_federated("ucfl_k2", fed,
                          paging=PagingConfig(schedule=FixedCohort(IDX)),
                          **kw)
    assert_history_equal(h_pag, h_res)
    assert_params_equal(take_rows(h_pag.final_params, IDX),
                        h_res.final_params)


def test_paged_rejects_eventful(fed):
    with pytest.raises(ValueError, match="cannot fuse"):
        run_federated("cfl", fed, fl=FL, paging=PagingConfig(cohort=4))
    with pytest.raises(TypeError, match="superstep=False"):
        run_federated("fedavg", fed, fl=FL, superstep=False,
                      paging=PagingConfig(cohort=4))


# ---------------------------------------------------------------------------
# S3 regression: executables are keyed on cohort shape, not population


def test_superstep_cache_reused_across_population_sizes(fed, model_init):
    import repro.fl.simulator as sim

    run_federated("ucfl_k2", fed, fl=FL, model_init=model_init)
    keys = set(sim._SUPERSTEP_FNS)
    sizes = {k: {ln: (fn._cache_size() if hasattr(fn, "_cache_size")
                      else None)
                 for ln, fn in v.items()}
             for k, v in sim._SUPERSTEP_FNS.items()}

    # double the population by concatenation: identical row shapes, so
    # the cohort-shaped superstep must NOT recompile or re-key
    fed2 = FederatedData(
        x=jnp.concatenate([fed.x, fed.x]),
        y=jnp.concatenate([fed.y, fed.y]),
        n=jnp.concatenate([fed.n, fed.n]),
        x_val=jnp.concatenate([fed.x_val, fed.x_val]),
        y_val=jnp.concatenate([fed.y_val, fed.y_val]),
        group=jnp.concatenate([fed.group, fed.group]))
    run_federated("ucfl_k2", fed2, fl=FL, model_init=model_init,
                  paging=PagingConfig(schedule=FixedCohort(np.arange(8))))

    assert set(sim._SUPERSTEP_FNS) == keys, \
        "population size leaked into the superstep cache key"
    for k, v in sim._SUPERSTEP_FNS.items():
        for ln, fn in v.items():
            want = sizes[k][ln]
            got = fn._cache_size() if hasattr(fn, "_cache_size") else None
            assert got == want, \
                f"superstep len={ln} re-specialized: {want} -> {got}"


# ---------------------------------------------------------------------------
# checkpointed supersteps: mid-sweep preemption + bit-identical resume


def test_paged_checkpoint_resume_mid_sweep(fed, model_init, tmp_path):
    ck, st = str(tmp_path / "ck"), str(tmp_path / "store")
    base = dict(cohort=4, schedule="sweep", checkpoint_dir=ck, store_dir=st)
    kw = dict(fl=FL, model_init=model_init, system=SYSTEMS["wired"],
              keep_state=True)

    h_full = run_federated("fedavg", fed,
                           paging=PagingConfig(cohort=4, schedule="sweep"),
                           **kw)
    # preempt after 2 of 3 supersteps ...
    h_part = run_federated("fedavg", fed,
                           paging=PagingConfig(max_chunks=2, **base), **kw)
    assert len(h_part.rounds) == 2
    assert h_part.rounds == h_full.rounds[:2]
    assert h_part.mean_acc == h_full.mean_acc[:2]
    path = latest_paged_checkpoint(ck)
    assert path is not None and path.endswith("superstep_000001.msgpack")
    # ... and resume: the finished run is bit-identical to uninterrupted
    h_res = run_federated("fedavg", fed,
                          paging=PagingConfig(resume=True, **base), **kw)
    assert_history_equal(h_res, h_full)
    assert_params_equal(h_res.final_params, h_full.final_params)
    assert_params_equal(h_res.final_opt_state, h_full.final_opt_state)
    assert h_res.extra["paging"]["resumed_at"] == 2


def test_paged_resume_rejects_mismatched_config(fed, model_init, tmp_path):
    ck = str(tmp_path / "ck")
    cfg = PagingConfig(cohort=4, schedule="sweep", checkpoint_dir=ck,
                       max_chunks=1)
    run_federated("fedavg", fed, fl=FL, model_init=model_init, paging=cfg)
    with pytest.raises(ValueError, match="different run configuration"):
        run_federated("fedavg", fed, fl=FL, model_init=model_init, seed=1,
                      paging=PagingConfig(cohort=4, schedule="sweep",
                                          checkpoint_dir=ck, resume=True))


# ---------------------------------------------------------------------------
# scale-out: population >> cohort trains end-to-end


def test_paged_population_64x_cohort():
    fed = scenario_label_shift(KEY, n=1600, m=128)
    fl = FLConfig(rounds=2, local_steps=1, batch_size=8, eval_every=1)
    h = run_federated("fedavg", fed, fl=fl, keep_state=True,
                      paging=PagingConfig(cohort=2, schedule="sweep"))
    pg = h.extra["paging"]
    assert pg["population"] == 128 and pg["cohort"] == 2
    assert pg["population"] >= 64 * pg["cohort"]
    assert len(h.mean_acc) == 2
    for leaf in jax.tree_util.tree_leaves(h.final_params):
        assert np.isfinite(np.asarray(leaf)).all()


# ---------------------------------------------------------------------------
# the paged buffered-async engine: lockstep anchor


def test_async_paged_lockstep_parity(fed, model_init):
    """buffer_k == population on the reliable system: every event is a
    lockstep round and the store-backed loop must be bit-identical to the
    resident async runtime."""
    cfg = AsyncConfig(buffer_k=fed.m)
    kw = dict(async_cfg=cfg, fl=FL, model_init=model_init, keep_state=True)
    h_res = run_async("fedavg", fed, **kw)
    h_pag = run_async("fedavg", fed, paging=PagingConfig(cohort=fed.m), **kw)
    assert_history_equal(h_pag, h_res)
    assert_params_equal(h_pag.final_params, h_res.final_params)
    assert h_pag.extra["async"]["buffer_k"] == fed.m
    assert h_pag.extra["paging"]["schedule"] == "arrival-buffer"


def test_async_paged_partial_buffer_runs(fed, model_init):
    """Partial arrival buffers (the real async regime): cohort-local
    aggregation trains and reports finite scores."""
    h = run_async("ucfl_k2", fed, async_cfg=AsyncConfig(buffer_k=4),
                  fl=FL, model_init=model_init,
                  system=SYSTEMS["wireless_fast"],
                  paging=PagingConfig(cohort=4), keep_state=True)
    assert len(h.mean_acc) >= 1
    assert all(np.isfinite(a) for a in h.mean_acc)
    for leaf in jax.tree_util.tree_leaves(h.final_params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_async_paged_partial_buffer_arrival_order(fed, model_init):
    """Arrival-ordered anchor for ``buffer_k`` NOT dividing the
    population (k=3, n=8): on the deterministic wired clock every arrival
    is ``start + t_min + ρ`` and heap ties break on client index, so the
    event cohorts and `History.time` are exactly reproducible by a
    reference heap — and the paged loop, driving the same seeded clock,
    must report the same times and per-event comm as the resident async
    engine (the wrap events mix first- and second-generation arrivals,
    which is precisely what a cohort-indexing bug would scramble)."""
    import heapq
    k, n = 3, fed.m
    fl = FLConfig(rounds=6, local_steps=1, batch_size=16, eval_every=1)
    kw = dict(async_cfg=AsyncConfig(buffer_k=k), fl=fl,
              model_init=model_init, system=SYSTEMS["wired"])
    h_pag = run_async("fedavg", fed, paging=PagingConfig(cohort=k), **kw)
    h_res = run_async("fedavg", fed, **kw)
    assert h_pag.time == h_res.time
    assert h_pag.comm == h_res.comm
    assert h_pag.rounds == h_res.rounds

    sysm = SYSTEMS["wired"]
    assert sysm.inv_mu == 0.0            # the law the pins below assume
    step = sysm.t_min + sysm.rho
    heap = [(step, c) for c in range(n)]
    heapq.heapify(heap)
    expect_time, cohorts, now, t_done = [], [], 0.0, 0.0
    for _ in range(fl.rounds):
        cohort = []
        for _ in range(k):
            t, c = heapq.heappop(heap)
            now = max(now, t)
            cohort.append(c)
        done = now + 1                   # fedavg: one broadcast stream
        t_done = max(t_done, done)
        for c in cohort:
            heapq.heappush(heap, (done + step, c))
        cohorts.append(cohort)
        expect_time.append(t_done)
    assert h_pag.time == expect_time
    # the first wrap event buffers stragglers 6, 7 of the first pass with
    # the already-rescheduled client 0 — event order pinned exactly
    assert cohorts[2] == [6, 7, 0]
