import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input shape) on the
production meshes, print memory/cost analysis, dump roofline artifacts.

MUST be run as its own process (the XLA_FLAGS line above precedes every
other import, including jax).  Artifacts land in benchmarks/dryrun_artifacts/
<mesh>/<arch>__<shape>[__tag].json and are consumed by repro.roofline and
benchmarks/roofline_table.py.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import INPUT_SHAPES, build_case
from repro.models.scan import layer_grouping
from repro.roofline.analysis import (model_flops, parse_collective_bytes,
                                     roofline)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "dryrun_artifacts")


def _get(d, *names, default=0.0):
    for n in names:
        if d and n in d:
            return float(d[n])
    return default


def _compile_case(cfg, mesh, shape_name, kw):
    case = build_case(cfg, mesh, shape_name, **kw)
    jitted = jax.jit(case.fn, in_shardings=case.in_shardings,
                     out_shardings=case.out_shardings,
                     donate_argnums=case.donate_argnums)
    with mesh:
        lowered = jitted.lower(*case.args)
        compiled = lowered.compile()
    return case, compiled


def _costs(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)
    return (_get(cost, "flops"),
            _get(cost, "bytes accessed", "bytes accessed operand 0"),
            coll, hlo)


def extrapolated_costs(cfg, mesh, shape_name, kw):
    """XLA's HLO cost analysis counts a while-loop body ONCE (trip count is
    ignored), so the layer-scanned step undercounts flops/bytes/collectives.
    Costs of the *python-loop* variant are exactly affine in depth, and
    shallow loop graphs compile fast — so compile loop variants at 2 and 3
    pattern blocks and extrapolate the slope to the real depth."""
    if cfg.family == "audio":
        return None                      # whisper uses the loop path anyway
    n_pre, period, groups = layer_grouping(cfg)
    if groups <= 3:
        return None
    vals = {}
    kw_loop = dict(kw)
    kw_loop["loop"] = True
    for g in (2, 3):
        cfg_g = dataclasses.replace(cfg, n_layers=n_pre + g * period)
        _, compiled = _compile_case(cfg_g, mesh, shape_name, kw_loop)
        vals[g] = _costs(compiled)[:3]
    def lin(f2, f3):
        slope = f3 - f2
        return f2 + (groups - 2) * slope
    flops = lin(vals[2][0], vals[3][0])
    byts = lin(vals[2][1], vals[3][1])
    coll = {k: lin(float(vals[2][2][k]), float(vals[3][2][k]))
            for k in vals[2][2]}
    return flops, byts, coll


def apply_overrides(cfg, overrides: dict):
    """dataclasses.replace with dotted paths, e.g. {"attn.mla_absorb": True}."""
    for path, value in (overrides or {}).items():
        parts = path.split(".")
        if len(parts) == 1:
            cfg = dataclasses.replace(cfg, **{parts[0]: value})
        else:
            sub = getattr(cfg, parts[0])
            sub = apply_overrides(sub, {".".join(parts[1:]): value})
            cfg = dataclasses.replace(cfg, **{parts[0]: sub})
    return cfg


def run_case(arch: str, shape_name: str, *, multi_pod: bool = False,
             schedule: str = "gspmd", n_streams: int = 4,
             remat: bool = True, microbatch: int = 1, tag: str = "",
             verbose: bool = True, save: bool = True,
             overrides: dict = None) -> dict:
    cfg = apply_overrides(get_config(arch), overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.devices.size
    shape = INPUT_SHAPES[shape_name]
    kw = {}
    if shape.kind == "train":
        kw = dict(schedule=schedule, n_streams=n_streams, remat=remat,
                  microbatch=microbatch)
    t0 = time.time()
    case, compiled = _compile_case(cfg, mesh, shape_name, kw)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    flops_raw, bytes_raw, coll_raw, hlo = _costs(compiled)
    extra = extrapolated_costs(cfg, mesh, shape_name, kw)
    if extra is not None:
        flops_dev, bytes_dev, coll = extra
    else:
        flops_dev, bytes_dev, coll = flops_raw, bytes_raw, coll_raw
    if microbatch > 1 and shape.kind == "train":
        # XLA cost analysis counts the accumulation scan body once; the
        # in-loop flops/bytes scale ×microbatch (weights re-read per slice).
        # Collectives are left unscaled: the mixing collective runs once
        # outside the loop (in-loop TP activation reduces are undercounted
        # — noted in the artifact).
        flops_dev *= microbatch
        bytes_dev *= microbatch
    coll_total = float(sum(coll.values()))
    peak_mem = None
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            peak_mem = (peak_mem or 0.0) + float(v)

    mf = model_flops(cfg, shape.kind, shape.seq_len, shape.global_batch)
    terms = roofline(arch, shape_name, mesh_name, chips, flops_dev, bytes_dev,
                     coll_total, mf, peak_mem)
    result = terms.as_dict()
    result.update({
        "collectives": coll,
        "raw_flops_per_device": flops_raw,
        "raw_bytes_per_device": bytes_raw,
        "extrapolated": extra is not None,
        "microbatch": microbatch,
        "compile_seconds": t_compile,
        "memory_analysis": str(mem),
        "meta": case.meta,
        "n_hlo_lines": hlo.count("\n"),
    })
    if verbose:
        print(f"== {arch} × {shape_name} × {mesh_name} "
              f"(compile {t_compile:.0f}s) ==")
        print(mem)
        print({"flops/device": flops_dev, "bytes/device": bytes_dev,
               "extrapolated": extra is not None})
        print("collective bytes/device:", coll)
        print(f"roofline: compute {terms.t_compute*1e3:.2f}ms  "
              f"memory {terms.t_memory*1e3:.2f}ms  "
              f"collective {terms.t_collective*1e3:.2f}ms  "
              f"-> {terms.bottleneck}; useful-flops ratio "
              f"{terms.useful_flops_ratio:.3f}")
    if save:
        os.makedirs(os.path.join(ARTIFACT_DIR, mesh_name), exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = os.path.join(ARTIFACT_DIR, mesh_name,
                            f"{arch}__{shape_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_IDS)
    p.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true",
                   help="every (arch × shape) on the selected mesh")
    p.add_argument("--schedule", default="gspmd",
                   choices=("gspmd", "shard_map_streams", "shard_map_unicast"))
    p.add_argument("--streams", type=int, default=4)
    p.add_argument("--microbatch", type=int, default=1)
    p.add_argument("--no-remat", action="store_true")
    p.add_argument("--tag", default="")
    p.add_argument("--skip-existing", action="store_true")
    args = p.parse_args()

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    failures = []
    for a, s in combos:
        suffix = f"__{args.tag}" if args.tag else ""
        path = os.path.join(ARTIFACT_DIR, mesh_name, f"{a}__{s}{suffix}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"skip {a} × {s} (artifact exists)")
            continue
        try:
            run_case(a, s, multi_pod=args.multi_pod, schedule=args.schedule,
                     n_streams=args.streams, remat=not args.no_remat,
                     microbatch=args.microbatch, tag=args.tag)
        except Exception as e:  # noqa: BLE001 - report and continue
            traceback.print_exc()
            failures.append((a, s, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("dry-run OK")


if __name__ == "__main__":
    main()
