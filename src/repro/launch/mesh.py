"""Production mesh construction (DESIGN.md §7).

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run forces 512 host devices (in dryrun.py, before any
import); the single-pod mesh then uses the first 256.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

# v5e-class hardware constants (roofline + memory planning)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
HBM_BYTES = 16 * 1024 ** 3


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run via "
            "launch/dryrun.py which forces XLA_FLAGS host device count")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh() -> Mesh:
    """1-device mesh for CPU examples/tests (same code path as production)."""
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def data_axes(mesh: Mesh):
    """The batch-sharding axes of a mesh (pod folds into data parallelism)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def client_axes(mesh: Mesh, cfg) -> tuple:
    """Mesh axes carrying the FL client dimension (DESIGN.md §3).

    "all" = client-per-chip placement (§Perf): weights replicated, every
    mesh axis carries clients — no tensor-parallel collectives remain and
    the mixing collective is the entire communication, exactly the paper's
    PS deployment.  Only for archs whose params+opt fit one chip.
    """
    if cfg.fl_client_axis == "pod":
        return ("pod",) if "pod" in mesh.axis_names else ()
    if cfg.fl_client_axis == "all":
        return tuple(mesh.axis_names)
    return data_axes(mesh)


def n_clients(mesh: Mesh, cfg) -> int:
    n = 1
    for a in client_axes(mesh, cfg):
        n *= mesh.shape[a]
    return n
