"""Partitioning rules: params / optimizer state / batches / caches → PartitionSpec.

Strategy (DESIGN.md §3, §7):
  * tensor parallel over "model": attention heads (or head_dim when the head
    count does not divide), MoE expert dim (expert parallelism), FFN hidden,
    vocab — chosen per-leaf by name-keyed rules with divisibility fallbacks;
  * FSDP over "data" for pod-placed giants (second divisible dim per leaf);
  * the FL client dim (leading axis of stacked params) is sharded over the
    client axes; scan-stacked layer groups add a replicated leading dim.

Everything here is pure metadata: functions map pytrees of arrays or
ShapeDtypeStructs to pytrees of PartitionSpec.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, GetAttrKey, SequenceKey

from repro.configs.base import ModelConfig
from repro.launch.mesh import client_axes, data_axes

# dims preferred for "model" sharding, per param name (indices into the
# leaf's *base* shape, tried in order; first divisible wins)
_MODEL_DIM_PREF = {
    "embed": (0, 1), "pos_emb": (1,), "lm_head": (1, 0),
    "wq": (1, 2, 0), "wk": (1, 2, 0), "wv": (1, 2, 0), "wo": (0, 1),
    "wq_a": (1, 0), "wq_b": (1, 0), "wkv_a": (1, 0), "wkv_b": (1, 0),
    "up": (1, 0), "gate": (1, 0), "down": (0, 1),
    "router": (1,),
    "w_up": (0, 2), "w_gate": (0, 2), "w_down": (0, 1),
    "in_proj": (1, 0), "out_proj": (0, 1),
    "vision_proj": (1, 0),
    "cross_k": (), "cross_v": (),
}
_REPLICATED = {"scale", "bias", "conv_w", "conv_b", "A_log", "D", "dt_bias",
               "norm_scale", "q_norm", "kv_norm", "q_scale", "k_scale"}


def _key_name(k) -> Optional[str]:
    if isinstance(k, DictKey):
        return str(k.key)
    if isinstance(k, GetAttrKey):
        return str(k.name)
    return None


def _path_names(path) -> list:
    return [n for n in (_key_name(k) for k in path) if n is not None]


def _base_spec(name: str, shape: Tuple[int, ...], mesh: Mesh,
               fsdp: bool, serve_tp: bool = False) -> list:
    """Per-dim axis assignment for an unstacked param leaf."""
    spec = [None] * len(shape)
    msize = mesh.shape["model"]
    if name in _REPLICATED or not shape:
        return spec
    prefs = _MODEL_DIM_PREF.get(name, tuple(np.argsort(shape)[::-1]))
    model_dim = None
    for d in prefs:
        if d < len(shape) and shape[d] % msize == 0:
            model_dim = d
            break
    if model_dim is not None:
        spec[model_dim] = "model"
    if serve_tp and "data" in mesh.axis_names:
        # weight-stationary 2D TP (§Perf): widen the TP dim to
        # ("data","model") when jointly divisible, else put "data" on the
        # next preferred dim.  Weights never move; activations all-reduce.
        dsize = mesh.shape["data"]
        if model_dim is not None and shape[model_dim] % (msize * dsize) == 0:
            spec[model_dim] = ("data", "model")
        else:
            for d in list(prefs) + sorted(range(len(shape)),
                                          key=lambda d: -shape[d]):
                if d < len(shape) and d != model_dim and \
                        shape[d] % dsize == 0 and shape[d] >= dsize:
                    spec[d] = "data"
                    break
    elif fsdp and "data" in mesh.axis_names:
        dsize = mesh.shape["data"]
        # largest remaining divisible dim carries the FSDP shard
        order = sorted(range(len(shape)), key=lambda d: -shape[d])
        for d in order:
            if d != model_dim and shape[d] % dsize == 0 and shape[d] >= dsize:
                spec[d] = "data"
                break
    return spec


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh, *,
                client_stacked: bool = False, serve: bool = False) -> Any:
    """PartitionSpec pytree for (possibly client-stacked, possibly
    scan-stacked) params or mirrored optimizer-state trees."""
    serve_tp = serve and cfg.serve_tp and cfg.fl_client_axis == "pod"
    fsdp = cfg.fl_client_axis == "pod" and not serve_tp
    caxes = client_axes(mesh, cfg)
    # client-per-chip placement: the client dim consumes every axis, so
    # weight feature dims must stay replicated
    replicate_inner = client_stacked and "model" in caxes

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        prefix = []
        skip = 0
        if client_stacked:
            prefix.append(caxes if caxes else None)
            skip += 1
        if "scan_layers" in names:
            prefix.append(None)
            skip += 1
        base_shape = leaf.shape[skip:]
        if name == "step" or not hasattr(leaf, "shape") or leaf.ndim == 0:
            return P()
        inner = [None] * len(base_shape) if replicate_inner else \
            _base_spec(name, base_shape, mesh, fsdp, serve_tp)
        return P(*prefix, *inner)

    return jax.tree_util.tree_map_with_path(spec, params)


def batch_specs(batch: Any, cfg: ModelConfig, mesh: Mesh, *,
                client_dim: bool = False) -> Any:
    """Batch sharding: leading client dim over client axes; otherwise the
    batch dim over all data axes.  batch=1 leaves (long_500k) replicate."""
    caxes = client_axes(mesh, cfg)
    daxes = data_axes(mesh)

    def spec(leaf):
        dims = [None] * leaf.ndim
        if client_dim:
            if caxes and leaf.shape[0] % int(np.prod([mesh.shape[a] for a in caxes])) == 0:
                dims[0] = caxes
            # per-client batch dim: shard over remaining data axes (pod mode)
            rem = tuple(a for a in daxes if a not in caxes)
            if rem and leaf.ndim > 1 and \
                    leaf.shape[1] % int(np.prod([mesh.shape[a] for a in rem])) == 0:
                dims[1] = rem if len(rem) > 1 else rem[0]
        else:
            total = int(np.prod([mesh.shape[a] for a in daxes]))
            if leaf.shape[0] % total == 0 and leaf.shape[0] >= total:
                dims[0] = daxes if len(daxes) > 1 else daxes[0]
        return P(*dims)

    return jax.tree_util.tree_map(spec, batch)


def cache_specs(caches: Any, cfg: ModelConfig, mesh: Mesh, *,
                batch: int, seq_shard: bool = False) -> Any:
    """KV/SSM cache sharding for serving.

    Batch dim over data axes when divisible; otherwise (long_500k, batch=1)
    the sequence dim is sharded over data and heads/feature dims over model.

    seq_shard=True (the serve_tp layout for pod-placed giants, §Perf):
    batch stays replicated — the cache SEQUENCE dim is sharded over "data"
    so it coexists with weights jointly sharded over ("data","model");
    batch-sharding the cache there forces GSPMD to re-gather it every
    token (measured 278 GiB/token on nemotron).
    """
    daxes = data_axes(mesh)
    dtotal = int(np.prod([mesh.shape[a] for a in daxes]))
    msize = mesh.shape["model"]
    batch_shardable = (not seq_shard) and batch % dtotal == 0 \
        and batch >= dtotal
    d_for_batch = daxes if len(daxes) > 1 else daxes[0]

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        if leaf.ndim == 0:
            return P()
        # scan-stacked caches carry a leading (n_groups,) dim — replicated
        skip = 1 if "scan" in names else 0
        b_dim, s_dim = skip, skip + 1
        dims = [None] * leaf.ndim
        if batch_shardable and leaf.ndim > b_dim:
            dims[b_dim] = d_for_batch
        if name == "pos":                       # (B, C) int positions
            if not batch_shardable and leaf.ndim > s_dim and \
                    leaf.shape[s_dim] % dtotal == 0:
                dims[s_dim] = d_for_batch
            return P(*dims)
        # feature dims: prefer heads/feature over model, seq over data
        if name in ("k", "v", "cross_k", "cross_v", "conv", "state"):
            # find a trailing dim divisible by model size (heads, ranks, hd)
            for d in range(leaf.ndim - 1, s_dim, -1):
                if leaf.shape[d] % msize == 0 and leaf.shape[d] >= msize:
                    dims[d] = "model"
                    break
            if not batch_shardable and leaf.ndim > s_dim and name != "state" \
                    and leaf.shape[s_dim] % dtotal == 0 \
                    and leaf.shape[s_dim] >= dtotal:
                dims[s_dim] = d_for_batch     # shard the seq/window dim
        return P(*dims)

    return jax.tree_util.tree_map_with_path(spec, caches)


def to_shardings(specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
