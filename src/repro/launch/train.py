"""End-to-end federated LM training driver.

Runs the full paper pipeline on any assigned architecture at a reduced or
full scale: similarity pre-round -> Eq.6 mixing matrix -> k-means streams ->
federated rounds of (local step + user-centric aggregation), with eval on
per-client held-out data and checkpointing.  The same step builder drives
the production dry-run; here it executes on the host mesh.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --preset cpu-small --steps 20 --algorithm ucfl_k2 --clients 4

Presets: cpu-small (~5M params, CPU-friendly), lm-100m (~100M params — the
deliverable-scale run for real hardware), full (the assigned config).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_train_state
from repro.configs import get_config, reduced
from repro.core import kmeans, mixing_matrix
from repro.core.similarity import delta_matrix, flatten_pytree
from repro.data.synthetic import synthetic_lm_tokens
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (build_train_step, init_stacked_params,
                                make_optimizer, _loss_fn)


def preset_config(arch: str, preset: str):
    cfg = get_config(arch)
    if preset == "full":
        return cfg
    if preset == "lm-100m":
        # ~100M params in the same family
        cfg = reduced(cfg, n_layers=8, d_model=512, vocab=32000, max_seq=1024)
        return dataclasses.replace(cfg, n_layers=8, d_ff=2048)
    return reduced(cfg, n_layers=2, d_model=256, vocab=512, max_seq=256)


def make_client_data(key, m: int, batch: int, seq: int, vocab: int,
                     n_groups: int = 2):
    """Heterogeneous LM clients: one Markov rule per GROUP (concept shift),
    so user-centric mixing has real structure to find."""
    groups = np.arange(m) % n_groups
    keys = jax.random.split(key, n_groups)

    def sample(rnd_key, step):
        out = []
        for i in range(m):
            k = jax.random.fold_in(jax.random.fold_in(keys[groups[i]], step), i)
            out.append(synthetic_lm_tokens(k, batch, seq, vocab))
        return jnp.stack(out)          # (m, batch, seq)

    return sample, groups


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="stablelm-3b")
    p.add_argument("--preset", default="cpu-small",
                   choices=("cpu-small", "lm-100m", "full"))
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--clients", type=int, default=4)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--algorithm", default="ucfl_k2",
                   help="fedavg | local | ucfl | ucfl_k<k>")
    p.add_argument("--eval-every", type=int, default=5)
    p.add_argument("--checkpoint", default="")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = preset_config(args.arch, args.preset)
    m = args.clients
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(args.seed)
    k_init, k_data, k_round = jax.random.split(key, 3)

    print(f"arch={cfg.name} preset={args.preset} clients={m} "
          f"alg={args.algorithm}")
    params = init_stacked_params(k_init, cfg, m)
    n_params = sum(int(np.prod(l.shape)) for l in
                   jax.tree_util.tree_leaves(params)) // m
    print(f"params/model: {n_params/1e6:.1f}M")
    opt = make_optimizer(cfg)
    opt_state = opt.init(params)

    sample, groups = make_client_data(k_data, m, args.batch, args.seq,
                                      cfg.vocab_size)
    loss_fn = _loss_fn(cfg, remat=False)

    # ---- similarity pre-round (paper §III-A) -----------------------------
    if args.algorithm.startswith("ucfl"):
        probe = jax.tree_util.tree_map(lambda l: l[0], params)
        batch0 = sample(k_data, 0)

        def grad_i(b):
            g = jax.grad(lambda q: loss_fn(q, {"tokens": b})[0])(probe)
            return flatten_pytree(g)

        grads = jnp.stack([grad_i(batch0[i]) for i in range(m)])
        delta = delta_matrix(grads)
        sigma2 = jnp.full((m,), jnp.mean(delta) + 1e-6)
        n = jnp.full((m,), float(args.batch * args.seq))
        w_full = mixing_matrix(delta, sigma2, n)
        if args.algorithm == "ucfl":
            w, assignment = w_full, jnp.arange(m, dtype=jnp.int32)
        else:
            k = int(args.algorithm.split("_k")[1])
            plan = kmeans(w_full, k, key=k_round)
            w, assignment = plan.centroids, plan.assignment
        print("mixing matrix rows:\n", np.round(np.asarray(w_full), 3))
        print("stream assignment:", np.asarray(assignment),
              "(true groups:", groups, ")")
    elif args.algorithm == "fedavg":
        w = jnp.full((1, m), 1.0 / m)
        assignment = jnp.zeros((m,), jnp.int32)
    else:  # local
        w = jnp.eye(m)
        assignment = jnp.arange(m, dtype=jnp.int32)

    train_step = build_train_step(cfg, mesh, schedule="gspmd", remat=False)
    train_step = jax.jit(train_step, donate_argnums=(0, 1))

    eval_batches = sample(jax.random.fold_in(k_data, 999), 10_000)

    @jax.jit
    def eval_loss(params):
        return jax.vmap(lambda p, b: loss_fn(p, {"tokens": b})[0])(
            params, eval_batches)

    t0 = time.time()
    for step in range(args.steps):
        batch = {"tokens": sample(k_round, step)}
        params, opt_state, metrics = train_step(params, opt_state, batch, w,
                                                assignment)
        if step % args.eval_every == 0 or step == args.steps - 1:
            ev = eval_loss(params)
            print(f"step {step:4d} train={float(metrics['loss']):.4f} "
                  f"eval/client={np.round(np.asarray(ev), 3)} "
                  f"({time.time()-t0:.0f}s)")
    if args.checkpoint:
        save_train_state(args.checkpoint, args.steps, jax.device_get(params),
                         jax.device_get(opt_state),
                         extra={"arch": cfg.name, "algorithm": args.algorithm})
        print("checkpoint written:", args.checkpoint)
    return float(jnp.mean(eval_loss(params)))


if __name__ == "__main__":
    main()
