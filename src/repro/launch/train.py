"""Federated LM training CLI — a thin shell over the round engine.

One command drives the full paper pipeline on any assigned architecture:
the registry resolves ``--algorithm`` to a `Strategy` (similarity
pre-round, Eq. 6 mixing, k-means streams all live in `UCFL.setup`), and
`run_federated` executes the rounds under a `MeshShardMap` placement —
clients sharded over the device mesh, aggregation via the
``--schedule``-selected collectives.  Every registered strategy
(fedavg | local | oracle | ucfl | ucfl_k<k> | cfl | fedfomo), every
`ClientSampler`, the CommCost accounting and the analytic clock run here
exactly as in the host simulator: there is no mesh-specific round loop.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --preset cpu-small --steps 20 --algorithm ucfl_k2 --clients 4

``--async [--buffer-k K --max-staleness TAU --staleness-discount L]``
switches to the buffered-async runtime (DESIGN.md §3a): `--steps` then
counts aggregation EVENTS and the reported time is the event-driven
virtual clock, not the analytic per-round maximum.

Presets: cpu-small (~5M params, CPU-friendly), lm-100m (~100M params — the
deliverable-scale run for real hardware), full (the assigned config).
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_train_state
from repro.configs import get_config, reduced
from repro.data.federated import FederatedData
from repro.data.synthetic import synthetic_lm_tokens
from repro.fl import (AsyncConfig, Channel, FLConfig, HierarchyConfig,
                      HostVmap, MeshShardMap, PagingConfig, SYSTEMS,
                      UniformFraction, get_strategy, run_federated)
from repro.launch.steps import _loss_fn, init_model_params


def preset_config(arch: str, preset: str):
    cfg = get_config(arch)
    if preset == "full":
        return cfg
    if preset == "lm-100m":
        # ~100M params in the same family
        cfg = reduced(cfg, n_layers=8, d_model=512, vocab=32000, max_seq=1024)
        return dataclasses.replace(cfg, n_layers=8, d_ff=2048)
    return reduced(cfg, n_layers=2, d_model=256, vocab=512, max_seq=256)


@functools.lru_cache(maxsize=8)
def _lm_fns(arch: str, preset: str):
    """(cfg, loss_fn, acc_fn) memoized per (arch, preset): stable function
    identities let the engine's cached jitted update hit across repeated
    main() calls (sweeps, tests) instead of recompiling per call."""
    cfg = preset_config(arch, preset)
    lm_loss = _loss_fn(cfg, remat=False)
    loss_fn = lambda p_, b: lm_loss(p_, {"tokens": b["x"]})
    # evaluate() reports (mean, worst) of a higher-is-better score: use −CE
    acc_fn = lambda p_, b: -lm_loss(p_, {"tokens": b["x"]})[0]
    return cfg, loss_fn, acc_fn


def lm_federated_data(key, m: int, *, pool: int, n_val: int, seq: int,
                      vocab: int, n_groups: int = 2) -> FederatedData:
    """Heterogeneous LM clients as a stacked `FederatedData`: one Markov
    rule per GROUP (concept shift), so user-centric mixing has real
    structure to find.  Tokens ride in the ``x`` slot ((m, n, seq) int32);
    ``y`` is a dummy — the LM loss reads only ``batch["x"]``."""
    groups = np.arange(m) % n_groups
    gkeys = jax.random.split(key, n_groups)
    xs, xv = [], []
    for i in range(m):
        ki = jax.random.fold_in(gkeys[groups[i]], i)
        xs.append(synthetic_lm_tokens(ki, pool, seq, vocab))
        xv.append(synthetic_lm_tokens(jax.random.fold_in(ki, 999),
                                      n_val, seq, vocab))
    return FederatedData(
        x=jnp.stack(xs), y=jnp.zeros((m, pool), jnp.int32),
        n=jnp.full((m,), float(pool)),
        x_val=jnp.stack(xv), y_val=jnp.zeros((m, n_val), jnp.int32),
        group=jnp.asarray(groups, jnp.int32))


def _fleet_arg(spec: str):
    """``"3"`` -> 3; anything else passes through as a fleet spec string
    (``uniform:<D>`` | ``ragged:<min>-<max>``)."""
    try:
        return int(spec)
    except ValueError:
        return spec


def _validate_specs(p, args):
    """Registry-backed spec validation at parse time (DESIGN.md §3b/§3e/
    §3f): a typo dies as a one-line argparse error naming the registry's
    options instead of a traceback from the middle of engine init."""
    from repro.fl.channel import get_codec, get_link_profile
    from repro.fl.hierarchy import get_edge_aggregator, resolve_fleet_spec
    for flag, spec in (("--codec", args.codec),
                       ("--edge-codec", args.edge_codec)):
        if spec is not None:
            try:
                get_codec(spec)
            except ValueError as e:
                p.error(f"{flag}: {e}")
    for flag, spec in (("--link-profile", args.link_profile),
                       ("--edge-link", args.edge_link)):
        if spec is not None:
            try:
                get_link_profile(spec, SYSTEMS["wired"], 32, 2)
            except ValueError as e:
                p.error(f"{flag}: {e}")
    if args.cohort_schedule not in ("sweep", "random"):
        p.error(f"--cohort-schedule: unknown cohort schedule "
                f"{args.cohort_schedule!r}; options: ['sweep', 'random']")
    if args.edge_aggregator is not None:
        try:
            get_edge_aggregator(args.edge_aggregator)
        except ValueError as e:
            p.error(f"--edge-aggregator: {e}")
    if args.devices_per_user is not None:
        try:
            resolve_fleet_spec(_fleet_arg(args.devices_per_user), 2,
                               seed=args.seed)
        except (TypeError, ValueError) as e:
            p.error(f"--devices-per-user: {e}")
    from repro.fl.faults import get_robust_aggregator, parse_fault_spec
    if args.faults is not None:
        try:
            parse_fault_spec(args.faults)
        except ValueError as e:
            p.error(f"--faults: {e}")
    if args.robust_agg is not None:
        try:
            get_robust_aggregator(args.robust_agg)
        except ValueError as e:
            p.error(f"--robust-agg: {e}")
    if args.min_quorum is not None and args.min_quorum < 1:
        p.error(f"--min-quorum: must be >= 1, got {args.min_quorum}")
    if args.max_retries < 0:
        p.error(f"--max-retries: must be >= 0, got {args.max_retries}")
    if args.retry_backoff <= 0:
        p.error(f"--retry-backoff: must be > 0, got {args.retry_backoff}")


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="stablelm-3b")
    p.add_argument("--preset", default="cpu-small",
                   choices=("cpu-small", "lm-100m", "full"))
    p.add_argument("--steps", type=int, default=20,
                   help="federated rounds")
    p.add_argument("--local-steps", type=int, default=1,
                   help="client SGD steps per round")
    p.add_argument("--clients", type=int, default=4)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--pool", type=int, default=32,
                   help="sequences per client dataset")
    p.add_argument("--algorithm", default="ucfl_k2",
                   help="any registry spec: fedavg | local | oracle | ucfl "
                        "| ucfl_k<k> | cfl | fedfomo")
    p.add_argument("--placement", default="mesh", choices=("mesh", "host"))
    p.add_argument("--schedule", default="gspmd",
                   choices=("gspmd", "shard_map_streams",
                            "shard_map_unicast"))
    p.add_argument("--participation", type=float, default=1.0,
                   help="per-round client fraction (UniformFraction)")
    p.add_argument("--async", dest="run_async", action="store_true",
                   help="buffered-async runtime (DESIGN.md §3a): event-"
                        "driven virtual clock instead of sync rounds")
    p.add_argument("--buffer-k", type=int, default=2,
                   help="async: aggregate once this many uploads buffer")
    p.add_argument("--max-staleness", type=float, default=None,
                   help="async: drop updates older than this many server "
                        "versions (default: keep all)")
    p.add_argument("--staleness-discount", type=float, default=0.9,
                   help="async: λ of the exp-schedule λ**age discount")
    p.add_argument("--staleness-schedule", default="exp",
                   choices=("exp", "poly"),
                   help="async: contributor discount law — FedBuff-style "
                        "exp (λ**age) or FedAsync poly ((1+age)**-α)")
    p.add_argument("--staleness-alpha", type=float, default=0.5,
                   help="async: α of the poly staleness schedule")
    p.add_argument("--codec", default=None,
                   help="uplink channel codec (DESIGN.md §3b): identity | "
                        "qsgd:<bits> | topk:<frac>; enables bit-level "
                        "payload accounting")
    p.add_argument("--link-profile", default=None,
                   help="per-client link rates: uniform | tiered:<factor> "
                        "| lognormal:<sigma> (implies a channel)")
    p.add_argument("--error-feedback", dest="error_feedback",
                   action="store_true", default=True,
                   help="carry per-client codec residuals (default on)")
    p.add_argument("--no-error-feedback", dest="error_feedback",
                   action="store_false")
    p.add_argument("--system", default="wired", choices=tuple(SYSTEMS),
                   help="analytic clock (paper §IV-C); in --async mode "
                        "also the virtual clock's arrival law")
    p.add_argument("--eval-every", type=int, default=5)
    p.add_argument("--checkpoint", default="")
    p.add_argument("--cohort", type=int, default=None,
                   help="cohort paging (DESIGN.md §3e): keep only this many "
                        "of --clients device-resident per superstep, the "
                        "rest in the host-backed store")
    p.add_argument("--cohort-schedule", default="sweep",
                   help="paging: which cohort each superstep trains "
                        "(sweep | random; registry-validated at parse)")
    p.add_argument("--store-dir", default=None,
                   help="paging: disk-back the client-state store (.npy "
                        "memmaps) instead of host RAM")
    p.add_argument("--checkpoint-dir", default=None,
                   help="paging: write superstep-boundary snapshots here "
                        "(store rows + engine carry + history)")
    p.add_argument("--checkpoint-every", type=int, default=1,
                   help="paging: snapshot cadence in supersteps")
    p.add_argument("--resume", action="store_true",
                   help="paging: resume from the latest snapshot in "
                        "--checkpoint-dir")
    p.add_argument("--devices-per-user", default=None,
                   help="hierarchy tier (DESIGN.md §3f): per-user device "
                        "fleet spec — an int, uniform:<D>, or "
                        "ragged:<min>-<max>; enables the edge sub-round")
    p.add_argument("--edge-codec", default="identity",
                   help="hierarchy: device->user uplink codec (same "
                        "registry as --codec)")
    p.add_argument("--edge-link", default=None,
                   help="hierarchy: per-device link profile (same "
                        "families as --link-profile)")
    p.add_argument("--edge-aggregator", default="mean",
                   help="hierarchy: edge aggregation rule — mean | "
                        "drop_stragglers:<frac>")
    p.add_argument("--edge-latency", type=float, default=0.0,
                   help="hierarchy: fixed per-sub-round edge latency "
                        "charged to every user's clock")
    p.add_argument("--device-dropout", type=float, default=0.0,
                   help="hierarchy: per-round probability each device "
                        "misses its edge sub-round")
    p.add_argument("--faults", default=None,
                   help="fault injection (DESIGN.md §3g): comma-joined "
                        "crash:<p> | nan:<p> | byz:<frac>[:<mode>[:<scale>]]"
                        " | bitrot:<p>[:<density>] | seed:<int>")
    p.add_argument("--robust-agg", dest="robust_agg", default=None,
                   help="defense (DESIGN.md §3g): none | clip:<c> | "
                        "trimmed_mean:<f> | median | krum:<f>; screens "
                        "non-finite uploads and quarantines outliers")
    p.add_argument("--min-quorum", type=int, default=None,
                   help="skip aggregation on rounds with fewer than this "
                        "many participating clients (server state carries "
                        "forward; uploads are wasted)")
    p.add_argument("--max-retries", type=int, default=3,
                   help="async+crash faults: consecutive crashes before a "
                        "client is dead for the run")
    p.add_argument("--retry-backoff", type=float, default=1.0,
                   help="async+crash faults: base of the backoff*2**attempt"
                        " reschedule delay")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    if args.steps < 1:
        p.error("--steps must be >= 1")
    _validate_specs(p, args)

    # registry-validated spec: bad specs raise ValueError before any work
    strategy = get_strategy(args.algorithm)
    cfg, loss_fn, acc_fn = _lm_fns(args.arch, args.preset)
    m = args.clients

    fed = lm_federated_data(jax.random.fold_in(jax.random.PRNGKey(args.seed),
                                               1),
                            m, pool=args.pool, n_val=max(4, args.batch),
                            seq=args.seq, vocab=cfg.vocab_size)

    placement = (MeshShardMap(schedule=args.schedule)
                 if args.placement == "mesh" else HostVmap())
    # paper optimizer (SGD η=.1 β=.9); giants drop momentum to fit HBM and
    # keep state in the param dtype (same policy as steps.make_optimizer)
    pod = cfg.fl_client_axis == "pod"
    fl = FLConfig(rounds=args.steps, local_steps=args.local_steps,
                  batch_size=args.batch, eval_every=args.eval_every,
                  momentum=0.0 if pod else 0.9,
                  opt_state_dtype=None if pod else "param")
    async_cfg = None
    if args.run_async:
        if args.participation < 1.0:
            p.error("--participation is a sync-only knob: the async "
                    "arrival buffer is the per-event cohort")
        async_cfg = AsyncConfig(buffer_k=args.buffer_k,
                                max_staleness=args.max_staleness,
                                staleness_schedule=args.staleness_schedule,
                                staleness_discount=args.staleness_discount,
                                staleness_alpha=args.staleness_alpha,
                                max_retries=args.max_retries,
                                retry_backoff=args.retry_backoff)
    sampler = (UniformFraction(args.participation)
               if args.participation < 1.0 else None)
    paging = None
    if args.cohort is not None:
        if args.cohort > m:
            p.error(f"--cohort {args.cohort} > --clients {m}")
        paging = PagingConfig(cohort=args.cohort,
                              schedule=args.cohort_schedule,
                              schedule_seed=args.seed,
                              store_dir=args.store_dir,
                              checkpoint_dir=args.checkpoint_dir,
                              checkpoint_every=args.checkpoint_every,
                              resume=args.resume)
    channel = None
    if args.codec is not None or args.link_profile is not None:
        channel = Channel(codec=args.codec or "identity",
                          link=args.link_profile,
                          error_feedback=args.error_feedback)
    hierarchy = None
    if args.devices_per_user is not None:
        hierarchy = HierarchyConfig(
            devices_per_user=_fleet_arg(args.devices_per_user),
            edge_codec=args.edge_codec,
            edge_aggregator=args.edge_aggregator,
            edge_link=args.edge_link,
            edge_latency=args.edge_latency,
            device_dropout=args.device_dropout,
            seed=args.seed)

    print(f"arch={cfg.name} preset={args.preset} clients={m} "
          f"alg={strategy.spec} placement={placement!r}"
          + (f" async={async_cfg}" if async_cfg else "")
          + (f" paging={paging}" if paging else "")
          + (f" channel={channel}" if channel else "")
          + (f" hierarchy={hierarchy}" if hierarchy else "")
          + (f" faults={args.faults}" if args.faults else "")
          + (f" robust_agg={args.robust_agg}" if args.robust_agg else "")
          + (f" min_quorum={args.min_quorum}" if args.min_quorum else ""))
    t0 = time.time()
    history = run_federated(
        strategy=strategy, fed=fed, fl=fl, sampler=sampler,
        model_init=lambda k: init_model_params(k, cfg),
        loss_fn=loss_fn, acc_fn=acc_fn, system=SYSTEMS[args.system],
        placement=placement, channel=channel,
        keep_state=bool(args.checkpoint),
        async_cfg=async_cfg, paging=paging, hierarchy=hierarchy,
        faults=args.faults, robust_agg=args.robust_agg,
        min_quorum=args.min_quorum, seed=args.seed)
    if paging is not None:
        pg = history.extra["paging"]
        print(f"paging: population={pg['population']} cohort={pg['cohort']} "
              f"schedule={pg['schedule']} "
              f"store={pg['store_bytes']/2**20:.1f} MiB"
              + (f" (resumed at superstep {pg['resumed_at']})"
                 if pg["resumed_at"] else ""))

    n_params = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(
        jax.eval_shape(lambda k: init_model_params(k, cfg),
                       jax.random.PRNGKey(0))))
    print(f"params/model: {n_params/1e6:.1f}M")
    if "mixing_matrix" in history.extra:
        print("mixing matrix rows:\n",
              np.round(np.asarray(history.extra["mixing_matrix"]), 3))
        print("(true groups:", np.asarray(fed.group), ")")
    for rnd, mean_s, worst_s, t in zip(history.rounds, history.mean_acc,
                                       history.worst_acc, history.time):
        print(f"round {rnd:4d} loss/mean={-mean_s:.4f} "
              f"loss/worst={-worst_s:.4f} t_sys={t:.1f} "
              f"({time.time()-t0:.0f}s)")
    streams = sum(c.n_streams for c in history.comm)
    unicasts = sum(c.n_unicasts for c in history.comm)
    print(f"downlink total: {streams} streams, {unicasts} unicasts "
          f"({args.system})")
    if channel is not None:
        ch = history.extra["channel"]
        print(f"channel: codec={ch['codec']} link={ch['link']} "
              f"payload={ch['payload_bits']/1e6:.2f} Mbit "
              f"(model {ch['model_bits']/1e6:.2f} Mbit) | "
              f"downlink {ch['dl_bits_total']/1e6:.1f} Mbit, "
              f"uplink {ch['ul_bits_total']/1e6:.1f} Mbit")
    if hierarchy is not None:
        hx = history.extra["hierarchy"]
        print(f"hierarchy: fleets={hx['devices_per_user']} "
              f"edge_codec={hx['edge_codec']} "
              f"agg={hx['edge_aggregator']} link={hx['edge_link']} | "
              f"edge downlink {hx['edge_dl_bits_total']/1e6:.1f} Mbit, "
              f"edge uplink {hx['edge_ul_bits_total']/1e6:.1f} Mbit")
    if "faults" in history.extra:
        fx = history.extra["faults"]
        print(f"faults: spec={fx['faults']} robust_agg={fx['robust_agg']} "
              f"byzantine={fx['byzantine_clients']} "
              f"min_quorum={fx['min_quorum']} | "
              f"crashed {fx['crashed_total']}, "
              f"quarantined {fx['quarantined_total']}, "
              f"skipped rounds {fx['skipped_rounds']}, "
              f"retries {fx['retries']}, dead {fx['dead_clients']}, "
              f"wasted uplink {fx['wasted_ul_bits']/1e6:.2f} Mbit")

    if args.checkpoint:
        save_train_state(args.checkpoint, args.steps,
                         jax.device_get(history.final_params),
                         jax.device_get(history.final_opt_state),
                         extra={"arch": cfg.name, "algorithm": strategy.spec})
        print("checkpoint written:", args.checkpoint)
    return -history.mean_acc[-1]


if __name__ == "__main__":
    main()
