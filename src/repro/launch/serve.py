"""Serving driver: prefill + batched decode through the production step
builders, on the host mesh at reduced scale (the dry-run lowers the same
functions at mesh scale).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import scan as scan_mod
from repro.models import transformer as T
from repro.launch.steps import init_model_params, _use_scan


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="mamba2-780m")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--tokens", type=int, default=16)
    p.add_argument("--cache-len", type=int, default=128)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_model_params(key, cfg)
    use_scan = _use_scan(cfg)
    B = args.batch

    batch = {"tokens": jax.random.randint(key, (B, args.prompt_len), 0,
                                          cfg.vocab_size)}
    if cfg.family == "audio":
        batch["audio_embeds"] = jax.random.normal(
            key, (B, cfg.encoder.n_ctx, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.vision.n_tokens, cfg.vision.embed_dim))
    prefix = cfg.vision.n_tokens if cfg.family == "vlm" else 0

    caches = T.make_caches(cfg, B, args.cache_len, jnp.float32)
    if use_scan:
        caches = scan_mod.stack_caches(caches, cfg)
        prefill = jax.jit(lambda p, b, c: scan_mod.prefill(p, cfg, b, c))
        decode = jax.jit(lambda p, t, c, pos: scan_mod.decode_step(
            p, cfg, t, c, pos))
    else:
        prefill = jax.jit(lambda p, b, c: T.prefill(p, cfg, b, c))
        decode = jax.jit(lambda p, t, c, pos: T.decode_step(p, cfg, t, c, pos))

    t0 = time.time()
    logits, caches = prefill(params, batch, caches)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    print(f"prefill {args.prompt_len} tokens x{B}: {time.time()-t0:.2f}s")

    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        pos = jnp.full((B,), prefix + args.prompt_len + i, jnp.int32)
        logits, caches = decode(params, tok, caches, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.time() - t0
    toks = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"decoded {args.tokens-1} steps x{B} in {dt:.2f}s "
          f"({(args.tokens-1)*B/max(dt,1e-9):.1f} tok/s)")
    print("sample:", toks[0][:16])
    return toks


if __name__ == "__main__":
    main()
