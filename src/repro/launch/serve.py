"""Serving driver: prefill + batched decode through the production step
builders, on the host mesh at reduced scale (the dry-run lowers the same
functions at mesh scale).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --tokens 16

``--federated`` flips the driver into the personalized serving plane
(DESIGN.md §3d): train a small federated LM population with
`run_federated(keep_state=True)` (or load a checkpointed `DeltaStore`),
ingest the per-user personalized params into a codec-compressed
`DeltaStore`, and serve per-user greedy decode — each user's prompt runs
through THEIR OWN reconstructed params via the `ServeEngine` micro-batcher
(one gather+decode and one vmapped prefill/decode_step per batch), with
the §3d parity anchor checked on every flush.

    PYTHONPATH=src python -m repro.launch.serve --federated \
        --rounds 4 --clients 4 --codec qsgd:4 --save-store /tmp/store.msgpack
    PYTHONPATH=src python -m repro.launch.serve --federated \
        --store /tmp/store.msgpack --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import scan as scan_mod
from repro.models import transformer as T
from repro.launch.steps import init_model_params, _use_scan


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="mamba2-780m")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--tokens", type=int, default=16)
    p.add_argument("--cache-len", type=int, default=128)
    p.add_argument("--seed", type=int, default=0)
    # ---- personalized serving plane (DESIGN.md §3d) ----
    p.add_argument("--federated", action="store_true",
                   help="serve per-user personalized models from a "
                        "DeltaStore (train first, or --store to load)")
    p.add_argument("--preset", default="cpu-small",
                   choices=("cpu-small", "lm-100m", "full"),
                   help="federated: LM preset (launch.train grammar)")
    p.add_argument("--algorithm", default="ucfl_k2",
                   help="federated: strategy registry spec")
    p.add_argument("--rounds", type=int, default=4)
    p.add_argument("--local-steps", type=int, default=1)
    p.add_argument("--clients", type=int, default=4)
    p.add_argument("--pool", type=int, default=16,
                   help="federated: sequences per client dataset")
    p.add_argument("--codec", default="identity",
                   help="federated: at-rest delta codec — identity | "
                        "qsgd:<bits> | topk:<frac>")
    p.add_argument("--placement", default="host", choices=("host", "mesh"),
                   help="federated: where batches decode and land")
    p.add_argument("--store", default="",
                   help="federated: load a checkpointed DeltaStore instead "
                        "of training")
    p.add_argument("--save-store", default="",
                   help="federated: checkpoint the built DeltaStore here")
    p.add_argument("--requests", type=int, default=8,
                   help="federated: number of decode requests to serve")
    p.add_argument("--max-batch", type=int, default=4,
                   help="federated: micro-batcher chunk size")
    args = p.parse_args(argv)
    if args.federated:
        return federated_main(args)
    return smoke_main(args)


def smoke_main(args):
    """Single un-personalized smoke model through prefill/decode_step."""
    cfg = get_smoke_config(args.arch)
    # independent streams per use: params init, prompt tokens and the
    # audio/vision embeds each get their own subkey
    kparams, ktok, kembed = jax.random.split(jax.random.PRNGKey(args.seed), 3)
    params = init_model_params(kparams, cfg)
    use_scan = _use_scan(cfg)
    B = args.batch

    batch = {"tokens": jax.random.randint(ktok, (B, args.prompt_len), 0,
                                          cfg.vocab_size)}
    if cfg.family == "audio":
        batch["audio_embeds"] = jax.random.normal(
            kembed, (B, cfg.encoder.n_ctx, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            kembed, (B, cfg.vision.n_tokens, cfg.vision.embed_dim))
    prefix = cfg.vision.n_tokens if cfg.family == "vlm" else 0

    caches = T.make_caches(cfg, B, args.cache_len, jnp.float32)
    if use_scan:
        caches = scan_mod.stack_caches(caches, cfg)
        prefill = jax.jit(lambda p, b, c: scan_mod.prefill(p, cfg, b, c))
        decode = jax.jit(lambda p, t, c, pos: scan_mod.decode_step(
            p, cfg, t, c, pos))
    else:
        prefill = jax.jit(lambda p, b, c: T.prefill(p, cfg, b, c))
        decode = jax.jit(lambda p, t, c, pos: T.decode_step(p, cfg, t, c, pos))

    t0 = time.time()
    logits, caches = prefill(params, batch, caches)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    print(f"prefill {args.prompt_len} tokens x{B}: {time.time()-t0:.2f}s")

    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        pos = jnp.full((B,), prefix + args.prompt_len + i, jnp.int32)
        logits, caches = decode(params, tok, caches, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.time() - t0
    toks = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"decoded {args.tokens-1} steps x{B} in {dt:.2f}s "
          f"({(args.tokens-1)*B/max(dt,1e-9):.1f} tok/s)")
    print("sample:", toks[0][:16])
    return toks


def build_decode_one(cfg, prompt_len: int, n_tokens: int, cache_len: int):
    """Per-user greedy decode, ONE user's params x ONE prompt -> token ids.

    The same `prefill`/`decode_step` the smoke path and the launch.steps
    case builders wrap — the ServeEngine vmaps it over the request batch,
    so a chunk of B users runs as one batched prefill + n_tokens batched
    decode steps through each user's own reconstructed params."""
    use_scan = _use_scan(cfg)

    def decode_one(params, tokens):
        batch = {"tokens": tokens[None]}
        caches = T.make_caches(cfg, 1, cache_len, jnp.float32)
        if use_scan:
            caches = scan_mod.stack_caches(caches, cfg)
            logits, caches = scan_mod.prefill(params, cfg, batch, caches)
        else:
            logits, caches = T.prefill(params, cfg, batch, caches)
        step = scan_mod.decode_step if use_scan else T.decode_step
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out = [tok]
        for i in range(n_tokens - 1):
            pos = jnp.full((1,), prompt_len + i, jnp.int32)
            logits, caches = step(params, cfg, tok[:, None], caches, pos)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            out.append(tok)
        return jnp.concatenate(out)

    return decode_one


def federated_main(args):
    """Train-then-serve (or load a store) — the §3d serving plane."""
    from repro.fl import (FLConfig, HostVmap, MeshShardMap, run_federated)
    from repro.fl.serve import DeltaStore, ServeEngine, check_parity
    from repro.launch.train import _lm_fns, lm_federated_data

    cfg, loss_fn, acc_fn = _lm_fns(args.arch, args.preset)
    placement = (MeshShardMap(schedule="shard_map_streams")
                 if args.placement == "mesh" else HostVmap())
    backend = placement.codec_backend

    if args.store:
        store = DeltaStore.load(args.store)
        print(f"loaded store {args.store}: {store.summary()}")
    else:
        m = args.clients
        fed = lm_federated_data(
            jax.random.fold_in(jax.random.PRNGKey(args.seed), 1), m,
            pool=args.pool, n_val=4, seq=args.prompt_len,
            vocab=cfg.vocab_size)
        fl = FLConfig(rounds=args.rounds, local_steps=args.local_steps,
                      batch_size=4, eval_every=max(1, args.rounds // 2))
        t0 = time.time()
        h = run_federated(args.algorithm, fed, fl=fl, placement=placement,
                          model_init=lambda k: init_model_params(k, cfg),
                          loss_fn=loss_fn, acc_fn=acc_fn,
                          keep_state=True, seed=args.seed)
        print(f"trained {args.algorithm} m={m} rounds={args.rounds} "
              f"final -CE={h.mean_acc[-1]:.4f} ({time.time()-t0:.0f}s)")
        store = DeltaStore.from_history(h, codec=args.codec, backend=backend)
        print(f"store[{args.codec}]: {store.summary()}")
    if args.save_store:
        store.save(args.save_store)
        print("store written:", args.save_store)

    decode_one = build_decode_one(cfg, args.prompt_len, args.tokens,
                                  max(args.cache_len, args.prompt_len
                                      + args.tokens))
    engine = ServeEngine(store, decode_one, placement=placement,
                         max_batch=args.max_batch)

    # per-user prompts on independent streams (the RNG-hygiene rule the
    # smoke path follows: one fold per user)
    kreq = jax.random.fold_in(jax.random.PRNGKey(args.seed), 2)
    users = [int(u) for u in np.arange(args.requests) % store.m]
    prompts = {
        u: jax.random.randint(jax.random.fold_in(kreq, u),
                              (args.prompt_len,), 0, cfg.vocab_size,
                              dtype=jnp.int32)
        for u in set(users)}
    tickets = [engine.submit(u, prompts[u]) for u in users]
    t0 = time.time()
    outs = engine.flush()
    dt = time.time() - t0
    del tickets
    # §3d parity anchor on the served batch: gather-then-decode output ==
    # direct forward through the reference reconstruction, bit-identical
    probe = sorted(set(users))[:args.max_batch]
    check_parity(engine, probe, np.stack([prompts[u] for u in probe]))
    stats = engine.last_stats
    lat = stats["latency_s"]
    print(f"served {stats['requests']} requests in {stats['batches']} "
          f"batches, {dt:.2f}s ({stats['requests']/max(dt, 1e-9):.1f} "
          f"req/s), per-batch p50={np.percentile(lat, 50)*1e3:.0f}ms "
          f"max={max(lat)*1e3:.0f}ms — parity anchor OK")
    for u, o in list(zip(users, outs))[:4]:
        print(f"user {u}: {np.asarray(o)[:12]}")
    return outs


if __name__ == "__main__":
    main()
