"""Step builders: FL-integrated train_step and serve prefill/decode steps.

train_step is one federated round at mesh scale (DESIGN.md §2):
  1. every client (leading dim of the stacked params, sharded over the
     client axes) takes one local SGD step on its batch shard;
  2. the user-centric aggregation mixes client models across the client
     axis — `w` is (k, m) (k=1 FedAvg, k=m unicast UCFL, 1<k<m streams)
     and `assignment` maps clients to streams.

The mixing `schedule` selects the collective implementation:
  gspmd               einsum, XLA chooses collectives (baseline)
  shard_map_streams   explicit psum of k weighted copies (§Perf)
  shard_map_unicast   explicit all-gather + local mix     (§Perf)

serve steps are standard single-model (stream-0) prefill / decode.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.distributed import mix_schedule
from repro.launch.mesh import client_axes, data_axes, n_clients
from repro.launch.sharding import (batch_specs, cache_specs, param_specs,
                                   to_shardings)
from repro.models import scan as scan_mod
from repro.models import transformer as T
from repro.optim import apply_updates, sgd


# ---------------------------------------------------------------------------
# shapes


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode
    long_context: bool = False


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode", long_context=True),
}


def _use_scan(cfg: ModelConfig) -> bool:
    return cfg.family != "audio"


def _loss_fn(cfg: ModelConfig, *, remat: bool) -> Callable:
    if _use_scan(cfg):
        return lambda p, b: scan_mod.loss_fn(p, cfg, b, remat=remat)
    return lambda p, b: T.loss_fn(p, cfg, b)


def init_model_params(key, cfg: ModelConfig):
    """Single-model params, scan-stacked when applicable."""
    params = T.init_params(key, cfg)
    if _use_scan(cfg):
        params = scan_mod.stack_layer_params(params, cfg)
    return params


def init_stacked_params(key, cfg: ModelConfig, m: int):
    """Client-stacked params: every leaf gains a leading (m,) dim."""
    params = init_model_params(key, cfg)
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (m,) + l.shape), params)


def init_stacked_params_loop(key, cfg: ModelConfig, m: int):
    """As init_stacked_params but without scan-stacking (loop path)."""
    params = T.init_params(key, cfg)
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (m,) + l.shape), params)


# ---------------------------------------------------------------------------
# batches


def train_batch_struct(cfg: ModelConfig, shape: InputShape, m: int,
                       tok_dtype=jnp.int32) -> Dict[str, jax.ShapeDtypeStruct]:
    b = shape.global_batch // m
    s = shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch = {}
    if cfg.family == "vlm":
        nv = cfg.vision.n_tokens
        batch["vision_embeds"] = sds((m, b, nv, cfg.vision.embed_dim),
                                     cfg.cdtype)
        batch["tokens"] = sds((m, b, s - nv), tok_dtype)
    elif cfg.family == "audio":
        batch["audio_embeds"] = sds((m, b, cfg.encoder.n_ctx, cfg.d_model),
                                    cfg.cdtype)
        batch["tokens"] = sds((m, b, s), tok_dtype)
    else:
        batch["tokens"] = sds((m, b, s), tok_dtype)
    return batch


def serve_batch_struct(cfg: ModelConfig, shape: InputShape
                       ) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch = {}
    if cfg.family == "vlm":
        nv = cfg.vision.n_tokens
        batch["vision_embeds"] = sds((b, nv, cfg.vision.embed_dim), cfg.cdtype)
        batch["tokens"] = sds((b, s - nv), jnp.int32)
    elif cfg.family == "audio":
        batch["audio_embeds"] = sds((b, cfg.encoder.n_ctx, cfg.d_model),
                                    cfg.cdtype)
        batch["tokens"] = sds((b, s), jnp.int32)
    else:
        batch["tokens"] = sds((b, s), jnp.int32)
    return batch


def sample_batch(key, struct: Dict[str, jax.ShapeDtypeStruct], vocab: int):
    """Materialize a random batch matching a struct (examples/tests)."""
    out = {}
    for k, s in struct.items():
        if k == "tokens":
            out[k] = jax.random.randint(key, s.shape, 0, vocab, s.dtype)
        else:
            out[k] = jax.random.normal(key, s.shape, s.dtype)
    return out


# ---------------------------------------------------------------------------
# train step


@dataclass
class TrainCase:
    fn: Callable
    args: Tuple[Any, ...]          # ShapeDtypeStructs
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    meta: Dict[str, Any]


def make_optimizer(cfg: ModelConfig):
    """Paper optimizer (SGD η=.1 β=.9); giants drop momentum to fit HBM
    (DESIGN.md §4) and keep state in the param dtype."""
    if cfg.fl_client_axis == "pod":
        return sgd(0.1, momentum=0.0)
    return sgd(0.1, momentum=0.9, state_dtype="param")


def build_train_step(cfg: ModelConfig, mesh: Mesh, *, n_streams: int = 0,
                     schedule: str = "gspmd", remat: bool = True,
                     mix_every: int = 1, loop: bool = False,
                     microbatch: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch, w, assignment).
    loop=True uses the unscanned per-layer python loop (dry-run cost
    extrapolation; numerically identical).
    microbatch>1 accumulates gradients over that many slices of the
    per-client batch dim (fp32 accumulator) — the activation-memory knob
    for the giant archs whose train_4k temps overshoot HBM."""
    m = n_clients(mesh, cfg)
    caxes = client_axes(mesh, cfg)
    opt = make_optimizer(cfg)
    loss_fn = (lambda p, b: T.loss_fn(p, cfg, b)) if loop else \
        _loss_fn(cfg, remat=remat)

    def total_loss(stacked, batch):
        losses, metrics = jax.vmap(lambda p, b: loss_fn(p, b))(stacked, batch)
        return jnp.sum(losses), metrics

    def grads_of(params, batch):
        if microbatch == 1:
            return jax.value_and_grad(total_loss, has_aux=True)(params, batch)
        # (m, b, ...) -> (micro, m, b/micro, ...) without data movement
        def split(l):
            mm, b = l.shape[:2]
            return l.reshape((mm, microbatch, b // microbatch) + l.shape[2:]
                             ).swapaxes(0, 1)
        mb = jax.tree_util.tree_map(split, batch)

        def body(carry, batch_i):
            g_acc, loss_acc = carry
            (loss, metrics), g = jax.value_and_grad(
                total_loss, has_aux=True)(params, batch_i)
            g_acc = jax.tree_util.tree_map(
                lambda a, x: a + x.astype(jnp.float32), g_acc, g)
            return (g_acc, loss_acc + loss), metrics

        g0 = jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, jnp.float32), params)
        (g, loss), metrics = jax.lax.scan(body, (g0, 0.0), mb)
        # per-client loss is a batch mean: average the slice means
        g = jax.tree_util.tree_map(
            lambda x, p: (x / microbatch).astype(p.dtype), g, params)
        return (loss / microbatch,
                jax.tree_util.tree_map(lambda x: jnp.mean(x), metrics)), g

    def mix(params, w, assignment):
        if schedule == "gspmd" or not caxes:
            # square w already has one row per client — skip the take
            assignment = None if w.shape[0] == w.shape[1] else assignment
        return mix_schedule(mesh, caxes, params, w, assignment,
                            schedule=schedule)

    def train_step(params, opt_state, batch, w, assignment):
        (loss, metrics), grads = grads_of(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        params = mix(params, w, assignment)
        return params, opt_state, {"loss": loss / m,
                                   "ce": jnp.mean(metrics["ce"])}

    return train_step


def build_train_case(cfg: ModelConfig, mesh: Mesh, shape: InputShape, *,
                     n_streams: int = 4, schedule: str = "gspmd",
                     remat: bool = True, loop: bool = False,
                     microbatch: int = 1) -> TrainCase:
    """Everything dryrun.py needs to lower a train_4k-style case."""
    m = n_clients(mesh, cfg)
    k = max(1, min(n_streams, m))
    opt = make_optimizer(cfg)

    init = init_stacked_params_loop if loop else init_stacked_params
    params_sds = jax.eval_shape(
        functools.partial(init, cfg=cfg, m=m), jax.random.PRNGKey(0))
    opt_sds = jax.eval_shape(opt.init, params_sds)
    batch_sds = train_batch_struct(cfg, shape, m)
    w_sds = jax.ShapeDtypeStruct((k, m), jnp.float32)
    assign_sds = jax.ShapeDtypeStruct((m,), jnp.int32)

    pspec = param_specs(params_sds, cfg, mesh, client_stacked=True)
    ospec = param_specs(opt_sds, cfg, mesh, client_stacked=True)
    bspec = batch_specs(batch_sds, cfg, mesh, client_dim=True)

    fn = build_train_step(cfg, mesh, n_streams=k, schedule=schedule,
                          remat=remat, loop=loop, microbatch=microbatch)
    in_specs = (pspec, ospec, bspec, P(), P())
    out_specs = (pspec, ospec, None)
    return TrainCase(
        fn=fn,
        args=(params_sds, opt_sds, batch_sds, w_sds, assign_sds),
        in_shardings=to_shardings(in_specs, mesh),
        out_shardings=to_shardings(out_specs, mesh),
        donate_argnums=(0, 1),
        meta={"m_clients": m, "n_streams": k, "schedule": schedule,
              "microbatch": microbatch},
    )


# ---------------------------------------------------------------------------
# serve steps


def _cache_len(cfg: ModelConfig, shape: InputShape) -> int:
    return shape.seq_len


def build_prefill_case(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                       *, loop: bool = False) -> TrainCase:
    """Prefill: (params, batch) -> (last_logits, caches)."""
    long_ctx = shape.long_context
    use_scan = _use_scan(cfg) and not loop

    def prefill_fn(params, batch):
        bsz = batch["tokens"].shape[0]
        caches = T.make_caches(cfg, bsz, _cache_len(cfg, shape), cfg.cdtype,
                               long_context=long_ctx)
        if use_scan:
            caches = scan_mod.stack_caches(caches, cfg)
            return scan_mod.prefill(params, cfg, batch, caches,
                                    long_context=long_ctx)
        return T.prefill(params, cfg, batch, caches, long_context=long_ctx)

    init = T.init_params if loop else \
        functools.partial(init_model_params, cfg=cfg)
    params_sds = jax.eval_shape(
        (lambda k: T.init_params(k, cfg)) if loop else init,
        jax.random.PRNGKey(0))
    batch_sds = serve_batch_struct(cfg, shape)
    serve_tp = cfg.serve_tp and cfg.fl_client_axis == "pod"
    pspec = param_specs(params_sds, cfg, mesh, client_stacked=False,
                        serve=True)
    bspec = batch_specs(batch_sds, cfg, mesh, client_dim=False)
    out_caches_sds = jax.eval_shape(prefill_fn, params_sds, batch_sds)[1]
    cspec = cache_specs(out_caches_sds, cfg, mesh, batch=shape.global_batch,
                        seq_shard=serve_tp)
    in_specs = (pspec, bspec)
    out_specs = (None, cspec)
    return TrainCase(
        fn=prefill_fn, args=(params_sds, batch_sds),
        in_shardings=to_shardings(in_specs, mesh),
        out_shardings=to_shardings(out_specs, mesh),
        donate_argnums=(),
        meta={"kind": "prefill"},
    )


def build_decode_case(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                      *, loop: bool = False) -> TrainCase:
    """Decode: (params, caches, token, pos) -> (logits, caches).

    The cache stands for `shape.seq_len` tokens of context; for long_500k
    attention archs it is the sliding-window ring buffer (sub-quadratic
    adaptation, DESIGN.md §6) and for SSM archs the O(1) state.
    """
    b = shape.global_batch
    long_ctx = shape.long_context
    use_scan = _use_scan(cfg) and not loop
    cache_len = _cache_len(cfg, shape)

    def make_cache_struct():
        caches = T.make_caches(cfg, b, cache_len, cfg.cdtype,
                               long_context=long_ctx)
        return scan_mod.stack_caches(caches, cfg) if use_scan else caches

    def decode_fn(params, caches, token, pos):
        if use_scan:
            return scan_mod.decode_step(params, cfg, token, caches, pos,
                                        long_context=long_ctx)
        return T.decode_step(params, cfg, token, caches, pos,
                             long_context=long_ctx)

    params_sds = jax.eval_shape(
        (lambda k: T.init_params(k, cfg)) if loop else
        functools.partial(init_model_params, cfg=cfg), jax.random.PRNGKey(0))
    caches_sds = jax.eval_shape(make_cache_struct)
    token_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((b,), jnp.int32)

    serve_tp = cfg.serve_tp and cfg.fl_client_axis == "pod"
    pspec = param_specs(params_sds, cfg, mesh, client_stacked=False,
                        serve=True)
    cspec = cache_specs(caches_sds, cfg, mesh, batch=b, seq_shard=serve_tp)
    # token/pos batch-sharded like the caches (replicated inputs make GSPMD
    # gather the huge cache to meet the activations); under the serve_tp
    # layout batch is replicated and the cache is sequence-sharded instead.
    if serve_tp:
        tspec = {"t": P(), "p": P()}
    else:
        tspec = batch_specs({"t": token_sds, "p": pos_sds}, cfg, mesh,
                            client_dim=False)
    in_specs = (pspec, cspec, tspec["t"], tspec["p"])
    out_specs = (None, cspec)
    return TrainCase(
        fn=decode_fn, args=(params_sds, caches_sds, token_sds, pos_sds),
        in_shardings=to_shardings(in_specs, mesh),
        out_shardings=to_shardings(out_specs, mesh),
        donate_argnums=(1,),
        meta={"kind": "decode", "cache_len": cache_len},
    )


def build_case(cfg: ModelConfig, mesh: Mesh, shape_name: str, **kw) -> TrainCase:
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_case(cfg, mesh, shape, **kw)
    loop = kw.get("loop", False)
    if shape.kind == "prefill":
        return build_prefill_case(cfg, mesh, shape, loop=loop)
    return build_decode_case(cfg, mesh, shape, loop=loop)
