# Launcher package: mesh.py / sharding.py / steps.py are import-safe (no jax
# device-state side effects); dryrun.py must run as its own process.
