from repro.fl.channel import (Channel, ChannelCost, Codec, LinkProfile,
                              get_codec, get_link_profile, tree_bits)
from repro.fl.comm import (SYSTEMS, SystemModel, WIRED, WIRELESS_FAST_UL,
                           WIRELESS_SLOW_UL, downlink_cost, harmonic)
from repro.fl.faults import (FaultConfig, FaultMeter, FaultPlan,
                             RobustAggregator, get_robust_aggregator,
                             parse_fault_spec, register_robust,
                             resolve_fault_plan, resolve_faults)
from repro.fl.hierarchy import (EdgeAggregator, EdgeMeter, EdgeState,
                                HierarchyConfig, get_edge_aggregator,
                                register_edge_aggregator, resolve_hierarchy)
from repro.fl.placement import HostVmap, MeshShardMap, Placement
from repro.fl.population import (ClientStateStore, CohortSchedule,
                                 FixedCohort, PagingConfig, RandomCohorts,
                                 SequentialSweep, run_async_paged, run_paged,
                                 sub_federated)
from repro.fl.simulator import (FLConfig, History, NonFiniteEvalWarning,
                                evaluate, run_federated, superstep_support)
from repro.fl.runtime import AsyncConfig, VirtualClock, run_async
from repro.fl.serve import DeltaStore, ServeEngine, StoreBits, check_parity
from repro.fl.stats import full_client_gradients, sigma2_estimates
from repro.fl.strategies import (ClientSampler, ClusterExtras, CommCost,
                                 FullParticipation, MixingExtras,
                                 RoundContext, Strategy, StrategyExtras,
                                 UniformFraction, available_strategies,
                                 get_strategy, get_strategy_class, register)

__all__ = ["AsyncConfig", "VirtualClock", "run_async",
           "Channel", "ChannelCost", "Codec", "LinkProfile", "get_codec",
           "get_link_profile", "tree_bits",
           "ClientStateStore", "CohortSchedule", "FixedCohort",
           "PagingConfig", "RandomCohorts", "SequentialSweep",
           "run_async_paged", "run_paged", "sub_federated",
           "DeltaStore", "ServeEngine", "StoreBits", "check_parity",
           "EdgeAggregator", "EdgeMeter", "EdgeState", "HierarchyConfig",
           "get_edge_aggregator", "register_edge_aggregator",
           "resolve_hierarchy",
           "FaultConfig", "FaultMeter", "FaultPlan", "RobustAggregator",
           "get_robust_aggregator", "parse_fault_spec", "register_robust",
           "resolve_fault_plan", "resolve_faults",
           "HostVmap", "MeshShardMap", "Placement",
           "SYSTEMS", "SystemModel", "WIRED", "WIRELESS_FAST_UL",
           "WIRELESS_SLOW_UL", "downlink_cost", "harmonic", "FLConfig",
           "History", "NonFiniteEvalWarning", "evaluate", "run_federated",
           "superstep_support",
           "full_client_gradients",
           "sigma2_estimates", "ClientSampler", "ClusterExtras", "CommCost",
           "FullParticipation", "MixingExtras", "RoundContext", "Strategy",
           "StrategyExtras", "UniformFraction", "available_strategies",
           "get_strategy", "get_strategy_class", "register"]
