from repro.fl.comm import (SYSTEMS, SystemModel, WIRED, WIRELESS_FAST_UL,
                           WIRELESS_SLOW_UL, downlink_cost, harmonic)
from repro.fl.simulator import (FLConfig, History, evaluate, run_federated)

__all__ = ["SYSTEMS", "SystemModel", "WIRED", "WIRELESS_FAST_UL",
           "WIRELESS_SLOW_UL", "downlink_cost", "harmonic", "FLConfig",
           "History", "evaluate", "run_federated"]
