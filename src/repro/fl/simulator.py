"""Federated learning round engine: placement-generic, strategy-driven.

The engine owns the generic round mechanics — client sampling, the local
update, evaluation, the analytic clock — and delegates every
algorithm-specific decision to a `Strategy` (repro.fl.strategies) and
every layout decision to a `Placement` (repro.fl.placement):

    run_federated("ucfl_k3", fed)                          # spec string
    run_federated(strategy=get_strategy("ucfl_k3"), fed=fed)  # instance
    run_federated("ucfl_k3", fed,
                  placement=MeshShardMap(schedule="shard_map_streams"))

Registered strategies: fedavg | local | oracle | ucfl | ucfl_k<k> |
cfl (Sattler et al.) | fedfomo (Zhang et al.); see DESIGN.md §4–§5.

Placements (DESIGN.md §3): `HostVmap` (default — all clients stacked on
one device, paper-scale m=20..100) and `MeshShardMap` (clients sharded
over a device mesh, mixing via schedule-selected collectives).  The
mesh CLI `repro.launch.train` drives this same engine.

Passing ``async_cfg=AsyncConfig(...)`` delegates to the event-driven
buffered-async runtime (`repro.fl.runtime`, DESIGN.md §3a): same
strategies, same placements, virtual-clock time instead of the analytic
per-round maximum.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedData
from repro.fl.channel import (Channel, ChannelCost, resolve_channel,
                              round_downlink_time, tree_bits,
                              uplink_roundtrip, zeros_like_stack)
from repro.fl.comm import SYSTEMS, SystemModel
from repro.fl.placement import (HostVmap, MeshShardMap,  # noqa: F401 (re-export)
                                Placement, evaluate, make_client_update,
                                reduce_scores, resolve_placement,
                                stack_params, where_clients)
from repro.fl.stats import full_client_gradients, sigma2_estimates  # noqa: F401 (re-exported for back-compat)
from repro.fl.strategies import (ClientSampler, CommCost, RoundContext,
                                 Strategy, StrategyExtras, TracedMix,
                                 get_strategy)
from repro.models import lenet


@dataclass
class FLConfig:
    local_steps: int = 10
    batch_size: int = 64
    lr: float = 0.1
    momentum: float = 0.9
    # optimizer-state dtype policy: None = fp32 state, "param" = keep
    # momentum in the param dtype (the giants' HBM-fit knob, DESIGN.md §4)
    opt_state_dtype: Optional[str] = None
    rounds: int = 60
    sigma_batches: int = 5
    eval_every: int = 5
    fomo_candidates: int = 5
    cfl_eps1: float = 0.04
    cfl_eps2: float = 0.06
    cfl_min_rounds: int = 10


# ---------------------------------------------------------------------------
# the round engine


def default_model_init(fed: FederatedData) -> Callable:
    """LeNet sized to the scenario's images — shared with the async engine
    so both runtimes build bit-identical initializations."""
    in_size, channels = fed.x.shape[2], fed.x.shape[4]
    n_classes = int(jnp.max(fed.y)) + 1
    return lambda k: lenet.init_params(
        k, lenet.LeNetConfig(in_size=in_size, in_channels=channels,
                             n_classes=max(n_classes, 10)))


def resolve_strategy(algorithm: Union[str, Strategy, None],
                     strategy: Optional[Strategy]) -> Strategy:
    """spec-string-or-instance -> Strategy (shared by both engines)."""
    if strategy is not None:
        if algorithm is not None:
            raise TypeError("pass either `algorithm` or `strategy=`, not both")
        return strategy
    if algorithm is None:
        raise TypeError("one of `algorithm` or `strategy=` is required")
    if isinstance(algorithm, Strategy):
        return algorithm
    return get_strategy(algorithm)


def init_run(strategy: Strategy, fed: FederatedData, fl: "FLConfig",
             model_init: Optional[Callable], loss_fn: Callable,
             acc_fn: Callable, placement: Placement, seed: int,
             donate: bool = False, hierarchy: Optional[Any] = None,
             system: Optional[SystemModel] = None):
    """Shared run prologue for the sync and async engines: PRNG split,
    model init, cached update step, client stack/opt/data placement,
    RoundContext and `strategy.setup`.  Returns
    ``(key, vmapped_update, stacked, opt_state, data, ctx, state)``.

    With ``hierarchy`` (a resolved `HierarchyConfig`, DESIGN.md §3f) the
    update step becomes the fleet sub-round, the data grows the nested
    device axis and the opt-state slot carries the `EdgeState`; the
    resolved `FleetPlan` rides on ``ctx.hierarchy_plan`` for the engines'
    `EdgeMeter`.  ``system`` is consumed only there (the edge link
    resolves against it, like `init_channel`'s link)."""
    m = fed.m
    key = jax.random.PRNGKey(seed)
    key, kinit = jax.random.split(key)
    if model_init is None:
        model_init = default_model_init(fed)
    params0 = model_init(kinit)
    if hierarchy is None:
        opt, vmapped_update = placement.build_update(loss_fn, fl,
                                                     donate=donate)
        stacked = placement.stack(params0, m)
        opt_state = placement.init_opt(opt, stacked)
        data = placement.place_data(fed)
        plan = None
    else:
        from repro.fl.hierarchy import init_fleet_run
        vmapped_update, stacked, opt_state, data, plan = init_fleet_run(
            hierarchy, placement, loss_fn, fl, fed, params0,
            system=system, donate=donate, strategy=strategy)

    ctx = RoundContext(fed=fed, fl=fl, loss_fn=loss_fn, acc_fn=acc_fn,
                       params0=params0, seed=seed, placement=placement,
                       strategy=strategy)
    ctx.hierarchy_plan = plan
    state = strategy.setup(ctx)
    return key, vmapped_update, stacked, opt_state, data, ctx, state


def finalize_history(history: "History", strategy: Strategy, state: Any,
                     keep_state: bool, stacked: Any, opt_state: Any
                     ) -> "History":
    """Shared run epilogue: typed extras, the legacy extra dict, and the
    optional final device-resident state."""
    history.extras = strategy.extras(state)
    history.extra["comm_per_round"] = list(history.comm)
    if history.extras is not None:
        history.extra.update(dataclasses.asdict(history.extras))
    if keep_state:
        history.final_params, history.final_opt_state = stacked, opt_state
    return history


def init_channel(channel: Optional[Channel], ctx: "RoundContext",
                 stacked: Any, system: Optional[SystemModel], m: int):
    """Shared channel prologue for the sync and async engines (so their
    §3b semantics can't drift, like `init_run` for the round prologue):
    payload bits, resolved link profile and the error-feedback residual
    stack.  Returns ``(payload, link, model_bits, ef, channel)`` — all
    None/0 when no channel is attached.  The link is resolved FIRST
    (validating its spec even when no ``system`` will consume it, against
    the default wired model, so ``extra["channel"]`` records it
    consistently), then the codec is bound to it — rate-adaptive codecs
    pick their per-client parameters here, so callers must use the
    RETURNED channel from this point on."""
    if channel is None:
        return None, None, 0, None, None
    model_bits = tree_bits(ctx.params0)
    link = channel.resolve_link(system if system is not None
                                else SYSTEMS["wired"], model_bits, m)
    codec = channel.codec.bind_link(link, ctx.params0)
    if codec is not channel.codec:
        channel = dataclasses.replace(channel, codec=codec)
    ef = None if codec.is_identity else zeros_like_stack(stacked)
    payload = codec.payload_bits(ctx.params0)
    return payload, link, model_bits, ef, channel


def per_client_uplink_bits(channel: Optional[Channel], ctx: "RoundContext",
                           payload: Optional[int],
                           m: int) -> Optional[np.ndarray]:
    """(m,) per-client uplink payload vector when the bound codec's bits
    are NOT uniform (rate-adaptive codecs), else None — keeping the fixed-
    codec accounting on its exact scalar path."""
    if channel is None:
        return None
    vec = channel.codec.per_client_bits(ctx.params0, m)
    return None if np.all(vec == payload) else vec


def channel_uplink(placement: Placement, channel: Channel, stacked: Any,
                   prev: Any, ef: Any, kround, mask):
    """Shared per-round uplink crossing (lossy codecs only): both engines
    derive the codec key as ``fold_in(kround, 2)`` — index 1 is the
    strategies' derivation — and thread the EF residuals identically."""
    stacked, new_ef = placement.uplink(
        channel.codec, stacked, prev, ef, jax.random.fold_in(kround, 2),
        mask)
    return stacked, (new_ef if channel.error_feedback else ef)


def channel_extra(history: "History", channel: Channel, link,
                  model_bits: int, ul_payload: int) -> None:
    """Shared `History.extra["channel"]` record of a channel-carrying run
    (both engines): codec/link identity, per-payload bits and the run's
    cumulative bit totals (the §3b bits axes)."""
    history.extra["channel"] = {
        "codec": channel.codec.spec,
        "error_feedback": bool(channel.error_feedback),
        "link": link.name if link is not None else None,
        "model_bits": int(model_bits),
        "payload_bits": int(ul_payload),
        "dl_bits_total": int(sum(c.dl_bits for c in history.comm_bits)),
        "ul_bits_total": int(sum(c.ul_bits for c in history.comm_bits)),
    }


# ---------------------------------------------------------------------------
# superstep execution (DESIGN.md §3c): fuse eval_every rounds into one scan


def _mro_definer(cls: type, name: str) -> Optional[type]:
    """The class in ``cls``'s MRO that actually defines ``name``."""
    for c in cls.__mro__:
        if name in vars(c):
            return c
    return None


def superstep_support(strategy: Strategy,
                      sampler: Optional[ClientSampler],
                      hierarchy: Optional[Any] = None) -> tuple:
    """(ok, reason) — whether this run qualifies for the fused superstep.

    Strategy and sampler must declare the traceability contract; every
    registered codec's ``roundtrip`` is already a pure traced function, so
    a `Channel` never blocks fusion.  A subclass of a traceable strategy
    that overrides the eventful hooks (``aggregate``/``reweight``)
    WITHOUT re-implementing ``aggregate_traced`` would silently fuse with
    the parent's traced rule — detected here and routed to the eventful
    loop instead."""
    if not strategy.traceable:
        return False, (f"strategy {strategy.spec!r} is not traceable "
                       "(eventful per-round state)")
    cls = type(strategy)
    traced_at = _mro_definer(cls, "aggregate_traced")
    for name in ("aggregate", "reweight"):
        at = _mro_definer(cls, name)
        if at is not Strategy and not issubclass(traced_at, at):
            return False, (
                f"{cls.__name__} overrides {name}() below the class "
                f"defining aggregate_traced ({traced_at.__name__}); the "
                "traced path would silently diverge — override "
                "aggregate_traced too (or set traceable=False)")
    if sampler is not None and not sampler.traceable:
        return False, (f"sampler {type(sampler).__name__} does not "
                       "implement sample_traced")
    if hierarchy is not None:
        agg = hierarchy.edge_aggregator
        if not agg.traceable:
            return False, (f"edge aggregator {agg.spec!r} is not traceable "
                           "(host-side edge weighting, DESIGN.md §3f)")
    return True, ""


# compiled supersteps, shared across `run_federated` calls: key ->
# {scan length -> jitted superstep}.  The key captures everything the
# trace closes over (the cached update step object carries the
# loss_fn/FLConfig identity; strategy and sampler contribute their
# spec-level identities; the placement its mesh/schedule; `acc_fn` the
# fused chunk-end eval) — but NOT the client count: the traced round
# derives m from the data shapes, so the jit wrapper re-specializes per
# COHORT SHAPE on its own and one cache entry serves every population
# size (the paging engine's executable-reuse contract, DESIGN.md §3e).
# Bounded like the neighboring executable caches (`cached_update`,
# `_uplink_fn`): oldest config evicted past the cap, so sweep processes
# iterating many (scenario × algorithm × codec) configs don't pin
# executables forever.
_SUPERSTEP_FNS: Dict[tuple, Dict[int, Callable]] = {}
_SUPERSTEP_CACHE_MAX = 32


def _superstep_cache(placement: Placement, strategy: Strategy,
                     sampler: Optional[ClientSampler],
                     codec, error_feedback: bool, update_fn: Callable,
                     acc_fn: Callable) -> Dict[int, Callable]:
    key = (placement.cache_key(), type(strategy), strategy.spec,
           None if sampler is None else sampler.cache_key,
           codec, bool(error_feedback), update_fn, acc_fn)
    cache = _SUPERSTEP_FNS.pop(key, None)   # re-insert: LRU, not FIFO
    if cache is None:
        while len(_SUPERSTEP_FNS) >= _SUPERSTEP_CACHE_MAX:
            _SUPERSTEP_FNS.pop(next(iter(_SUPERSTEP_FNS)))
        cache = {}
    _SUPERSTEP_FNS[key] = cache
    return cache


def _build_traced_round(strategy: Strategy, sampler: Optional[ClientSampler],
                        codec, error_feedback: bool, placement: Placement,
                        update_fn: Callable) -> Callable:
    """The fused round: (local update → sampler select → codec uplink with
    error feedback → strategy aggregate) as one pure function

        round_fn((key, stacked, opt_state, ef), (x, y, n), consts)
            -> ((key', stacked', opt_state', ef'), mask | None)

    with EXACTLY the eventful engine's key derivation — ``ksample`` split
    first (stochastic samplers only), then ``kround``; per-client batch
    keys are ``split(kround, m)``, the codec key ``fold_in(kround, 2)``
    (index 1 stays reserved for the strategies' derivation) — so the
    fused run is bit-identical to the per-round loop.  The client count
    m comes from the traced data shapes, NOT from the builder: one
    round_fn (and so one cached superstep) serves every cohort size,
    which is what lets the paging engine (DESIGN.md §3e) reuse
    executables across populations."""
    tmix = TracedMix(placement)
    lossy = codec is not None and not codec.is_identity
    backend = placement.codec_backend

    def round_fn(carry, data, consts):
        key, stacked, opt_state, ef = carry
        x, y, n = data
        m = x.shape[0]      # static under trace: the cohort shape
        ksample = None
        if sampler is not None and sampler.needs_key:
            key, ksample = jax.random.split(key)
        key, kround = jax.random.split(key)
        ckeys = jax.random.split(kround, m)
        prev, prev_opt = stacked, opt_state
        stacked, opt_state = update_fn(stacked, opt_state, x, y, n, ckeys)
        mask = None
        if sampler is not None:
            # all-True where the eventful sampler would return None: the
            # row-select below is then a bitwise identity.  Through the
            # placement's `select` hook (pure on both backends) so a
            # backend overriding rollback keeps working under fusion.
            mask = sampler.sample_traced(ksample, m)
            stacked = placement.select(mask, stacked, prev)
            opt_state = placement.select(mask, opt_state, prev_opt)
        if lossy:
            new_stacked, new_ef = uplink_roundtrip(
                codec, stacked, prev, ef, jax.random.fold_in(kround, 2),
                mask, backend=backend)
            stacked = new_stacked
            ef = new_ef if error_feedback else ef
        stacked = strategy.aggregate_traced(consts, stacked, prev, tmix)
        return (key, stacked, opt_state, ef), mask

    return round_fn


def _eval_rounds(rounds: int, eval_every: int):
    """The eventful engine's eval boundaries (``rnd % eval_every == 0 or
    rnd == rounds - 1``) as consecutive chunk ends: yields the round index
    each superstep runs up to (inclusive)."""
    rnd = 0
    while rnd < rounds:
        nxt = min(((rnd + eval_every - 1) // eval_every) * eval_every,
                  rounds - 1)
        yield rnd, nxt
        rnd = nxt + 1


def charge_round(history: "History", cost: CommCost, mask_np, m: int,
                 payload: int, link, system: Optional[SystemModel],
                 channel: Optional[Channel], t_accum: float,
                 assignment: Optional[np.ndarray] = None,
                 ul_bits_pc: Optional[np.ndarray] = None,
                 edge: Optional[Any] = None) -> float:
    """One round's comm/bits/clock accounting, SHARED by the eventful loop
    and the superstep replay so the two engines can't drift (like
    `init_run`/`init_channel` for the prologue).  ``mask_np`` is the
    HOST-side participation row (None or all-True = full cohort — the
    eventful sampler returns None there); returns the updated clock.
    ``assignment`` is the strategy's client→stream map (membership-aware
    broadcast charging, None = legacy cohort-slowest upper bound);
    ``ul_bits_pc`` the (m,) per-client uplink payload vector (rate-
    adaptive codecs; None = uniform ``payload`` per client); ``edge`` the
    hierarchy tier's `EdgeMeter` (DESIGN.md §3f) — the device→user hop's
    bits land in its own books every round and its time (slowest
    participating user's edge sub-round) is added to the clock whenever a
    ``system`` runs one."""
    history.comm.append(cost)
    n_part, participants = m, None
    if channel is not None or system is not None or edge is not None:
        # the round only waits for the clients that computed: H_|S| under
        # partial participation, not H_m
        if mask_np is not None and not mask_np.all():
            n_part = int(mask_np.sum())
            participants = np.where(mask_np)[0]
    if channel is not None:
        # downlink streams move the codec-compressed model (§3b)
        if ul_bits_pc is None:
            ul_bits = n_part * payload
        else:
            idx = participants if participants is not None else slice(None)
            ul_bits = int(np.sum(ul_bits_pc[idx]))
        history.comm_bits.append(ChannelCost(
            dl_bits=(cost.n_streams + cost.n_unicasts) * payload,
            ul_bits=ul_bits))
    if system is not None:
        if link is not None:
            ul = payload if ul_bits_pc is None else ul_bits_pc
            t_accum += (system.compute_time(n_part)
                        + link.max_uplink_time(ul, participants)
                        + round_downlink_time(link, cost, payload,
                                              participants, assignment))
        else:
            t_accum += system.round_time(n_part, n_streams=cost.n_streams,
                                         n_unicasts=cost.n_unicasts)
    if edge is not None:
        t_edge = edge.charge(mask_np)
        if system is not None:
            t_accum += t_edge
    return t_accum


@dataclass
class History:
    rounds: List[int] = field(default_factory=list)
    mean_acc: List[float] = field(default_factory=list)
    worst_acc: List[float] = field(default_factory=list)
    time: List[float] = field(default_factory=list)
    comm: List[CommCost] = field(default_factory=list)
    # bits-based sibling of `comm`, one entry per round — populated only
    # when the run carries a Channel (DESIGN.md §3b)
    comm_bits: List[ChannelCost] = field(default_factory=list)
    extras: Optional[StrategyExtras] = None
    # legacy mapping view, filled by the engine from `comm` + `extras`;
    # a real dict so pre-redesign callers that annotate it keep working
    extra: Dict[str, Any] = field(default_factory=dict)
    # populated when run_federated(keep_state=True): the final client-
    # stacked params / optimizer state (still device-resident)
    final_params: Any = None
    final_opt_state: Any = None


def _run_superstep(strategy: Strategy, fed: FederatedData, *,
                   sampler: Optional[ClientSampler], fl: "FLConfig",
                   model_init: Optional[Callable], loss_fn: Callable,
                   acc_fn: Callable, system: Optional[SystemModel],
                   placement: Placement, channel: Optional[Channel],
                   keep_state: bool, seed: int,
                   hierarchy: Optional[Any] = None) -> "History":
    """Scan-compiled sync run (DESIGN.md §3c): Python re-enters only at
    eval boundaries; per-round participation masks come back as ONE
    stacked device->host transfer per superstep, the chunk-end eval runs
    INSIDE the compiled superstep (fused onto the end of the scan — no
    separate eval dispatch on the hot path), and the clock/CommCost/
    ChannelCost accounting is replayed host-side in the eventful engine's
    exact per-round order (bit-identical histories)."""
    m = fed.m
    key, update_fn, stacked, opt_state, data, ctx, state = init_run(
        strategy, fed, fl, model_init, loss_fn, acc_fn, placement, seed,
        donate=False,   # donation happens at the superstep boundary instead
        hierarchy=hierarchy, system=system)
    meter = None
    if hierarchy is not None:
        from repro.fl.hierarchy import EdgeMeter
        meter = EdgeMeter(ctx.hierarchy_plan)
    payload, link, model_bits, ef, channel = init_channel(
        channel, ctx, stacked, system, m)
    lossy = channel is not None and not channel.codec.is_identity
    # identity codecs trace no uplink: normalize so channel-less and
    # identity-channel runs share one compiled superstep
    codec = channel.codec if lossy else None
    ef_flag = channel.error_feedback if lossy else True
    consts = strategy.traced_state(state)
    round_fn = _build_traced_round(strategy, sampler, codec, ef_flag,
                                   placement, update_fn)
    cache = _superstep_cache(placement, strategy, sampler, codec, ef_flag,
                             update_fn, acc_fn)
    eval_fn = lambda st, ed: placement.eval_traced(acc_fn, st, ed[0], ed[1])
    cost = strategy.comm(state)     # round-constant by the traceability
    history = History()             # contract (state never changes)
    assignment = strategy.membership(state)      # round-constant too
    ul_bits_pc = per_client_uplink_bits(channel, ctx, payload, m)
    t_accum = 0.0
    carry = (key, stacked, opt_state, ef if lossy else None)

    for rnd, nxt in _eval_rounds(fl.rounds, fl.eval_every):
        length = nxt - rnd + 1
        carry, masks, accs = placement.run_supersteps(
            round_fn, carry, data, consts, length, cache=cache,
            eval_fn=eval_fn, eval_data=(fed.x_val, fed.y_val))
        # the chunk's ONE blocking device->host transfer — and only when a
        # clock or the bits axis actually consumes the masks
        masks_np = (np.asarray(masks)
                    if masks is not None
                    and (channel is not None or system is not None
                         or meter is not None)
                    else None)
        for i in range(length):
            t_accum = charge_round(
                history, cost, None if masks_np is None else masks_np[i],
                m, payload, link, system, channel, t_accum,
                assignment, ul_bits_pc, meter)
        mean_acc, worst_acc = reduce_scores(accs)
        history.rounds.append(nxt)
        history.mean_acc.append(mean_acc)
        history.worst_acc.append(worst_acc)
        history.time.append(t_accum)

    _, stacked, opt_state, _ = carry
    history = finalize_history(history, strategy, state, keep_state,
                               stacked, opt_state)
    if meter is not None:
        history.extra["hierarchy"] = meter.extra()
    if channel is not None:
        channel_extra(history, channel, link, model_bits, payload)
    return history


def run_federated(algorithm: Union[str, Strategy, None] = None,
                  fed: Optional[FederatedData] = None, *,
                  strategy: Optional[Strategy] = None,
                  sampler: Optional[ClientSampler] = None,
                  fl: Optional[FLConfig] = None,
                  model_init: Optional[Callable] = None,
                  loss_fn: Callable = lenet.loss_fn,
                  acc_fn: Callable = lenet.accuracy,
                  system: Optional[SystemModel] = None,
                  placement: Optional[Placement] = None,
                  channel: Union[str, Channel, None] = None,
                  keep_state: bool = False,
                  async_cfg: Optional[Any] = None,
                  superstep: Optional[bool] = None,
                  paging: Optional[Any] = None,
                  hierarchy: Optional[Any] = None,
                  seed: int = 0) -> History:
    """Run one strategy on one scenario; returns accuracy/time history.

    algorithm: a registry spec string (``"fedavg"``, ``"ucfl_k3"``, ...)
    or a `Strategy` instance; alternatively pass ``strategy=``.  ``sampler``
    selects per-round client participation (default: everyone).
    ``placement`` selects the client layout backend (default `HostVmap`,
    bit-identical to the pre-placement engine); ``keep_state=True``
    attaches the final stacked params / opt state to the History.
    ``channel`` (a `Channel` or codec spec string, DESIGN.md §3b) turns on
    bit-level payload accounting, uplink compression with error feedback
    and per-client link timing; ``Channel()``/None with the identity codec
    are bit-identical.  ``async_cfg`` (an `AsyncConfig`) switches to the
    event-driven buffered-async runtime (DESIGN.md §3a).  ``superstep``
    (DESIGN.md §3c) compiles ``eval_every`` consecutive rounds as one
    device-resident `lax.scan`: None (default) fuses exactly when
    strategy and sampler satisfy the traceability contract (bit-identical
    histories either way), False forces the eventful per-round loop, True
    raises if the configuration cannot fuse.  ``paging`` (a
    `PagingConfig`, DESIGN.md §3e) switches to the cohort paging engine:
    the full client population lives in a host-backed store and only one
    cohort is device-resident per superstep.  ``hierarchy`` (a
    `HierarchyConfig`, an int devices-per-user, or a fleet spec string —
    DESIGN.md §3f) nests an edge sub-round inside every round: each user
    aggregates its device fleet before the server sees it, both hops are
    charged, and the device→user hop's bits land in
    ``History.extra["hierarchy"]``.
    """
    if hierarchy is not None:
        from repro.fl.hierarchy import resolve_hierarchy
        hierarchy = resolve_hierarchy(hierarchy)
    if async_cfg is not None:
        if sampler is not None:
            raise TypeError("the async runtime takes no ClientSampler — "
                            "the arrival buffer is the per-event cohort")
        if superstep:
            raise TypeError("superstep fusion is a synchronous-engine "
                            "feature; the async runtime is event-driven")
        from repro.fl.runtime import run_async
        return run_async(algorithm, fed, strategy=strategy,
                         async_cfg=async_cfg, fl=fl, model_init=model_init,
                         loss_fn=loss_fn, acc_fn=acc_fn, system=system,
                         placement=placement, channel=channel,
                         keep_state=keep_state, paging=paging,
                         hierarchy=hierarchy, seed=seed)
    if paging is not None:
        if hierarchy is not None:
            raise TypeError("the hierarchy tier does not compose with the "
                            "cohort paging engine yet (the store pages "
                            "flat client rows, not device fleets)")
        if superstep is False:
            raise TypeError("the paging engine runs fused supersteps only "
                            "(DESIGN.md §3e); superstep=False cannot page")
        from repro.fl.population import run_paged
        return run_paged(algorithm, fed, paging=paging, strategy=strategy,
                         sampler=sampler, fl=fl, model_init=model_init,
                         loss_fn=loss_fn, acc_fn=acc_fn, system=system,
                         placement=placement, channel=channel,
                         keep_state=keep_state, seed=seed)
    strategy = resolve_strategy(algorithm, strategy)
    if fed is None:
        raise TypeError("`fed` is required")
    fl = FLConfig() if fl is None else fl
    placement = resolve_placement(placement)
    channel = resolve_channel(channel)
    codec = channel.codec if channel is not None else None
    lossy = codec is not None and not codec.is_identity

    if superstep is None or superstep:
        ok, why = superstep_support(strategy, sampler, hierarchy=hierarchy)
        if not ok and superstep:
            raise ValueError(f"superstep=True but this run cannot fuse: "
                             f"{why}")
        if ok:
            return _run_superstep(strategy, fed, sampler=sampler, fl=fl,
                                  model_init=model_init, loss_fn=loss_fn,
                                  acc_fn=acc_fn, system=system,
                                  placement=placement, channel=channel,
                                  keep_state=keep_state,
                                  hierarchy=hierarchy, seed=seed)

    m = fed.m
    # When no sampler can roll clients back and the strategy declares it
    # never reads `prev`, the update step may consume (donate) the old
    # stacked/opt buffers — peak memory drops from ~2× params+opt to ~1×.
    # A lossy codec reads `prev` too (the uplink transmits Δ = new − prev).
    donate = sampler is None and not strategy.reads_prev and not lossy
    key, vmapped_update, stacked, opt_state, (x, y, n), ctx, state = \
        init_run(strategy, fed, fl, model_init, loss_fn, acc_fn,
                 placement, seed, donate=donate, hierarchy=hierarchy,
                 system=system)
    meter = None
    if hierarchy is not None:
        from repro.fl.hierarchy import EdgeMeter
        meter = EdgeMeter(ctx.hierarchy_plan)

    payload, link, model_bits, ef, channel = init_channel(
        channel, ctx, stacked, system, m)
    ul_bits_pc = per_client_uplink_bits(channel, ctx, payload, m)

    history = History()
    t_accum = 0.0

    for rnd in range(fl.rounds):
        ksample = None
        if sampler is not None and sampler.needs_key:
            key, ksample = jax.random.split(key)
        key, kround = jax.random.split(key)
        ckeys = placement.place_keys(jax.random.split(kround, m))
        # donated buffers are dead after the update call: strategies that
        # declared reads_prev=False see prev=None
        prev, prev_opt = (None, None) if donate else (stacked, opt_state)
        stacked, opt_state = vmapped_update(stacked, opt_state, x, y, n,
                                            ckeys)

        mask = sampler.sample(rnd, m, ksample) if sampler is not None else None
        if mask is not None:
            # non-participants keep their pre-round model and optimizer
            stacked = placement.select(mask, stacked, prev)
            opt_state = placement.select(mask, opt_state, prev_opt)

        if lossy:
            # uplink channel crossing (DESIGN.md §3b): the server receives
            # the codec's decode(encode(Δ + residual))
            stacked, ef = channel_uplink(placement, channel, stacked, prev,
                                         ef, kround, mask)

        # strategies get their own key derivation: kround's raw splits are
        # already consumed as the per-client minibatch keys
        ctx.rnd, ctx.key, ctx.participation = \
            rnd, jax.random.fold_in(kround, 1), mask
        stacked, state = strategy.aggregate(state, stacked, prev, ctx)

        # ONE host sync per round at most (the mask pull), none when no
        # clock or bits axis consumes it — n_part and the link-clock
        # participants both come from the same host-side array inside
        # `charge_round` (shared with the superstep replay)
        mask_np = (np.asarray(mask)
                   if mask is not None
                   and (channel is not None or system is not None
                        or meter is not None)
                   else None)
        t_accum = charge_round(history, strategy.comm(state), mask_np, m,
                               payload, link, system, channel, t_accum,
                               strategy.membership(state), ul_bits_pc,
                               meter)

        if rnd % fl.eval_every == 0 or rnd == fl.rounds - 1:
            mean_acc, worst_acc = placement.evaluate(acc_fn, stacked, fed)
            history.rounds.append(rnd)
            history.mean_acc.append(mean_acc)
            history.worst_acc.append(worst_acc)
            history.time.append(t_accum)

    history = finalize_history(history, strategy, state, keep_state,
                               stacked, opt_state)
    if meter is not None:
        history.extra["hierarchy"] = meter.extra()
    if channel is not None:
        channel_extra(history, channel, link, model_bits, payload)
    return history
