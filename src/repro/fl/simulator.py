"""Federated learning simulator: vmap-over-clients round engine.

Runs the paper's algorithms on stacked client data (`FederatedData`):

    fedavg | local | oracle | ucfl (full personalization) | ucfl_k<k> |
    cfl (Sattler et al.) | fedfomo (Zhang et al.)

Client placement here is the host `vmap` mode of DESIGN.md §3 (paper-scale
m=20..100, LeNet).  The mesh-placed variants live in repro/launch.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (fedavg_weights, kmeans, mixing_matrix,
                        silhouette_score, stream_aggregate,
                        user_centric_aggregate)
from repro.core.similarity import flatten_pytree
from repro.core.streams import StreamPlan
from repro.data.federated import FederatedData
from repro.fl.comm import SystemModel, downlink_cost
from repro.models import lenet
from repro.optim import apply_updates, sgd


@dataclass
class FLConfig:
    local_steps: int = 10
    batch_size: int = 64
    lr: float = 0.1
    momentum: float = 0.9
    rounds: int = 60
    sigma_batches: int = 5
    eval_every: int = 5
    fomo_candidates: int = 5
    cfl_eps1: float = 0.04
    cfl_eps2: float = 0.06
    cfl_min_rounds: int = 10


# ---------------------------------------------------------------------------
# building blocks


def make_client_update(loss_fn: Callable, opt, fl: FLConfig):
    """Returns f(params_i, opt_i, data_i, n_i, key) -> (params_i', opt_i')
    running `local_steps` SGD steps on mini-batches drawn from client i."""

    def client_update(params_i, opt_i, x_i, y_i, n_i, key):
        n_slots = x_i.shape[0]

        def step(carry, k):
            p, o = carry
            idx = jax.random.randint(k, (fl.batch_size,), 0, 1 << 30) % \
                jnp.maximum(n_i.astype(jnp.int32), 1)
            idx = idx % n_slots
            batch = {"x": x_i[idx], "y": y_i[idx]}
            grads, _ = jax.grad(loss_fn, has_aux=True)(p, batch)
            upd, o = opt.update(grads, o, p)
            return (apply_updates(p, upd), o), None

        keys = jax.random.split(key, fl.local_steps)
        (p, o), _ = jax.lax.scan(step, (params_i, opt_i), keys)
        return p, o

    return client_update


def _stack(params, m: int):
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (m,) + l.shape).copy(), params)


def full_client_gradients(loss_fn, params, fed: FederatedData) -> jnp.ndarray:
    """ĝ_i over each client's (padded) dataset; (m, D) float32."""

    def one(x_i, y_i):
        g, _ = jax.grad(loss_fn, has_aux=True)(params, {"x": x_i, "y": y_i})
        return flatten_pytree(g)

    return jax.vmap(one)(fed.x, fed.y)


def sigma2_estimates(loss_fn, params, fed: FederatedData, k_batches: int
                     ) -> jnp.ndarray:
    """Eq. 7 on contiguous K-way splits of each client's data."""
    n_max = fed.x.shape[1]
    bs = n_max // k_batches

    def one(x_i, y_i):
        gfull, _ = jax.grad(loss_fn, has_aux=True)(
            params, {"x": x_i, "y": y_i})
        gfull = flatten_pytree(gfull)
        devs = []
        for k in range(k_batches):
            sl = {"x": x_i[k * bs:(k + 1) * bs], "y": y_i[k * bs:(k + 1) * bs]}
            gk, _ = jax.grad(loss_fn, has_aux=True)(params, sl)
            devs.append(jnp.sum((flatten_pytree(gk) - gfull) ** 2))
        return jnp.mean(jnp.stack(devs))

    return jax.vmap(one)(fed.x, fed.y)


@functools.lru_cache(maxsize=8)
def _eval_fn(apply_acc: Callable):
    return jax.jit(jax.vmap(lambda p, x, y: apply_acc(p, {"x": x, "y": y})))


def evaluate(apply_acc: Callable, stacked_params, fed: FederatedData
             ) -> Tuple[float, float]:
    """(mean, worst) validation accuracy across clients, personalized models."""
    accs = _eval_fn(apply_acc)(stacked_params, fed.x_val, fed.y_val)
    return float(jnp.mean(accs)), float(jnp.min(accs))


# ---------------------------------------------------------------------------
# the round engine


@dataclass
class History:
    rounds: List[int] = field(default_factory=list)
    mean_acc: List[float] = field(default_factory=list)
    worst_acc: List[float] = field(default_factory=list)
    time: List[float] = field(default_factory=list)
    extra: Dict[str, Any] = field(default_factory=dict)


def run_federated(algorithm: str, fed: FederatedData, *,
                  fl: FLConfig = FLConfig(),
                  model_init: Optional[Callable] = None,
                  loss_fn: Callable = lenet.loss_fn,
                  acc_fn: Callable = lenet.accuracy,
                  system: Optional[SystemModel] = None,
                  seed: int = 0) -> History:
    """Run one algorithm on one scenario; returns accuracy/time history.

    algorithm: fedavg | local | oracle | ucfl | ucfl_k<int> | cfl | fedfomo
    """
    m = fed.m
    key = jax.random.PRNGKey(seed)
    key, kinit = jax.random.split(key)
    if model_init is None:
        in_size, channels = fed.x.shape[2], fed.x.shape[4]
        n_classes = int(jnp.max(fed.y)) + 1
        model_init = lambda k: lenet.init_params(
            k, lenet.LeNetConfig(in_size=in_size, in_channels=channels,
                                 n_classes=max(n_classes, 10)))
    params0 = model_init(kinit)
    opt = sgd(fl.lr, momentum=fl.momentum)
    client_update = make_client_update(loss_fn, opt, fl)
    vmapped_update = jax.jit(jax.vmap(client_update))

    stacked = _stack(params0, m)
    opt_state = jax.vmap(opt.init)(stacked)

    # --- pre-round: mixing coefficients (UCFL family) ---------------------
    w, plan, n_streams = None, None, 1
    if algorithm.startswith("ucfl"):
        grads = full_client_gradients(loss_fn, params0, fed)
        from repro.core.similarity import delta_matrix
        delta = delta_matrix(grads)
        sigma2 = sigma2_estimates(loss_fn, params0, fed, fl.sigma_batches)
        w = mixing_matrix(delta, sigma2, fed.n)
        if algorithm == "ucfl":
            n_streams = m
        else:
            k = int(algorithm.split("_k")[1])
            plan = kmeans(w, k, key=jax.random.PRNGKey(seed + 1))
            n_streams = k
    elif algorithm == "oracle":
        n_streams = int(jnp.max(fed.group)) + 1
    elif algorithm == "fedavg":
        n_streams = 1

    # CFL state (host-side orchestration)
    cfl_clusters = np.zeros(m, dtype=int)

    history = History()
    t_accum = 0.0
    comm_log: List[Tuple[int, int]] = []   # per-round (n_streams, n_unicasts)
    sys_model = system
    fomo_val_loss = jax.jit(jax.vmap(
        lambda p, x, y: loss_fn(p, {"x": x, "y": y})[0], in_axes=(None, 0, 0)))

    for rnd in range(fl.rounds):
        key, kround = jax.random.split(key)
        ckeys = jax.random.split(kround, m)
        prev = stacked
        stacked, opt_state = vmapped_update(stacked, opt_state, fed.x, fed.y,
                                            fed.n, ckeys)

        # --- aggregation ---------------------------------------------------
        if algorithm == "fedavg":
            stacked = user_centric_aggregate(stacked, fedavg_weights(fed.n))
        elif algorithm == "local":
            pass
        elif algorithm == "oracle":
            stacked = _groupwise_fedavg(stacked, fed.n, np.asarray(fed.group))
        elif algorithm == "ucfl" and plan is None:
            stacked = user_centric_aggregate(stacked, w)
        elif algorithm.startswith("ucfl"):
            stacked = stream_aggregate(stacked, plan)
        elif algorithm == "cfl":
            stacked, cfl_clusters = _cfl_round(
                stacked, prev, fed.n, cfl_clusters, rnd, fl)
            n_streams = int(cfl_clusters.max()) + 1
        elif algorithm == "fedfomo":
            stacked = _fedfomo_round(stacked, prev, fed, fomo_val_loss,
                                     fl.fomo_candidates, kround)
        else:
            raise ValueError(algorithm)

        ns, nu = downlink_cost(algorithm.split("_k")[0], m,
                               n_streams=n_streams,
                               fomo_candidates=fl.fomo_candidates)
        comm_log.append((ns, nu))
        if sys_model is not None:
            t_accum += sys_model.round_time(m, n_streams=ns, n_unicasts=nu)

        if rnd % fl.eval_every == 0 or rnd == fl.rounds - 1:
            mean_acc, worst_acc = evaluate(acc_fn, stacked, fed)
            history.rounds.append(rnd)
            history.mean_acc.append(mean_acc)
            history.worst_acc.append(worst_acc)
            history.time.append(t_accum)

    history.extra["comm_per_round"] = comm_log   # any SystemModel's time
    # axis is recoverable offline: cumsum of round_time(m, *comm_log[r])
    if w is not None:
        history.extra["mixing_matrix"] = np.asarray(w)
    if algorithm == "cfl":
        history.extra["clusters"] = cfl_clusters.copy()
    return history


# ---------------------------------------------------------------------------
# CFL (Sattler et al. 2020) — hierarchical bipartition on update cosine sim


def _groupwise_fedavg(stacked, n, group: np.ndarray):
    m = len(group)
    wmat = np.zeros((m, m), np.float32)
    nn = np.asarray(n)
    for g in np.unique(group):
        idx = np.where(group == g)[0]
        wg = nn[idx] / nn[idx].sum()
        for i in idx:
            wmat[i, idx] = wg
    return user_centric_aggregate(stacked, jnp.asarray(wmat))


def _cfl_round(stacked, prev, n, clusters: np.ndarray, rnd: int, fl: FLConfig):
    """Per-cluster FedAvg + Sattler bipartition criterion."""
    deltas = jax.vmap(lambda a, b: flatten_pytree(
        jax.tree_util.tree_map(lambda x, y: x - y, a, b)))(stacked, prev)
    deltas = np.asarray(deltas)
    norms = np.linalg.norm(deltas, axis=1)
    new_clusters = clusters.copy()
    if rnd >= fl.cfl_min_rounds:
        for c in np.unique(clusters):
            idx = np.where(clusters == c)[0]
            if len(idx) < 4:
                continue
            mean_delta = deltas[idx].mean(0)
            if (np.linalg.norm(mean_delta) < fl.cfl_eps1 * norms[idx].mean()
                    and norms[idx].max() > fl.cfl_eps2 * norms[idx].mean()):
                sub = _cosine_bipartition(deltas[idx])
                nxt = new_clusters.max() + 1
                new_clusters[idx[sub == 1]] = nxt
    stacked = _groupwise_fedavg(stacked, n, new_clusters)
    return stacked, new_clusters


def _cosine_bipartition(d: np.ndarray) -> np.ndarray:
    norm = d / (np.linalg.norm(d, axis=1, keepdims=True) + 1e-9)
    sim = norm @ norm.T
    i, j = np.unravel_index(np.argmin(sim), sim.shape)
    return (sim[:, j] > sim[:, i]).astype(int)


# ---------------------------------------------------------------------------
# FedFOMO (Zhang et al. 2020) — client-side first-order model optimization


def _fedfomo_round(stacked, prev, fed: FederatedData, val_loss_fn,
                   n_candidates: int, key):
    m = fed.m
    # loss of every candidate model on every client's validation set
    losses = np.zeros((m, m), np.float32)
    flat = jax.vmap(flatten_pytree)(stacked)
    flat_prev = jax.vmap(flatten_pytree)(prev)
    for j in range(m):
        pj = jax.tree_util.tree_map(lambda l: l[j], stacked)
        losses[:, j] = np.asarray(val_loss_fn(pj, fed.x_val, fed.y_val))
    prev_losses = np.zeros((m,), np.float32)
    for i in range(m):
        pi = jax.tree_util.tree_map(lambda l: l[i], prev)
        prev_losses[i] = float(val_loss_fn(pi, fed.x_val[i:i + 1],
                                           fed.y_val[i:i + 1])[0])
    dist = np.asarray(jnp.linalg.norm(
        flat[None, :, :] - flat_prev[:, None, :], axis=-1)) + 1e-9
    wmat = np.maximum((prev_losses[:, None] - losses) / dist, 0.0)
    # keep top candidates per client (paper samples M models)
    if n_candidates < m:
        thresh = np.sort(wmat, axis=1)[:, -n_candidates][:, None]
        wmat = np.where(wmat >= thresh, wmat, 0.0)
    rows = wmat.sum(1, keepdims=True)
    wmat = np.where(rows > 0, wmat / np.maximum(rows, 1e-9), 0.0)
    wj = jnp.asarray(wmat)
    # θ_i ← θ_i^prev + Σ_j w_ij (θ_j − θ_i^prev)
    mixed = user_centric_aggregate(stacked, wj)
    keep = jnp.asarray(1.0 - wmat.sum(1))
    return jax.tree_util.tree_map(
        lambda mx, pv: mx + keep.reshape((-1,) + (1,) * (pv.ndim - 1)) * pv,
        mixed, prev)
