"""Federated learning round engine: placement-generic, strategy-driven.

The engine owns the generic round mechanics — client sampling, the local
update, evaluation, the analytic clock — and delegates every
algorithm-specific decision to a `Strategy` (repro.fl.strategies) and
every layout decision to a `Placement` (repro.fl.placement):

    run_federated("ucfl_k3", fed)                          # spec string
    run_federated(strategy=get_strategy("ucfl_k3"), fed=fed)  # instance
    run_federated("ucfl_k3", fed,
                  placement=MeshShardMap(schedule="shard_map_streams"))

Registered strategies: fedavg | local | oracle | ucfl | ucfl_k<k> |
cfl (Sattler et al.) | fedfomo (Zhang et al.); see DESIGN.md §4–§5.

Placements (DESIGN.md §3): `HostVmap` (default — all clients stacked on
one device, paper-scale m=20..100) and `MeshShardMap` (clients sharded
over a device mesh, mixing via schedule-selected collectives).  The
mesh CLI `repro.launch.train` drives this same engine.

Passing ``async_cfg=AsyncConfig(...)`` delegates to the event-driven
buffered-async runtime (`repro.fl.runtime`, DESIGN.md §3a): same
strategies, same placements, virtual-clock time instead of the analytic
per-round maximum.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedData
from repro.fl.channel import (Channel, ChannelCost, resolve_channel,
                              round_downlink_time, tree_bits,
                              uplink_roundtrip, zeros_like_stack)
from repro.fl.comm import SYSTEMS, SystemModel
from repro.fl.faults import (FaultMeter, crash_mask, get_robust_aggregator,
                             inject_values, resolve_fault_plan,
                             resolve_faults, screen_and_defend)
from repro.fl.placement import (HostVmap, MeshShardMap,  # noqa: F401 (re-export)
                                Placement, evaluate, make_client_update,
                                reduce_scores, resolve_placement,
                                stack_params, where_clients)
from repro.fl.stats import full_client_gradients, sigma2_estimates  # noqa: F401 (re-exported for back-compat)
from repro.fl.strategies import (ClientSampler, CommCost, RoundContext,
                                 Strategy, StrategyExtras, TracedMix,
                                 get_strategy)
from repro.models import lenet


@dataclass
class FLConfig:
    local_steps: int = 10
    batch_size: int = 64
    lr: float = 0.1
    momentum: float = 0.9
    # optimizer-state dtype policy: None = fp32 state, "param" = keep
    # momentum in the param dtype (the giants' HBM-fit knob, DESIGN.md §4)
    opt_state_dtype: Optional[str] = None
    rounds: int = 60
    sigma_batches: int = 5
    eval_every: int = 5
    fomo_candidates: int = 5
    cfl_eps1: float = 0.04
    cfl_eps2: float = 0.06
    cfl_min_rounds: int = 10


# ---------------------------------------------------------------------------
# the round engine


def default_model_init(fed: FederatedData) -> Callable:
    """LeNet sized to the scenario's images — shared with the async engine
    so both runtimes build bit-identical initializations."""
    in_size, channels = fed.x.shape[2], fed.x.shape[4]
    n_classes = int(jnp.max(fed.y)) + 1
    return lambda k: lenet.init_params(
        k, lenet.LeNetConfig(in_size=in_size, in_channels=channels,
                             n_classes=max(n_classes, 10)))


def resolve_strategy(algorithm: Union[str, Strategy, None],
                     strategy: Optional[Strategy]) -> Strategy:
    """spec-string-or-instance -> Strategy (shared by both engines)."""
    if strategy is not None:
        if algorithm is not None:
            raise TypeError("pass either `algorithm` or `strategy=`, not both")
        return strategy
    if algorithm is None:
        raise TypeError("one of `algorithm` or `strategy=` is required")
    if isinstance(algorithm, Strategy):
        return algorithm
    return get_strategy(algorithm)


def init_run(strategy: Strategy, fed: FederatedData, fl: "FLConfig",
             model_init: Optional[Callable], loss_fn: Callable,
             acc_fn: Callable, placement: Placement, seed: int,
             donate: bool = False, hierarchy: Optional[Any] = None,
             system: Optional[SystemModel] = None,
             faults: Optional[Any] = None):
    """Shared run prologue for the sync and async engines: PRNG split,
    model init, cached update step, client stack/opt/data placement,
    RoundContext and `strategy.setup`.  Returns
    ``(key, vmapped_update, stacked, opt_state, data, ctx, state)``.

    With ``hierarchy`` (a resolved `HierarchyConfig`, DESIGN.md §3f) the
    update step becomes the fleet sub-round, the data grows the nested
    device axis and the opt-state slot carries the `EdgeState`; the
    resolved `FleetPlan` rides on ``ctx.hierarchy_plan`` for the engines'
    `EdgeMeter`.  ``system`` is consumed only there (the edge link
    resolves against it, like `init_channel`'s link).  ``faults`` (a
    `FaultConfig`/spec, DESIGN.md §3g) is resolved ONCE here into the
    run's `FaultPlan` — static Byzantine set, arrival-crash stream — and
    rides on ``ctx.fault_plan`` for the engines' injector/meter (the
    `FleetPlan` pattern; None keeps the plan off and the run on the
    faults-off parity path)."""
    m = fed.m
    key = jax.random.PRNGKey(seed)
    key, kinit = jax.random.split(key)
    if model_init is None:
        model_init = default_model_init(fed)
    params0 = model_init(kinit)
    if hierarchy is None:
        opt, vmapped_update = placement.build_update(loss_fn, fl,
                                                     donate=donate)
        stacked = placement.stack(params0, m)
        opt_state = placement.init_opt(opt, stacked)
        data = placement.place_data(fed)
        plan = None
    else:
        from repro.fl.hierarchy import init_fleet_run
        vmapped_update, stacked, opt_state, data, plan = init_fleet_run(
            hierarchy, placement, loss_fn, fl, fed, params0,
            system=system, donate=donate, strategy=strategy)

    ctx = RoundContext(fed=fed, fl=fl, loss_fn=loss_fn, acc_fn=acc_fn,
                       params0=params0, seed=seed, placement=placement,
                       strategy=strategy)
    ctx.hierarchy_plan = plan
    ctx.fault_plan = resolve_fault_plan(faults, m)
    state = strategy.setup(ctx)
    return key, vmapped_update, stacked, opt_state, data, ctx, state


def finalize_history(history: "History", strategy: Strategy, state: Any,
                     keep_state: bool, stacked: Any, opt_state: Any
                     ) -> "History":
    """Shared run epilogue: typed extras, the legacy extra dict, and the
    optional final device-resident state."""
    history.extras = strategy.extras(state)
    history.extra["comm_per_round"] = list(history.comm)
    if history.extras is not None:
        history.extra.update(dataclasses.asdict(history.extras))
    if keep_state:
        history.final_params, history.final_opt_state = stacked, opt_state
    return history


def init_channel(channel: Optional[Channel], ctx: "RoundContext",
                 stacked: Any, system: Optional[SystemModel], m: int):
    """Shared channel prologue for the sync and async engines (so their
    §3b semantics can't drift, like `init_run` for the round prologue):
    payload bits, resolved link profile and the error-feedback residual
    stack.  Returns ``(payload, link, model_bits, ef, channel)`` — all
    None/0 when no channel is attached.  The link is resolved FIRST
    (validating its spec even when no ``system`` will consume it, against
    the default wired model, so ``extra["channel"]`` records it
    consistently), then the codec is bound to it — rate-adaptive codecs
    pick their per-client parameters here, so callers must use the
    RETURNED channel from this point on."""
    if channel is None:
        return None, None, 0, None, None
    model_bits = tree_bits(ctx.params0)
    link = channel.resolve_link(system if system is not None
                                else SYSTEMS["wired"], model_bits, m)
    codec = channel.codec.bind_link(link, ctx.params0)
    if codec is not channel.codec:
        channel = dataclasses.replace(channel, codec=codec)
    ef = None if codec.is_identity else zeros_like_stack(stacked)
    payload = codec.payload_bits(ctx.params0)
    return payload, link, model_bits, ef, channel


def per_client_uplink_bits(channel: Optional[Channel], ctx: "RoundContext",
                           payload: Optional[int],
                           m: int) -> Optional[np.ndarray]:
    """(m,) per-client uplink payload vector when the bound codec's bits
    are NOT uniform (rate-adaptive codecs), else None — keeping the fixed-
    codec accounting on its exact scalar path."""
    if channel is None:
        return None
    vec = channel.codec.per_client_bits(ctx.params0, m)
    return None if np.all(vec == payload) else vec


def channel_uplink(placement: Placement, channel: Channel, stacked: Any,
                   prev: Any, ef: Any, kround, mask):
    """Shared per-round uplink crossing (lossy codecs only): both engines
    derive the codec key as ``fold_in(kround, 2)`` — index 1 is the
    strategies' derivation — and thread the EF residuals identically."""
    stacked, new_ef = placement.uplink(
        channel.codec, stacked, prev, ef, jax.random.fold_in(kround, 2),
        mask)
    return stacked, (new_ef if channel.error_feedback else ef)


def channel_extra(history: "History", channel: Channel, link,
                  model_bits: int, ul_payload: int) -> None:
    """Shared `History.extra["channel"]` record of a channel-carrying run
    (both engines): codec/link identity, per-payload bits and the run's
    cumulative bit totals (the §3b bits axes)."""
    history.extra["channel"] = {
        "codec": channel.codec.spec,
        "error_feedback": bool(channel.error_feedback),
        "link": link.name if link is not None else None,
        "model_bits": int(model_bits),
        "payload_bits": int(ul_payload),
        "dl_bits_total": int(sum(c.dl_bits for c in history.comm_bits)),
        "ul_bits_total": int(sum(c.ul_bits for c in history.comm_bits)),
    }


# ---------------------------------------------------------------------------
# superstep execution (DESIGN.md §3c): fuse eval_every rounds into one scan


def _mro_definer(cls: type, name: str) -> Optional[type]:
    """The class in ``cls``'s MRO that actually defines ``name``."""
    for c in cls.__mro__:
        if name in vars(c):
            return c
    return None


def superstep_support(strategy: Strategy,
                      sampler: Optional[ClientSampler],
                      hierarchy: Optional[Any] = None) -> tuple:
    """(ok, reason) — whether this run qualifies for the fused superstep.

    Strategy and sampler must declare the traceability contract; every
    registered codec's ``roundtrip`` is already a pure traced function, so
    a `Channel` never blocks fusion.  A subclass of a traceable strategy
    that overrides the eventful hooks (``aggregate``/``reweight``)
    WITHOUT re-implementing ``aggregate_traced`` would silently fuse with
    the parent's traced rule — detected here and routed to the eventful
    loop instead."""
    if not strategy.traceable:
        return False, (f"strategy {strategy.spec!r} is not traceable "
                       "(eventful per-round state)")
    cls = type(strategy)
    traced_at = _mro_definer(cls, "aggregate_traced")
    for name in ("aggregate", "reweight"):
        at = _mro_definer(cls, name)
        if at is not Strategy and not issubclass(traced_at, at):
            return False, (
                f"{cls.__name__} overrides {name}() below the class "
                f"defining aggregate_traced ({traced_at.__name__}); the "
                "traced path would silently diverge — override "
                "aggregate_traced too (or set traceable=False)")
    if sampler is not None and not sampler.traceable:
        return False, (f"sampler {type(sampler).__name__} does not "
                       "implement sample_traced")
    if hierarchy is not None:
        agg = hierarchy.edge_aggregator
        if not agg.traceable:
            return False, (f"edge aggregator {agg.spec!r} is not traceable "
                           "(host-side edge weighting, DESIGN.md §3f)")
    return True, ""


# compiled supersteps, shared across `run_federated` calls: key ->
# {scan length -> jitted superstep}.  The key captures everything the
# trace closes over (the cached update step object carries the
# loss_fn/FLConfig identity; strategy and sampler contribute their
# spec-level identities; the placement its mesh/schedule; `acc_fn` the
# fused chunk-end eval) — but NOT the client count: the traced round
# derives m from the data shapes, so the jit wrapper re-specializes per
# COHORT SHAPE on its own and one cache entry serves every population
# size (the paging engine's executable-reuse contract, DESIGN.md §3e).
# Bounded like the neighboring executable caches (`cached_update`,
# `_uplink_fn`): oldest config evicted past the cap, so sweep processes
# iterating many (scenario × algorithm × codec) configs don't pin
# executables forever.
_SUPERSTEP_FNS: Dict[tuple, Dict[int, Callable]] = {}
_SUPERSTEP_CACHE_MAX = 32


def _superstep_cache(placement: Placement, strategy: Strategy,
                     sampler: Optional[ClientSampler],
                     codec, error_feedback: bool, update_fn: Callable,
                     acc_fn: Callable, fault_cfg: Optional[Any] = None,
                     robust_spec: Optional[str] = None,
                     min_quorum: Optional[int] = None) -> Dict[int, Callable]:
    # fault/defense/quorum identity is part of the key: the cached jitted
    # superstep wraps the FIRST round_fn seen for a key, and the fault
    # injector/defense/quorum gate are traced INTO that round (§3g)
    key = (placement.cache_key(), type(strategy), strategy.spec,
           None if sampler is None else sampler.cache_key,
           codec, bool(error_feedback), update_fn, acc_fn,
           fault_cfg, robust_spec, min_quorum)
    cache = _SUPERSTEP_FNS.pop(key, None)   # re-insert: LRU, not FIFO
    if cache is None:
        while len(_SUPERSTEP_FNS) >= _SUPERSTEP_CACHE_MAX:
            _SUPERSTEP_FNS.pop(next(iter(_SUPERSTEP_FNS)))
        cache = {}
    _SUPERSTEP_FNS[key] = cache
    return cache


def _build_traced_round(strategy: Strategy, sampler: Optional[ClientSampler],
                        codec, error_feedback: bool, placement: Placement,
                        update_fn: Callable, fault_plan: Optional[Any] = None,
                        defense: Optional[Any] = None,
                        min_quorum: Optional[int] = None) -> Callable:
    """The fused round: (local update → sampler select → fault injection →
    codec uplink with error feedback → screening/robust defense →
    strategy aggregate → quorum gate) as one pure function

        round_fn((key, stacked, opt_state, ef), (x, y, n), consts)
            -> ((key', stacked', opt_state', ef'), (mask, crash, quarantine))

    with EXACTLY the eventful engine's key derivation — ``ksample`` split
    first (stochastic samplers only), then ``kround``; per-client batch
    keys are ``split(kround, m)``, the codec key ``fold_in(kround, 2)``
    (index 1 stays reserved for the strategies' derivation, index 3 for
    the fault injector) — so the fused run is bit-identical to the
    per-round loop.  The client count m comes from the traced data
    shapes, NOT from the builder: one round_fn (and so one cached
    superstep) serves every cohort size, which is what lets the paging
    engine (DESIGN.md §3e) reuse executables across populations.

    With ``fault_plan`` (DESIGN.md §3g) ``consts`` is the pair
    ``(strategy_consts, byz_row)`` — the static adversary row rides as a
    traced input so per-cohort rows never retrace.  Crash rolls the row
    back exactly like a sampler no-show; the other faults corrupt what
    the row TRANSMITS.  ``min_quorum`` snapshots the clients' own models
    before the uplink and discards the mixed result when too few rows
    participated (the round's uploads are wasted; the server state
    carries forward).  All three knobs off is byte-for-byte the
    pre-faults trace — the parity anchor."""
    tmix = TracedMix(placement)
    lossy = codec is not None and not codec.is_identity
    backend = placement.codec_backend
    faulted = fault_plan is not None

    def round_fn(carry, data, consts):
        if faulted:
            consts, byz_row = consts
        key, stacked, opt_state, ef = carry
        x, y, n = data
        m = x.shape[0]      # static under trace: the cohort shape
        ksample = None
        if sampler is not None and sampler.needs_key:
            key, ksample = jax.random.split(key)
        key, kround = jax.random.split(key)
        ckeys = jax.random.split(kround, m)
        prev, prev_opt = stacked, opt_state
        stacked, opt_state = update_fn(stacked, opt_state, x, y, n, ckeys)
        mask = None
        if sampler is not None:
            # all-True where the eventful sampler would return None: the
            # row-select below is then a bitwise identity.  Through the
            # placement's `select` hook (pure on both backends) so a
            # backend overriding rollback keeps working under fusion.
            mask = sampler.sample_traced(ksample, m)
            stacked = placement.select(mask, stacked, prev)
            opt_state = placement.select(mask, opt_state, prev_opt)
        crash = None
        if faulted:
            kfault = jax.random.fold_in(kround, 3)
            if fault_plan.value_faults:
                stacked = inject_values(fault_plan, byz_row, stacked, prev,
                                        kfault, rows=mask)
            crash = crash_mask(fault_plan, kfault, m)
            if crash is not None:
                # a crashed client never reports: row rollback, exactly a
                # sampler no-show
                stacked = placement.select(~crash, stacked, prev)
                opt_state = placement.select(~crash, opt_state, prev_opt)
        part = mask
        if crash is not None:
            part = ~crash if part is None else part & ~crash
        # quorum snapshot: the clients' own post-update models BEFORE the
        # uplink — on a skipped round each keeps what it computed
        clients = stacked if min_quorum is not None else None
        if lossy:
            new_stacked, new_ef = uplink_roundtrip(
                codec, stacked, prev, ef, jax.random.fold_in(kround, 2),
                part, backend=backend)
            stacked = new_stacked
            ef = new_ef if error_feedback else ef
        q = None
        if defense is not None:
            stacked, q = screen_and_defend(defense, stacked, prev)
            tmix.quarantine = q
        stacked = strategy.aggregate_traced(consts, stacked, prev, tmix)
        tmix.quarantine = None
        if min_quorum is not None:
            count = (jnp.float32(m) if part is None
                     else jnp.sum(part.astype(jnp.float32)))
            ok = count >= jnp.float32(min_quorum)
            stacked = jax.tree_util.tree_map(
                lambda a, b: jnp.where(ok, a, b), stacked, clients)
        return (key, stacked, opt_state, ef), (mask, crash, q)

    return round_fn


def _eval_rounds(rounds: int, eval_every: int):
    """The eventful engine's eval boundaries (``rnd % eval_every == 0 or
    rnd == rounds - 1``) as consecutive chunk ends: yields the round index
    each superstep runs up to (inclusive)."""
    rnd = 0
    while rnd < rounds:
        nxt = min(((rnd + eval_every - 1) // eval_every) * eval_every,
                  rounds - 1)
        yield rnd, nxt
        rnd = nxt + 1


def charge_round(history: "History", cost: CommCost, mask_np, m: int,
                 payload: int, link, system: Optional[SystemModel],
                 channel: Optional[Channel], t_accum: float,
                 assignment: Optional[np.ndarray] = None,
                 ul_bits_pc: Optional[np.ndarray] = None,
                 edge: Optional[Any] = None) -> float:
    """One round's comm/bits/clock accounting, SHARED by the eventful loop
    and the superstep replay so the two engines can't drift (like
    `init_run`/`init_channel` for the prologue).  ``mask_np`` is the
    HOST-side participation row (None or all-True = full cohort — the
    eventful sampler returns None there); returns the updated clock.
    ``assignment`` is the strategy's client→stream map (membership-aware
    broadcast charging, None = legacy cohort-slowest upper bound);
    ``ul_bits_pc`` the (m,) per-client uplink payload vector (rate-
    adaptive codecs; None = uniform ``payload`` per client); ``edge`` the
    hierarchy tier's `EdgeMeter` (DESIGN.md §3f) — the device→user hop's
    bits land in its own books every round and its time (slowest
    participating user's edge sub-round) is added to the clock whenever a
    ``system`` runs one."""
    history.comm.append(cost)
    n_part, participants = m, None
    if channel is not None or system is not None or edge is not None:
        # the round only waits for the clients that computed: H_|S| under
        # partial participation, not H_m
        if mask_np is not None and not mask_np.all():
            n_part = int(mask_np.sum())
            participants = np.where(mask_np)[0]
    if channel is not None:
        # downlink streams move the codec-compressed model (§3b)
        if ul_bits_pc is None:
            ul_bits = n_part * payload
        else:
            idx = participants if participants is not None else slice(None)
            ul_bits = int(np.sum(ul_bits_pc[idx]))
        history.comm_bits.append(ChannelCost(
            dl_bits=(cost.n_streams + cost.n_unicasts) * payload,
            ul_bits=ul_bits))
    if system is not None:
        if link is not None:
            ul = payload if ul_bits_pc is None else ul_bits_pc
            t_accum += (system.compute_time(n_part)
                        + link.max_uplink_time(ul, participants)
                        + round_downlink_time(link, cost, payload,
                                              participants, assignment))
        else:
            t_accum += system.round_time(n_part, n_streams=cost.n_streams,
                                         n_unicasts=cost.n_unicasts)
    if edge is not None:
        t_edge = edge.charge(mask_np)
        if system is not None:
            t_accum += t_edge
    return t_accum


@dataclass
class History:
    rounds: List[int] = field(default_factory=list)
    mean_acc: List[float] = field(default_factory=list)
    worst_acc: List[float] = field(default_factory=list)
    time: List[float] = field(default_factory=list)
    comm: List[CommCost] = field(default_factory=list)
    # bits-based sibling of `comm`, one entry per round — populated only
    # when the run carries a Channel (DESIGN.md §3b)
    comm_bits: List[ChannelCost] = field(default_factory=list)
    extras: Optional[StrategyExtras] = None
    # legacy mapping view, filled by the engine from `comm` + `extras`;
    # a real dict so pre-redesign callers that annotate it keep working
    extra: Dict[str, Any] = field(default_factory=dict)
    # populated when run_federated(keep_state=True): the final client-
    # stacked params / optimizer state (still device-resident)
    final_params: Any = None
    final_opt_state: Any = None


class NonFiniteEvalWarning(RuntimeWarning):
    """A recorded eval score was NaN/Inf — the run diverged."""


def record_eval(history: "History", rnd: int, mean_acc: float,
                worst_acc: float, t_accum: float) -> None:
    """Shared eval bookkeeping for every engine: appends one eval row and
    guards the scores — a NaN/Inf accuracy warns `NonFiniteEvalWarning`
    loudly (so diverged runs fail CI benches instead of silently charting
    garbage) and is booked under ``History.extra["nonfinite_evals"]``.
    Undefended NaN fault injection (DESIGN.md §3g) trips this; the
    screening defense keeps scores finite."""
    if not (np.isfinite(mean_acc) and np.isfinite(worst_acc)):
        warnings.warn(
            f"non-finite eval at round {rnd}: mean_acc={mean_acc}, "
            f"worst_acc={worst_acc} — the run diverged (NaN/Inf client "
            "updates reached aggregation; a robust_agg/screening defense "
            "would quarantine them, DESIGN.md §3g)",
            NonFiniteEvalWarning, stacklevel=2)
        history.extra["nonfinite_evals"] = (
            history.extra.get("nonfinite_evals", 0) + 1)
    history.rounds.append(rnd)
    history.mean_acc.append(mean_acc)
    history.worst_acc.append(worst_acc)
    history.time.append(t_accum)


def _run_superstep(strategy: Strategy, fed: FederatedData, *,
                   sampler: Optional[ClientSampler], fl: "FLConfig",
                   model_init: Optional[Callable], loss_fn: Callable,
                   acc_fn: Callable, system: Optional[SystemModel],
                   placement: Placement, channel: Optional[Channel],
                   keep_state: bool, seed: int,
                   hierarchy: Optional[Any] = None,
                   faults: Optional[Any] = None,
                   robust_agg: Optional[str] = None,
                   min_quorum: Optional[int] = None) -> "History":
    """Scan-compiled sync run (DESIGN.md §3c): Python re-enters only at
    eval boundaries; per-round participation masks come back as ONE
    stacked device->host transfer per superstep, the chunk-end eval runs
    INSIDE the compiled superstep (fused onto the end of the scan — no
    separate eval dispatch on the hot path), and the clock/CommCost/
    ChannelCost accounting is replayed host-side in the eventful engine's
    exact per-round order (bit-identical histories).  The fault injector,
    defense layer and quorum gate (DESIGN.md §3g) trace into the same
    scan; their per-round crash/quarantine rows ride the superstep outs
    next to the masks and are replayed into the `FaultMeter` here."""
    m = fed.m
    key, update_fn, stacked, opt_state, data, ctx, state = init_run(
        strategy, fed, fl, model_init, loss_fn, acc_fn, placement, seed,
        donate=False,   # donation happens at the superstep boundary instead
        hierarchy=hierarchy, system=system, faults=faults)
    plan = ctx.fault_plan
    defense = get_robust_aggregator(robust_agg)
    robust_spec = "none" if defense is None else str(robust_agg)
    meter = None
    if hierarchy is not None:
        from repro.fl.hierarchy import EdgeMeter
        meter = EdgeMeter(ctx.hierarchy_plan)
    fmeter = None
    if plan is not None or defense is not None or min_quorum is not None:
        fmeter = FaultMeter(plan, robust_spec, min_quorum)
    payload, link, model_bits, ef, channel = init_channel(
        channel, ctx, stacked, system, m)
    lossy = channel is not None and not channel.codec.is_identity
    # identity codecs trace no uplink: normalize so channel-less and
    # identity-channel runs share one compiled superstep
    codec = channel.codec if lossy else None
    ef_flag = channel.error_feedback if lossy else True
    consts = strategy.traced_state(state)
    if plan is not None:
        # the static adversary row rides as a traced const input (§3g)
        consts = (consts, jnp.asarray(plan.byz_row()))
    round_fn = _build_traced_round(strategy, sampler, codec, ef_flag,
                                   placement, update_fn, fault_plan=plan,
                                   defense=defense, min_quorum=min_quorum)
    cache = _superstep_cache(placement, strategy, sampler, codec, ef_flag,
                             update_fn, acc_fn,
                             fault_cfg=None if plan is None else plan.cfg,
                             robust_spec=robust_spec, min_quorum=min_quorum)
    eval_fn = lambda st, ed: placement.eval_traced(acc_fn, st, ed[0], ed[1])
    cost = strategy.comm(state)     # round-constant by the traceability
    history = History()             # contract (state never changes)
    assignment = strategy.membership(state)      # round-constant too
    ul_bits_pc = per_client_uplink_bits(channel, ctx, payload, m)
    t_accum = 0.0
    carry = (key, stacked, opt_state, ef if lossy else None)

    for rnd, nxt in _eval_rounds(fl.rounds, fl.eval_every):
        length = nxt - rnd + 1
        carry, outs, accs = placement.run_supersteps(
            round_fn, carry, data, consts, length, cache=cache,
            eval_fn=eval_fn, eval_data=(fed.x_val, fed.y_val))
        masks, crashes, qs = outs
        # the chunk's ONE blocking device->host transfer — and only when a
        # clock, the bits axis or a meter actually consumes the masks
        masks_np = (np.asarray(masks)
                    if masks is not None
                    and (channel is not None or system is not None
                         or meter is not None or fmeter is not None)
                    else None)
        crashes_np = None if crashes is None else np.asarray(crashes)
        qs_np = None if qs is None else np.asarray(qs)
        for i in range(length):
            mrow = None if masks_np is None else masks_np[i]
            crow = None if crashes_np is None else crashes_np[i]
            eff = mrow
            if crow is not None:
                eff = ~crow if eff is None else eff & ~crow
            n_eff = m if eff is None else int(eff.sum())
            ok = min_quorum is None or n_eff >= min_quorum
            # a quorum-skipped round moves no server model: no downlink
            # streams, no membership-aware broadcast — but the clients DID
            # compute and upload (eff mask → compute + uplink time accrue)
            t_accum = charge_round(
                history, cost if ok else CommCost(0, 0), eff, m, payload,
                link, system, channel, t_accum,
                assignment if ok else None, ul_bits_pc, meter)
            if fmeter is not None:
                qrow = None if qs_np is None else qs_np[i]
                rbits = qbits = 0
                if channel is not None:
                    rbits = (n_eff * payload if ul_bits_pc is None else
                             int(np.sum(ul_bits_pc[eff]) if eff is not None
                                 else np.sum(ul_bits_pc)))
                    if qrow is not None:
                        qbits = int(np.sum(qrow <= 0)) * payload
                fmeter.charge(crow, qrow, ok, rbits, qbits)
        mean_acc, worst_acc = reduce_scores(accs)
        record_eval(history, nxt, mean_acc, worst_acc, t_accum)

    _, stacked, opt_state, _ = carry
    history = finalize_history(history, strategy, state, keep_state,
                               stacked, opt_state)
    if meter is not None:
        history.extra["hierarchy"] = meter.extra()
    if fmeter is not None:
        history.extra["faults"] = fmeter.extra()
    if channel is not None:
        channel_extra(history, channel, link, model_bits, payload)
    return history


def run_federated(algorithm: Union[str, Strategy, None] = None,
                  fed: Optional[FederatedData] = None, *,
                  strategy: Optional[Strategy] = None,
                  sampler: Optional[ClientSampler] = None,
                  fl: Optional[FLConfig] = None,
                  model_init: Optional[Callable] = None,
                  loss_fn: Callable = lenet.loss_fn,
                  acc_fn: Callable = lenet.accuracy,
                  system: Optional[SystemModel] = None,
                  placement: Optional[Placement] = None,
                  channel: Union[str, Channel, None] = None,
                  keep_state: bool = False,
                  async_cfg: Optional[Any] = None,
                  superstep: Optional[bool] = None,
                  paging: Optional[Any] = None,
                  hierarchy: Optional[Any] = None,
                  faults: Optional[Any] = None,
                  robust_agg: Optional[str] = None,
                  min_quorum: Optional[int] = None,
                  seed: int = 0) -> History:
    """Run one strategy on one scenario; returns accuracy/time history.

    algorithm: a registry spec string (``"fedavg"``, ``"ucfl_k3"``, ...)
    or a `Strategy` instance; alternatively pass ``strategy=``.  ``sampler``
    selects per-round client participation (default: everyone).
    ``placement`` selects the client layout backend (default `HostVmap`,
    bit-identical to the pre-placement engine); ``keep_state=True``
    attaches the final stacked params / opt state to the History.
    ``channel`` (a `Channel` or codec spec string, DESIGN.md §3b) turns on
    bit-level payload accounting, uplink compression with error feedback
    and per-client link timing; ``Channel()``/None with the identity codec
    are bit-identical.  ``async_cfg`` (an `AsyncConfig`) switches to the
    event-driven buffered-async runtime (DESIGN.md §3a).  ``superstep``
    (DESIGN.md §3c) compiles ``eval_every`` consecutive rounds as one
    device-resident `lax.scan`: None (default) fuses exactly when
    strategy and sampler satisfy the traceability contract (bit-identical
    histories either way), False forces the eventful per-round loop, True
    raises if the configuration cannot fuse.  ``paging`` (a
    `PagingConfig`, DESIGN.md §3e) switches to the cohort paging engine:
    the full client population lives in a host-backed store and only one
    cohort is device-resident per superstep.  ``hierarchy`` (a
    `HierarchyConfig`, an int devices-per-user, or a fleet spec string —
    DESIGN.md §3f) nests an edge sub-round inside every round: each user
    aggregates its device fleet before the server sees it, both hops are
    charged, and the device→user hop's bits land in
    ``History.extra["hierarchy"]``.  ``faults`` (a `FaultConfig` or spec
    string like ``"crash:0.1,byz:0.25:sign_flip"``, DESIGN.md §3g)
    injects deterministic seeded client failures; ``robust_agg``
    (``none | clip:<c> | trimmed_mean:<f> | median | krum:<f>``) screens
    non-finite uploads and robustifies the aggregation against them;
    ``min_quorum`` skips aggregation on rounds where fewer clients
    participate (the server state carries forward).  All three default
    off and off is bit-identical to the pre-faults engine; the run's
    fault ledger lands in ``History.extra["faults"]``.
    """
    if min_quorum is not None:
        min_quorum = int(min_quorum)
        if min_quorum < 1:
            raise ValueError(f"min_quorum must be >= 1, got {min_quorum}")
    faults = resolve_faults(faults)     # validates the spec once, up front
    if hierarchy is not None:
        from repro.fl.hierarchy import resolve_hierarchy
        hierarchy = resolve_hierarchy(hierarchy)
    if async_cfg is not None:
        if sampler is not None:
            raise TypeError("the async runtime takes no ClientSampler — "
                            "the arrival buffer is the per-event cohort")
        if superstep:
            raise TypeError("superstep fusion is a synchronous-engine "
                            "feature; the async runtime is event-driven")
        from repro.fl.runtime import run_async
        return run_async(algorithm, fed, strategy=strategy,
                         async_cfg=async_cfg, fl=fl, model_init=model_init,
                         loss_fn=loss_fn, acc_fn=acc_fn, system=system,
                         placement=placement, channel=channel,
                         keep_state=keep_state, paging=paging,
                         hierarchy=hierarchy, faults=faults,
                         robust_agg=robust_agg, min_quorum=min_quorum,
                         seed=seed)
    if paging is not None:
        if hierarchy is not None:
            raise TypeError("the hierarchy tier does not compose with the "
                            "cohort paging engine yet (the store pages "
                            "flat client rows, not device fleets)")
        if superstep is False:
            raise TypeError("the paging engine runs fused supersteps only "
                            "(DESIGN.md §3e); superstep=False cannot page")
        from repro.fl.population import run_paged
        return run_paged(algorithm, fed, paging=paging, strategy=strategy,
                         sampler=sampler, fl=fl, model_init=model_init,
                         loss_fn=loss_fn, acc_fn=acc_fn, system=system,
                         placement=placement, channel=channel,
                         keep_state=keep_state, faults=faults,
                         robust_agg=robust_agg, min_quorum=min_quorum,
                         seed=seed)
    strategy = resolve_strategy(algorithm, strategy)
    if fed is None:
        raise TypeError("`fed` is required")
    fl = FLConfig() if fl is None else fl
    placement = resolve_placement(placement)
    channel = resolve_channel(channel)
    codec = channel.codec if channel is not None else None
    lossy = codec is not None and not codec.is_identity

    if superstep is None or superstep:
        ok, why = superstep_support(strategy, sampler, hierarchy=hierarchy)
        if not ok and superstep:
            raise ValueError(f"superstep=True but this run cannot fuse: "
                             f"{why}")
        if ok:
            return _run_superstep(strategy, fed, sampler=sampler, fl=fl,
                                  model_init=model_init, loss_fn=loss_fn,
                                  acc_fn=acc_fn, system=system,
                                  placement=placement, channel=channel,
                                  keep_state=keep_state,
                                  hierarchy=hierarchy, faults=faults,
                                  robust_agg=robust_agg,
                                  min_quorum=min_quorum, seed=seed)

    m = fed.m
    defense = get_robust_aggregator(robust_agg)
    # When no sampler can roll clients back and the strategy declares it
    # never reads `prev`, the update step may consume (donate) the old
    # stacked/opt buffers — peak memory drops from ~2× params+opt to ~1×.
    # A lossy codec reads `prev` too (the uplink transmits Δ = new − prev);
    # so do the fault injector and the screening defense (both work on
    # Δ = new − prev).  `min_quorum` alone stays donate-safe: its snapshot
    # is the post-update clients stack, never `prev`.
    donate = (sampler is None and not strategy.reads_prev and not lossy
              and faults is None and defense is None)
    key, vmapped_update, stacked, opt_state, (x, y, n), ctx, state = \
        init_run(strategy, fed, fl, model_init, loss_fn, acc_fn,
                 placement, seed, donate=donate, hierarchy=hierarchy,
                 system=system, faults=faults)
    plan = ctx.fault_plan
    robust_spec = "none" if defense is None else str(robust_agg)
    byz_row = None if plan is None else jnp.asarray(plan.byz_row())
    meter = None
    if hierarchy is not None:
        from repro.fl.hierarchy import EdgeMeter
        meter = EdgeMeter(ctx.hierarchy_plan)
    fmeter = None
    if plan is not None or defense is not None or min_quorum is not None:
        fmeter = FaultMeter(plan, robust_spec, min_quorum)

    payload, link, model_bits, ef, channel = init_channel(
        channel, ctx, stacked, system, m)
    ul_bits_pc = per_client_uplink_bits(channel, ctx, payload, m)

    history = History()
    t_accum = 0.0

    for rnd in range(fl.rounds):
        ksample = None
        if sampler is not None and sampler.needs_key:
            key, ksample = jax.random.split(key)
        key, kround = jax.random.split(key)
        ckeys = placement.place_keys(jax.random.split(kround, m))
        # donated buffers are dead after the update call: strategies that
        # declared reads_prev=False see prev=None
        prev, prev_opt = (None, None) if donate else (stacked, opt_state)
        stacked, opt_state = vmapped_update(stacked, opt_state, x, y, n,
                                            ckeys)

        mask = sampler.sample(rnd, m, ksample) if sampler is not None else None
        if mask is not None:
            # non-participants keep their pre-round model and optimizer
            stacked = placement.select(mask, stacked, prev)
            opt_state = placement.select(mask, opt_state, prev_opt)

        crash = None
        if plan is not None:
            # fault injection (DESIGN.md §3g): value faults corrupt what
            # the row transmits; crash rolls the row back like a no-show
            kfault = jax.random.fold_in(kround, 3)
            if plan.value_faults:
                stacked = inject_values(plan, byz_row, stacked, prev,
                                        kfault, rows=mask)
            crash = crash_mask(plan, kfault, m)
            if crash is not None:
                stacked = placement.select(~crash, stacked, prev)
                opt_state = placement.select(~crash, opt_state, prev_opt)
        part = mask
        if crash is not None:
            part = ~crash if part is None else part & ~crash
        # quorum snapshot: the clients' own post-update models BEFORE the
        # uplink — on a skipped round each keeps what it computed
        clients_snap = stacked if min_quorum is not None else None

        if lossy:
            # uplink channel crossing (DESIGN.md §3b): the server receives
            # the codec's decode(encode(Δ + residual))
            stacked, ef = channel_uplink(placement, channel, stacked, prev,
                                         ef, kround, part)

        q = None
        if defense is not None:
            # screening + robust aggregation (DESIGN.md §3g), before the
            # strategy's mixing — quarantined rows' deltas are zeroed and
            # their aggregation-weight columns renormalized away
            stacked, q = screen_and_defend(defense, stacked, prev)

        # ONE host sync per round at most (the mask pull), none when no
        # clock, bits axis or meter consumes it — n_part and the link-clock
        # participants both come from the same host-side array inside
        # `charge_round` (shared with the superstep replay).  The quorum
        # gate always needs the count, so it forces the pull.
        eff_np = (np.asarray(part)
                  if part is not None
                  and (channel is not None or system is not None
                       or meter is not None or fmeter is not None
                       or min_quorum is not None)
                  else None)
        n_eff = m if eff_np is None else int(eff_np.sum())
        ok = min_quorum is None or n_eff >= min_quorum
        if ok:
            # strategies get their own key derivation: kround's raw splits
            # are already consumed as the per-client minibatch keys
            ctx.rnd, ctx.key, ctx.participation = \
                rnd, jax.random.fold_in(kround, 1), part
            ctx.quarantine = q
            stacked, state = strategy.aggregate(state, stacked, prev, ctx)
            ctx.quarantine = None
        else:
            # below quorum: the mixed result never happens — every client
            # keeps its own pre-uplink model, the server state carries
            # forward, and the round's uploads are wasted
            stacked = clients_snap

        t_accum = charge_round(history,
                               strategy.comm(state) if ok else CommCost(0, 0),
                               eff_np, m, payload, link, system, channel,
                               t_accum,
                               strategy.membership(state) if ok else None,
                               ul_bits_pc, meter)
        if fmeter is not None:
            crow = None if crash is None else np.asarray(crash)
            qrow = None if q is None else np.asarray(q)
            rbits = qbits = 0
            if channel is not None:
                rbits = (n_eff * payload if ul_bits_pc is None else
                         int(np.sum(ul_bits_pc[eff_np])
                             if eff_np is not None else np.sum(ul_bits_pc)))
                if qrow is not None:
                    qbits = int(np.sum(qrow <= 0)) * payload
            fmeter.charge(crow, qrow, ok, rbits, qbits)

        if rnd % fl.eval_every == 0 or rnd == fl.rounds - 1:
            mean_acc, worst_acc = placement.evaluate(acc_fn, stacked, fed)
            record_eval(history, rnd, mean_acc, worst_acc, t_accum)

    history = finalize_history(history, strategy, state, keep_state,
                               stacked, opt_state)
    if meter is not None:
        history.extra["hierarchy"] = meter.extra()
    if fmeter is not None:
        history.extra["faults"] = fmeter.extra()
    if channel is not None:
        channel_extra(history, channel, link, model_bits, payload)
    return history
