"""Federated learning round engine: placement-generic, strategy-driven.

The engine owns the generic round mechanics — client sampling, the local
update, evaluation, the analytic clock — and delegates every
algorithm-specific decision to a `Strategy` (repro.fl.strategies) and
every layout decision to a `Placement` (repro.fl.placement):

    run_federated("ucfl_k3", fed)                          # spec string
    run_federated(strategy=get_strategy("ucfl_k3"), fed=fed)  # instance
    run_federated("ucfl_k3", fed,
                  placement=MeshShardMap(schedule="shard_map_streams"))

Registered strategies: fedavg | local | oracle | ucfl | ucfl_k<k> |
cfl (Sattler et al.) | fedfomo (Zhang et al.); see DESIGN.md §4–§5.

Placements (DESIGN.md §3): `HostVmap` (default — all clients stacked on
one device, paper-scale m=20..100) and `MeshShardMap` (clients sharded
over a device mesh, mixing via schedule-selected collectives).  The
mesh CLI `repro.launch.train` drives this same engine.

Passing ``async_cfg=AsyncConfig(...)`` delegates to the event-driven
buffered-async runtime (`repro.fl.runtime`, DESIGN.md §3a): same
strategies, same placements, virtual-clock time instead of the analytic
per-round maximum.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedData
from repro.fl.channel import (Channel, ChannelCost, resolve_channel,
                              round_downlink_time, tree_bits,
                              zeros_like_stack)
from repro.fl.comm import SYSTEMS, SystemModel
from repro.fl.placement import (HostVmap, MeshShardMap,  # noqa: F401 (re-export)
                                Placement, evaluate, make_client_update,
                                resolve_placement, stack_params,
                                where_clients)
from repro.fl.stats import full_client_gradients, sigma2_estimates  # noqa: F401 (re-exported for back-compat)
from repro.fl.strategies import (ClientSampler, CommCost, RoundContext,
                                 Strategy, StrategyExtras, get_strategy)
from repro.models import lenet


@dataclass
class FLConfig:
    local_steps: int = 10
    batch_size: int = 64
    lr: float = 0.1
    momentum: float = 0.9
    # optimizer-state dtype policy: None = fp32 state, "param" = keep
    # momentum in the param dtype (the giants' HBM-fit knob, DESIGN.md §4)
    opt_state_dtype: Optional[str] = None
    rounds: int = 60
    sigma_batches: int = 5
    eval_every: int = 5
    fomo_candidates: int = 5
    cfl_eps1: float = 0.04
    cfl_eps2: float = 0.06
    cfl_min_rounds: int = 10


# ---------------------------------------------------------------------------
# the round engine


def default_model_init(fed: FederatedData) -> Callable:
    """LeNet sized to the scenario's images — shared with the async engine
    so both runtimes build bit-identical initializations."""
    in_size, channels = fed.x.shape[2], fed.x.shape[4]
    n_classes = int(jnp.max(fed.y)) + 1
    return lambda k: lenet.init_params(
        k, lenet.LeNetConfig(in_size=in_size, in_channels=channels,
                             n_classes=max(n_classes, 10)))


def resolve_strategy(algorithm: Union[str, Strategy, None],
                     strategy: Optional[Strategy]) -> Strategy:
    """spec-string-or-instance -> Strategy (shared by both engines)."""
    if strategy is not None:
        if algorithm is not None:
            raise TypeError("pass either `algorithm` or `strategy=`, not both")
        return strategy
    if algorithm is None:
        raise TypeError("one of `algorithm` or `strategy=` is required")
    if isinstance(algorithm, Strategy):
        return algorithm
    return get_strategy(algorithm)


def init_run(strategy: Strategy, fed: FederatedData, fl: "FLConfig",
             model_init: Optional[Callable], loss_fn: Callable,
             acc_fn: Callable, placement: Placement, seed: int,
             donate: bool = False):
    """Shared run prologue for the sync and async engines: PRNG split,
    model init, cached update step, client stack/opt/data placement,
    RoundContext and `strategy.setup`.  Returns
    ``(key, vmapped_update, stacked, opt_state, data, ctx, state)``."""
    m = fed.m
    key = jax.random.PRNGKey(seed)
    key, kinit = jax.random.split(key)
    if model_init is None:
        model_init = default_model_init(fed)
    params0 = model_init(kinit)
    opt, vmapped_update = placement.build_update(loss_fn, fl, donate=donate)

    stacked = placement.stack(params0, m)
    opt_state = placement.init_opt(opt, stacked)
    data = placement.place_data(fed)

    ctx = RoundContext(fed=fed, fl=fl, loss_fn=loss_fn, acc_fn=acc_fn,
                       params0=params0, seed=seed, placement=placement,
                       strategy=strategy)
    state = strategy.setup(ctx)
    return key, vmapped_update, stacked, opt_state, data, ctx, state


def finalize_history(history: "History", strategy: Strategy, state: Any,
                     keep_state: bool, stacked: Any, opt_state: Any
                     ) -> "History":
    """Shared run epilogue: typed extras, the legacy extra dict, and the
    optional final device-resident state."""
    history.extras = strategy.extras(state)
    history.extra["comm_per_round"] = list(history.comm)
    if history.extras is not None:
        history.extra.update(dataclasses.asdict(history.extras))
    if keep_state:
        history.final_params, history.final_opt_state = stacked, opt_state
    return history


def init_channel(channel: Optional[Channel], ctx: "RoundContext",
                 stacked: Any, system: Optional[SystemModel], m: int):
    """Shared channel prologue for the sync and async engines (so their
    §3b semantics can't drift, like `init_run` for the round prologue):
    payload bits, resolved link profile and the error-feedback residual
    stack.  Returns ``(payload, link, model_bits, ef)`` — all None/0 when
    no channel is attached.  The link is resolved (validating its spec)
    even when no ``system`` will consume it, against the default wired
    model, so ``extra["channel"]`` records it consistently."""
    if channel is None:
        return None, None, 0, None
    codec = channel.codec
    ef = None if codec.is_identity else zeros_like_stack(stacked)
    model_bits = tree_bits(ctx.params0)
    payload = codec.payload_bits(ctx.params0)
    link = channel.resolve_link(system if system is not None
                                else SYSTEMS["wired"], model_bits, m)
    return payload, link, model_bits, ef


def channel_uplink(placement: Placement, channel: Channel, stacked: Any,
                   prev: Any, ef: Any, kround, mask):
    """Shared per-round uplink crossing (lossy codecs only): both engines
    derive the codec key as ``fold_in(kround, 2)`` — index 1 is the
    strategies' derivation — and thread the EF residuals identically."""
    stacked, new_ef = placement.uplink(
        channel.codec, stacked, prev, ef, jax.random.fold_in(kround, 2),
        mask)
    return stacked, (new_ef if channel.error_feedback else ef)


def channel_extra(history: "History", channel: Channel, link,
                  model_bits: int, ul_payload: int) -> None:
    """Shared `History.extra["channel"]` record of a channel-carrying run
    (both engines): codec/link identity, per-payload bits and the run's
    cumulative bit totals (the §3b bits axes)."""
    history.extra["channel"] = {
        "codec": channel.codec.spec,
        "error_feedback": bool(channel.error_feedback),
        "link": link.name if link is not None else None,
        "model_bits": int(model_bits),
        "payload_bits": int(ul_payload),
        "dl_bits_total": int(sum(c.dl_bits for c in history.comm_bits)),
        "ul_bits_total": int(sum(c.ul_bits for c in history.comm_bits)),
    }


@dataclass
class History:
    rounds: List[int] = field(default_factory=list)
    mean_acc: List[float] = field(default_factory=list)
    worst_acc: List[float] = field(default_factory=list)
    time: List[float] = field(default_factory=list)
    comm: List[CommCost] = field(default_factory=list)
    # bits-based sibling of `comm`, one entry per round — populated only
    # when the run carries a Channel (DESIGN.md §3b)
    comm_bits: List[ChannelCost] = field(default_factory=list)
    extras: Optional[StrategyExtras] = None
    # legacy mapping view, filled by the engine from `comm` + `extras`;
    # a real dict so pre-redesign callers that annotate it keep working
    extra: Dict[str, Any] = field(default_factory=dict)
    # populated when run_federated(keep_state=True): the final client-
    # stacked params / optimizer state (still device-resident)
    final_params: Any = None
    final_opt_state: Any = None


def run_federated(algorithm: Union[str, Strategy, None] = None,
                  fed: Optional[FederatedData] = None, *,
                  strategy: Optional[Strategy] = None,
                  sampler: Optional[ClientSampler] = None,
                  fl: Optional[FLConfig] = None,
                  model_init: Optional[Callable] = None,
                  loss_fn: Callable = lenet.loss_fn,
                  acc_fn: Callable = lenet.accuracy,
                  system: Optional[SystemModel] = None,
                  placement: Optional[Placement] = None,
                  channel: Union[str, Channel, None] = None,
                  keep_state: bool = False,
                  async_cfg: Optional[Any] = None,
                  seed: int = 0) -> History:
    """Run one strategy on one scenario; returns accuracy/time history.

    algorithm: a registry spec string (``"fedavg"``, ``"ucfl_k3"``, ...)
    or a `Strategy` instance; alternatively pass ``strategy=``.  ``sampler``
    selects per-round client participation (default: everyone).
    ``placement`` selects the client layout backend (default `HostVmap`,
    bit-identical to the pre-placement engine); ``keep_state=True``
    attaches the final stacked params / opt state to the History.
    ``channel`` (a `Channel` or codec spec string, DESIGN.md §3b) turns on
    bit-level payload accounting, uplink compression with error feedback
    and per-client link timing; ``Channel()``/None with the identity codec
    are bit-identical.  ``async_cfg`` (an `AsyncConfig`) switches to the
    event-driven buffered-async runtime (DESIGN.md §3a).
    """
    if async_cfg is not None:
        if sampler is not None:
            raise TypeError("the async runtime takes no ClientSampler — "
                            "the arrival buffer is the per-event cohort")
        from repro.fl.runtime import run_async
        return run_async(algorithm, fed, strategy=strategy,
                         async_cfg=async_cfg, fl=fl, model_init=model_init,
                         loss_fn=loss_fn, acc_fn=acc_fn, system=system,
                         placement=placement, channel=channel,
                         keep_state=keep_state, seed=seed)
    strategy = resolve_strategy(algorithm, strategy)
    if fed is None:
        raise TypeError("`fed` is required")
    fl = FLConfig() if fl is None else fl
    placement = resolve_placement(placement)
    channel = resolve_channel(channel)
    codec = channel.codec if channel is not None else None
    lossy = codec is not None and not codec.is_identity

    m = fed.m
    # When no sampler can roll clients back and the strategy declares it
    # never reads `prev`, the update step may consume (donate) the old
    # stacked/opt buffers — peak memory drops from ~2× params+opt to ~1×.
    # A lossy codec reads `prev` too (the uplink transmits Δ = new − prev).
    donate = sampler is None and not strategy.reads_prev and not lossy
    key, vmapped_update, stacked, opt_state, (x, y, n), ctx, state = \
        init_run(strategy, fed, fl, model_init, loss_fn, acc_fn,
                 placement, seed, donate=donate)

    payload, link, model_bits, ef = init_channel(channel, ctx, stacked,
                                                 system, m)

    history = History()
    t_accum = 0.0

    for rnd in range(fl.rounds):
        ksample = None
        if sampler is not None and sampler.needs_key:
            key, ksample = jax.random.split(key)
        key, kround = jax.random.split(key)
        ckeys = placement.place_keys(jax.random.split(kround, m))
        # donated buffers are dead after the update call: strategies that
        # declared reads_prev=False see prev=None
        prev, prev_opt = (None, None) if donate else (stacked, opt_state)
        stacked, opt_state = vmapped_update(stacked, opt_state, x, y, n,
                                            ckeys)

        mask = sampler.sample(rnd, m, ksample) if sampler is not None else None
        if mask is not None:
            # non-participants keep their pre-round model and optimizer
            stacked = placement.select(mask, stacked, prev)
            opt_state = placement.select(mask, opt_state, prev_opt)

        if lossy:
            # uplink channel crossing (DESIGN.md §3b): the server receives
            # the codec's decode(encode(Δ + residual))
            stacked, ef = channel_uplink(placement, channel, stacked, prev,
                                         ef, kround, mask)

        # strategies get their own key derivation: kround's raw splits are
        # already consumed as the per-client minibatch keys
        ctx.rnd, ctx.key, ctx.participation = \
            rnd, jax.random.fold_in(kround, 1), mask
        stacked, state = strategy.aggregate(state, stacked, prev, ctx)

        cost = strategy.comm(state)
        history.comm.append(cost)
        if channel is not None or system is not None:
            # the round only waits for the clients that computed: H_|S|
            # under partial participation, not H_m (host-synced only when
            # a clock or the bits axis consumes it)
            n_part = m if mask is None else int(jnp.sum(mask))
        if channel is not None:
            # downlink streams move the codec-compressed model (§3b)
            history.comm_bits.append(ChannelCost(
                dl_bits=(cost.n_streams + cost.n_unicasts) * payload,
                ul_bits=n_part * payload))
        if system is not None:
            if link is not None:
                participants = (None if mask is None
                                else np.where(np.asarray(mask))[0])
                t_accum += (system.compute_time(n_part)
                            + link.max_uplink_time(payload, participants)
                            + round_downlink_time(link, cost, payload,
                                                       participants))
            else:
                t_accum += system.round_time(n_part,
                                             n_streams=cost.n_streams,
                                             n_unicasts=cost.n_unicasts)

        if rnd % fl.eval_every == 0 or rnd == fl.rounds - 1:
            mean_acc, worst_acc = placement.evaluate(acc_fn, stacked, fed)
            history.rounds.append(rnd)
            history.mean_acc.append(mean_acc)
            history.worst_acc.append(worst_acc)
            history.time.append(t_accum)

    history = finalize_history(history, strategy, state, keep_state,
                               stacked, opt_state)
    if channel is not None:
        channel_extra(history, channel, link, model_bits, payload)
    return history
