"""Federated learning round engine: vmap-over-clients, strategy-driven.

The engine owns the generic round mechanics — client sampling, vmapped
local SGD, evaluation, the analytic clock — and delegates every
algorithm-specific decision to a `Strategy` (repro.fl.strategies):

    run_federated("ucfl_k3", fed)                          # spec string
    run_federated(strategy=get_strategy("ucfl_k3"), fed=fed)  # instance

Registered strategies: fedavg | local | oracle | ucfl | ucfl_k<k> |
cfl (Sattler et al.) | fedfomo (Zhang et al.); see DESIGN.md §4–§5.

Client placement here is the host `vmap` mode of DESIGN.md §3 (paper-scale
m=20..100, LeNet).  The mesh-placed variants live in repro/launch.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.data.federated import FederatedData
from repro.fl.comm import SystemModel
from repro.fl.stats import full_client_gradients, sigma2_estimates  # noqa: F401 (re-exported for back-compat)
from repro.fl.strategies import (ClientSampler, CommCost, RoundContext,
                                 Strategy, StrategyExtras, get_strategy)
from repro.models import lenet
from repro.optim import apply_updates, sgd


@dataclass
class FLConfig:
    local_steps: int = 10
    batch_size: int = 64
    lr: float = 0.1
    momentum: float = 0.9
    rounds: int = 60
    sigma_batches: int = 5
    eval_every: int = 5
    fomo_candidates: int = 5
    cfl_eps1: float = 0.04
    cfl_eps2: float = 0.06
    cfl_min_rounds: int = 10


# ---------------------------------------------------------------------------
# building blocks


def make_client_update(loss_fn: Callable, opt, fl: FLConfig):
    """Returns f(params_i, opt_i, data_i, n_i, key) -> (params_i', opt_i')
    running `local_steps` SGD steps on mini-batches drawn from client i."""

    def client_update(params_i, opt_i, x_i, y_i, n_i, key):
        n_slots = x_i.shape[0]

        def step(carry, k):
            p, o = carry
            idx = jax.random.randint(k, (fl.batch_size,), 0, 1 << 30) % \
                jnp.maximum(n_i.astype(jnp.int32), 1)
            idx = idx % n_slots
            batch = {"x": x_i[idx], "y": y_i[idx]}
            grads, _ = jax.grad(loss_fn, has_aux=True)(p, batch)
            upd, o = opt.update(grads, o, p)
            return (apply_updates(p, upd), o), None

        keys = jax.random.split(key, fl.local_steps)
        (p, o), _ = jax.lax.scan(step, (params_i, opt_i), keys)
        return p, o

    return client_update


def _stack(params, m: int):
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (m,) + l.shape).copy(), params)


def _where_clients(mask: jnp.ndarray, new, old):
    """Per-client select over stacked pytrees (leading dim m)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(mask.reshape((-1,) + (1,) * (a.ndim - 1)),
                               a, b), new, old)


@functools.lru_cache(maxsize=8)
def _eval_fn(apply_acc: Callable):
    return jax.jit(jax.vmap(lambda p, x, y: apply_acc(p, {"x": x, "y": y})))


def evaluate(apply_acc: Callable, stacked_params, fed: FederatedData
             ) -> Tuple[float, float]:
    """(mean, worst) validation accuracy across clients, personalized models."""
    accs = _eval_fn(apply_acc)(stacked_params, fed.x_val, fed.y_val)
    return float(jnp.mean(accs)), float(jnp.min(accs))


# ---------------------------------------------------------------------------
# the round engine


@dataclass
class History:
    rounds: List[int] = field(default_factory=list)
    mean_acc: List[float] = field(default_factory=list)
    worst_acc: List[float] = field(default_factory=list)
    time: List[float] = field(default_factory=list)
    comm: List[CommCost] = field(default_factory=list)
    extras: Optional[StrategyExtras] = None
    # legacy mapping view, filled by the engine from `comm` + `extras`;
    # a real dict so pre-redesign callers that annotate it keep working
    extra: Dict[str, Any] = field(default_factory=dict)


def run_federated(algorithm: Union[str, Strategy, None] = None,
                  fed: Optional[FederatedData] = None, *,
                  strategy: Optional[Strategy] = None,
                  sampler: Optional[ClientSampler] = None,
                  fl: Optional[FLConfig] = None,
                  model_init: Optional[Callable] = None,
                  loss_fn: Callable = lenet.loss_fn,
                  acc_fn: Callable = lenet.accuracy,
                  system: Optional[SystemModel] = None,
                  seed: int = 0) -> History:
    """Run one strategy on one scenario; returns accuracy/time history.

    algorithm: a registry spec string (``"fedavg"``, ``"ucfl_k3"``, ...)
    or a `Strategy` instance; alternatively pass ``strategy=``.  ``sampler``
    selects per-round client participation (default: everyone).
    """
    if strategy is not None:
        if algorithm is not None:
            raise TypeError("pass either `algorithm` or `strategy=`, not both")
    elif algorithm is None:
        raise TypeError("one of `algorithm` or `strategy=` is required")
    elif isinstance(algorithm, Strategy):
        strategy = algorithm
    else:
        strategy = get_strategy(algorithm)
    if fed is None:
        raise TypeError("`fed` is required")
    fl = FLConfig() if fl is None else fl

    m = fed.m
    key = jax.random.PRNGKey(seed)
    key, kinit = jax.random.split(key)
    if model_init is None:
        in_size, channels = fed.x.shape[2], fed.x.shape[4]
        n_classes = int(jnp.max(fed.y)) + 1
        model_init = lambda k: lenet.init_params(
            k, lenet.LeNetConfig(in_size=in_size, in_channels=channels,
                                 n_classes=max(n_classes, 10)))
    params0 = model_init(kinit)
    opt = sgd(fl.lr, momentum=fl.momentum)
    client_update = make_client_update(loss_fn, opt, fl)
    vmapped_update = jax.jit(jax.vmap(client_update))

    stacked = _stack(params0, m)
    opt_state = jax.vmap(opt.init)(stacked)

    ctx = RoundContext(fed=fed, fl=fl, loss_fn=loss_fn, acc_fn=acc_fn,
                       params0=params0, seed=seed)
    state = strategy.setup(ctx)

    history = History()
    t_accum = 0.0

    for rnd in range(fl.rounds):
        ksample = None
        if sampler is not None and sampler.needs_key:
            key, ksample = jax.random.split(key)
        key, kround = jax.random.split(key)
        ckeys = jax.random.split(kround, m)
        prev, prev_opt = stacked, opt_state
        stacked, opt_state = vmapped_update(stacked, opt_state, fed.x, fed.y,
                                            fed.n, ckeys)

        mask = sampler.sample(rnd, m, ksample) if sampler is not None else None
        if mask is not None:
            # non-participants keep their pre-round model and optimizer
            stacked = _where_clients(mask, stacked, prev)
            opt_state = _where_clients(mask, opt_state, prev_opt)

        # strategies get their own key derivation: kround's raw splits are
        # already consumed as the per-client minibatch keys
        ctx.rnd, ctx.key, ctx.participation = \
            rnd, jax.random.fold_in(kround, 1), mask
        stacked, state = strategy.aggregate(state, stacked, prev, ctx)

        cost = strategy.comm(state)
        history.comm.append(cost)
        if system is not None:
            t_accum += system.round_time(m, n_streams=cost.n_streams,
                                         n_unicasts=cost.n_unicasts)

        if rnd % fl.eval_every == 0 or rnd == fl.rounds - 1:
            mean_acc, worst_acc = evaluate(acc_fn, stacked, fed)
            history.rounds.append(rnd)
            history.mean_acc.append(mean_acc)
            history.worst_acc.append(worst_acc)
            history.time.append(t_accum)

    history.extras = strategy.extras(state)
    history.extra["comm_per_round"] = list(history.comm)
    if history.extras is not None:
        history.extra.update(dataclasses.asdict(history.extras))
    return history
