"""Placement protocol: where clients live and how their models move.

A `Placement` owns everything about the *physical* layout of a federated
round (DESIGN.md §3): stacking the common initialization into the
client-stacked pytree, building the (cached, jitted) local-update step,
placing the client datasets and per-round PRNG keys, rolling back
non-participants, applying a mixing matrix `W` or a `StreamPlan`, and
evaluating the personalized models.  Strategies (DESIGN.md §4) stay
placement-agnostic: they route every matrix/plan application through
`RoundContext.mix` / `RoundContext.mix_plan`, which dispatch here.

Two backends ship:

  * `HostVmap`   — all clients in one stacked pytree on the default
    device; local updates are one `jit(vmap(client_update))`.  Bit-for-bit
    the pre-placement `run_federated` semantics.
  * `MeshShardMap` — clients sharded over a device mesh axis; the mixing
    becomes explicit collectives (GSPMD einsum or hand-scheduled
    `shard_map`, selected by `schedule=`).
"""
from __future__ import annotations

import abc
from typing import Any, Callable, ClassVar, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.streams import StreamPlan
from repro.data.federated import FederatedData


def stack_params(params: Any, m: int) -> Any:
    """Broadcast a single-model pytree to the (m, ...) client stack."""
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (m,) + l.shape).copy(), params)


def where_clients(mask: jnp.ndarray, new: Any, old: Any) -> Any:
    """Per-client select over stacked pytrees (leading dim m)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(mask.reshape((-1,) + (1,) * (a.ndim - 1)),
                               a, b), new, old)


class Placement(abc.ABC):
    """One client-placement backend; see module docstring."""

    name: ClassVar[str]

    # Which codec implementation the channel uplink (DESIGN.md §3b) runs
    # on this backend: "pallas" = the repro.kernels quantize / top-k
    # threshold kernels (single-device stacks); "jnp" = the pure-jnp
    # oracle math, which GSPMD shards over the client axis (bit-identical
    # for qsgd; top-k differs only in tie handling).
    codec_backend: ClassVar[str] = "pallas"

    @abc.abstractmethod
    def build_update(self, loss_fn: Callable, fl: Any, *,
                     donate: bool = False) -> Tuple[Any, Callable]:
        """Returns ``(opt, update_fn)`` where ``update_fn(stacked, opt_state,
        x, y, n, ckeys) -> (stacked', opt_state')`` runs every client's
        local SGD.  Implementations cache the jitted step across calls
        (sweeps re-enter `run_federated` with identical configs).
        ``donate=True`` donates the input stacked/opt buffers to the step
        (they are dead after the call) — the engine requests it when no
        sampler needs rollback and the strategy never reads `prev`."""

    @abc.abstractmethod
    def stack(self, params0: Any, m: int) -> Any:
        """Place the common initialization as the (m, ...) client stack."""

    def init_opt(self, opt: Any, stacked: Any) -> Any:
        return jax.vmap(opt.init)(stacked)

    def place_data(self, fed: FederatedData) -> Tuple[Any, Any, Any]:
        """Place the stacked client train arrays ``(x, y, n)``."""
        return fed.x, fed.y, fed.n

    def place_keys(self, ckeys: jnp.ndarray) -> jnp.ndarray:
        """Place the (m, 2) per-client round keys."""
        return ckeys

    def place_stack(self, tree: Any, m: int) -> Any:
        """Place an ALREADY-stacked (m, ...) pytree on this backend (the
        serving plane hands request batches / decoded parameter stacks
        through here; `stack` is its broadcast-from-one-model sibling).
        Host default: identity."""
        return tree

    def place_fleet(self, tree: Any, m: int) -> Any:
        """Place device-partitioned (m, d_max, ...) fleet arrays (the
        hierarchy tier's nested device axis, DESIGN.md §3f).  Dim 0 is the
        USER axis on every backend — HostVmap device_puts the stack and
        vmaps (user, device); MeshShardMap shards users across the mesh
        and the device axis rides inside each shard — so the default
        `stage` placement is exactly right on both."""
        return self.stage(tree, m)

    def select(self, mask: jnp.ndarray, new: Any, old: Any) -> Any:
        """Participation rollback: keep `old` where ``mask`` is False."""
        return where_clients(mask, new, old)

    def update_cohort(self, update_fn: Callable, idx: jnp.ndarray,
                      keep: jnp.ndarray, stacked: Any, opt_state: Any,
                      x: Any, y: Any, n: Any, ckeys: jnp.ndarray
                      ) -> Tuple[Any, Any]:
        """Run the local update for the cohort ``idx`` (k,) only, merging
        back the rows where ``keep`` (k,) is True; every other client row
        is untouched (the async runtime's per-event step, DESIGN.md §3a).

        Default: run every slot and mask — the static-layout path sharded
        placements need.  `HostVmap` overrides with a gather/scatter so an
        event costs O(k) local-update compute, not O(m)."""
        m = ckeys.shape[0]
        mask = jnp.zeros((m,), dtype=bool).at[idx].set(keep)
        upd, upd_opt = update_fn(stacked, opt_state, x, y, n, ckeys)
        return (self.select(mask, upd, stacked),
                self.select(mask, upd_opt, opt_state))

    def uplink(self, codec: Any, stacked: Any, prev: Any, ef: Any,
               key: jnp.ndarray, mask: Optional[jnp.ndarray] = None
               ) -> Tuple[Any, Any]:
        """Pass the participating clients' updates through the channel
        codec with error feedback (DESIGN.md §3b): returns the server-side
        ``(stacked', ef')``.  Rows where ``mask`` is False are untouched.
        Identity codecs return the inputs unchanged (bit-parity anchor)."""
        from repro.fl.channel import apply_uplink
        return apply_uplink(codec, stacked, prev, ef, key, mask,
                            backend=self.codec_backend)

    @abc.abstractmethod
    def mix(self, stacked: Any, w: jnp.ndarray) -> Any:
        """Apply a full per-client aggregation matrix ``w`` (m, m)."""

    @abc.abstractmethod
    def mix_plan(self, stacked: Any, plan: StreamPlan) -> Any:
        """Apply a k-stream `StreamPlan` (centroid mix + group broadcast)."""

    # ---- superstep execution (DESIGN.md §3c) ------------------------------

    def mix_traced(self, stacked: Any, w: jnp.ndarray) -> Any:
        """Trace-safe sibling of `mix`, usable inside the superstep scan
        (no jit dispatch of its own).  Default: `mix` itself — correct for
        backends whose `mix` is already pure jnp (HostVmap)."""
        return self.mix(stacked, w)

    def mix_plan_traced(self, stacked: Any, centroids: jnp.ndarray,
                        assignment: jnp.ndarray) -> Any:
        """Trace-safe sibling of `mix_plan` (plan unpacked into arrays —
        a traced scan carries arrays, not host NamedTuples)."""
        return self.mix_plan(stacked, StreamPlan(centroids, assignment,
                                                 jnp.float32(0.0)))

    def eval_traced(self, acc_fn: Callable, stacked: Any, x_val: Any,
                    y_val: Any) -> Any:
        """Per-client validation scores (m,), trace-safe — the superstep
        fuses this onto the end of the scan (DESIGN.md §3c/§3e) so the
        chunk's eval costs no extra program dispatch.  Same vmapped math
        as the eventful `evaluate`; the (mean, worst) reduction stays
        host-side (`reduce_scores`) on both paths so they cannot drift."""
        return jax.vmap(lambda p, x, y: acc_fn(p, {"x": x, "y": y}))(
            stacked, x_val, y_val)

    def stage(self, tree: Any, m: int) -> Any:
        """Begin the host->device transfer of a gathered cohort pytree
        (the paging engine's H2D leg, DESIGN.md §3e).  Returns
        device-backed arrays immediately — the copy proceeds under jax's
        async dispatch, which is what lets the engine stage cohort t+1
        while cohort t's superstep is still running."""
        return jax.device_put(tree)

    def build_round(self, round_fn: Callable, *, length: int,
                    donate: bool = True,
                    eval_fn: Optional[Callable] = None) -> Callable:
        """Compile ``length`` consecutive traced rounds as ONE `lax.scan`
        superstep: returns ``fn(carry, data, consts, eval_data) ->
        (carry', outs, accs)`` where ``round_fn(carry, data, consts) ->
        (carry', out)`` is the engine-built fused round (update → select →
        codec uplink → aggregate) and ``eval_fn(stacked, eval_data)`` (if
        given) computes the chunk-end per-client scores INSIDE the same
        program — the eval dispatch disappears from the per-chunk Python.
        The carry is donated by default — the input stacked/opt/EF buffers
        are dead once the superstep returns, so buffer donation survives
        fusion.  Backends whose arrays carry shardings (MeshShardMap) rely
        on GSPMD propagating them through the scan: the carry never leaves
        the mesh between rounds."""

        def superstep(carry, data, consts, eval_data):
            carry, outs = jax.lax.scan(lambda c, _: round_fn(c, data,
                                                             consts),
                                       carry, None, length=length)
            accs = None if eval_fn is None else eval_fn(carry[1], eval_data)
            return carry, outs, accs

        return jax.jit(superstep, donate_argnums=(0,) if donate else ())

    def run_supersteps(self, round_fn: Callable, carry: Any, data: Any,
                       consts: Any, length: int, *, cache: dict,
                       donate: bool = True,
                       eval_fn: Optional[Callable] = None,
                       eval_data: Any = None) -> Tuple[Any, Any, Any]:
        """Run ``length`` fused rounds (+ the fused chunk-end eval),
        compiling (and caching in ``cache``, keyed by length) the
        superstep on first use.  The jit re-specializes per input SHAPE,
        so one cached superstep serves every cohort size — the paging
        engine (DESIGN.md §3e) relies on this to reuse executables across
        runs that differ only in population size."""
        fn = cache.get(length)
        if fn is None:
            fn = cache[length] = self.build_round(round_fn, length=length,
                                                  donate=donate,
                                                  eval_fn=eval_fn)
        return fn(carry, data, consts, eval_data)

    def cache_key(self) -> Tuple:
        """Hashable identity for the compiled-superstep cache: two
        placements with equal keys must trace identical supersteps."""
        return (type(self).__name__,)

    @abc.abstractmethod
    def evaluate(self, acc_fn: Callable, stacked: Any, fed: FederatedData
                 ) -> Tuple[float, float]:
        """(mean, worst) validation score across clients."""

    def __repr__(self) -> str:
        return f"{type(self).__name__}()"


def resolve_placement(placement: Optional["Placement"]) -> "Placement":
    """None -> the default `HostVmap` backend."""
    if placement is None:
        from repro.fl.placement.host import HostVmap
        return HostVmap()
    return placement
