"""Client placement backends for the federated round engine (DESIGN.md §3).

    run_federated("ucfl_k2", fed)                                  # HostVmap
    run_federated("ucfl_k2", fed, placement=MeshShardMap(
        schedule="shard_map_streams"))                             # mesh

`HostVmap` is the reference single-device backend (bit-for-bit the
pre-placement engine); `MeshShardMap` shards the client stack over a
device mesh and mixes with real collectives.
"""
from repro.fl.placement.base import (Placement, resolve_placement,
                                     stack_params, where_clients)
from repro.fl.placement.host import (HostVmap, evaluate, make_client_update,
                                     reduce_scores)
from repro.fl.placement.mesh import MeshShardMap

__all__ = ["HostVmap", "MeshShardMap", "Placement", "evaluate",
           "make_client_update", "reduce_scores", "resolve_placement",
           "stack_params", "where_clients"]
