"""Host `vmap` placement: all clients stacked on one device (DESIGN.md §3).

This is the paper-scale backend (m=20..100, LeNet) and the reference
semantics: a `run_federated` call with `HostVmap()` is bit-identical to
the pre-placement engine.  The jitted local-update step is cached across
calls keyed on the (loss_fn, FLConfig) fields it closes over, so sweep
drivers (`benchmarks/paper_experiments.py`) re-entering `run_federated`
per (scenario × algorithm × trial) stop recompiling identical programs.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import stream_aggregate, user_centric_aggregate
from repro.core.streams import StreamPlan
from repro.data.federated import FederatedData
from repro.fl.placement.base import (Placement, stack_params,
                                     where_clients)
from repro.optim import apply_updates, sgd


def make_client_update(loss_fn: Callable, opt, fl):
    """Returns f(params_i, opt_i, data_i, n_i, key) -> (params_i', opt_i')
    running `local_steps` SGD steps on mini-batches drawn from client i."""

    def client_update(params_i, opt_i, x_i, y_i, n_i, key):
        n_slots = x_i.shape[0]

        def step(carry, k):
            p, o = carry
            idx = jax.random.randint(k, (fl.batch_size,), 0, 1 << 30) % \
                jnp.maximum(n_i.astype(jnp.int32), 1)
            idx = idx % n_slots
            batch = {"x": x_i[idx], "y": y_i[idx]}
            grads, _ = jax.grad(loss_fn, has_aux=True)(p, batch)
            upd, o = opt.update(grads, o, p)
            return (apply_updates(p, upd), o), None

        keys = jax.random.split(key, fl.local_steps)
        # NOTE: do not be tempted to unroll this scan — unrolling lets XLA
        # fuse across step boundaries differently in the eventful per-round
        # jit vs the fused superstep program (§3c), breaking their
        # final-params bit-parity at local_steps >= 2
        (p, o), _ = jax.lax.scan(step, (params_i, opt_i), keys)
        return p, o

    return client_update


class _UpdateConfig:
    """The FLConfig fields `make_client_update` closes over (hash key)."""

    def __init__(self, local_steps: int, batch_size: int):
        self.local_steps = local_steps
        self.batch_size = batch_size


@functools.lru_cache(maxsize=16)
def cached_update(loss_fn: Callable, local_steps: int, batch_size: int,
                  lr: float, momentum: float, state_dtype=None,
                  donate: bool = False) -> Tuple[Any, Callable]:
    """(opt, jit(vmap(client_update))) memoized on everything the step
    closes over — repeated `run_federated` calls with the same config
    reuse the compiled executable instead of re-tracing per run.
    ``donate=True`` donates the stacked params/opt-state arguments, so the
    step updates in place instead of holding two copies of the client
    stack (the engine's buffer-donation memory lever)."""
    opt = sgd(lr, momentum=momentum, state_dtype=state_dtype)
    client_update = make_client_update(
        loss_fn, opt, _UpdateConfig(local_steps, batch_size))
    step = jax.vmap(client_update)
    return opt, (jax.jit(step, donate_argnums=(0, 1)) if donate
                 else jax.jit(step))


@functools.lru_cache(maxsize=8)
def _eval_fn(apply_acc: Callable):
    return jax.jit(jax.vmap(lambda p, x, y: apply_acc(p, {"x": x, "y": y})))


def reduce_scores(accs) -> Tuple[float, float]:
    """(mean, worst) reduction of the per-client score vector — shared by
    the eventful `evaluate` and the fused-eval superstep replay
    (DESIGN.md §3c/§3e) so the two paths reduce identically."""
    return float(jnp.mean(accs)), float(jnp.min(accs))


def evaluate(apply_acc: Callable, stacked_params, fed: FederatedData
             ) -> Tuple[float, float]:
    """(mean, worst) validation accuracy across clients, personalized models."""
    return reduce_scores(
        _eval_fn(apply_acc)(stacked_params, fed.x_val, fed.y_val))


class HostVmap(Placement):
    """Single-device stacked-client placement (reference semantics)."""

    name = "host_vmap"

    def build_update(self, loss_fn: Callable, fl, *,
                     donate: bool = False) -> Tuple[Any, Callable]:
        return cached_update(loss_fn, fl.local_steps, fl.batch_size,
                             fl.lr, fl.momentum,
                             getattr(fl, "opt_state_dtype", None), donate)

    def stack(self, params0: Any, m: int) -> Any:
        return stack_params(params0, m)

    def update_cohort(self, update_fn, idx, keep, stacked, opt_state,
                      x, y, n, ckeys):
        # gather the k cohort rows, update them, scatter the kept ones
        # back: O(k) local-update compute per async event instead of O(m)
        # (the jitted step retraces once for the (k, ...) shapes)
        take = lambda t: jax.tree_util.tree_map(lambda l: l[idx], t)
        sub, sub_opt = take(stacked), take(opt_state)
        new_sub, new_opt = update_fn(sub, sub_opt, x[idx], y[idx], n[idx],
                                     ckeys[idx])
        new_sub = where_clients(keep, new_sub, sub)
        new_opt = where_clients(keep, new_opt, sub_opt)
        scatter = lambda full, s: jax.tree_util.tree_map(
            lambda l, ls: l.at[idx].set(ls), full, s)
        return scatter(stacked, new_sub), scatter(opt_state, new_opt)

    def mix(self, stacked: Any, w: jnp.ndarray) -> Any:
        return user_centric_aggregate(stacked, w)

    def mix_plan(self, stacked: Any, plan: StreamPlan) -> Any:
        return stream_aggregate(stacked, plan)

    def evaluate(self, acc_fn: Callable, stacked: Any, fed: FederatedData
                 ) -> Tuple[float, float]:
        return evaluate(acc_fn, stacked, fed)
