"""Host `vmap` placement: all clients stacked on one device (DESIGN.md §3).

This is the paper-scale backend (m=20..100, LeNet) and the reference
semantics: a `run_federated` call with `HostVmap()` is bit-identical to
the pre-placement engine.  The jitted local-update step is cached across
calls keyed on the (loss_fn, FLConfig) fields it closes over, so sweep
drivers (`benchmarks/paper_experiments.py`) re-entering `run_federated`
per (scenario × algorithm × trial) stop recompiling identical programs.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import stream_aggregate, user_centric_aggregate
from repro.core.streams import StreamPlan
from repro.data.federated import FederatedData
from repro.fl.placement.base import Placement, stack_params
from repro.optim import apply_updates, sgd


def make_client_update(loss_fn: Callable, opt, fl):
    """Returns f(params_i, opt_i, data_i, n_i, key) -> (params_i', opt_i')
    running `local_steps` SGD steps on mini-batches drawn from client i."""

    def client_update(params_i, opt_i, x_i, y_i, n_i, key):
        n_slots = x_i.shape[0]

        def step(carry, k):
            p, o = carry
            idx = jax.random.randint(k, (fl.batch_size,), 0, 1 << 30) % \
                jnp.maximum(n_i.astype(jnp.int32), 1)
            idx = idx % n_slots
            batch = {"x": x_i[idx], "y": y_i[idx]}
            grads, _ = jax.grad(loss_fn, has_aux=True)(p, batch)
            upd, o = opt.update(grads, o, p)
            return (apply_updates(p, upd), o), None

        keys = jax.random.split(key, fl.local_steps)
        (p, o), _ = jax.lax.scan(step, (params_i, opt_i), keys)
        return p, o

    return client_update


class _UpdateConfig:
    """The FLConfig fields `make_client_update` closes over (hash key)."""

    def __init__(self, local_steps: int, batch_size: int):
        self.local_steps = local_steps
        self.batch_size = batch_size


@functools.lru_cache(maxsize=16)
def cached_update(loss_fn: Callable, local_steps: int, batch_size: int,
                  lr: float, momentum: float, state_dtype=None
                  ) -> Tuple[Any, Callable]:
    """(opt, jit(vmap(client_update))) memoized on everything the step
    closes over — repeated `run_federated` calls with the same config
    reuse the compiled executable instead of re-tracing per run."""
    opt = sgd(lr, momentum=momentum, state_dtype=state_dtype)
    client_update = make_client_update(
        loss_fn, opt, _UpdateConfig(local_steps, batch_size))
    return opt, jax.jit(jax.vmap(client_update))


@functools.lru_cache(maxsize=8)
def _eval_fn(apply_acc: Callable):
    return jax.jit(jax.vmap(lambda p, x, y: apply_acc(p, {"x": x, "y": y})))


def evaluate(apply_acc: Callable, stacked_params, fed: FederatedData
             ) -> Tuple[float, float]:
    """(mean, worst) validation accuracy across clients, personalized models."""
    accs = _eval_fn(apply_acc)(stacked_params, fed.x_val, fed.y_val)
    return float(jnp.mean(accs)), float(jnp.min(accs))


class HostVmap(Placement):
    """Single-device stacked-client placement (reference semantics)."""

    name = "host_vmap"

    def build_update(self, loss_fn: Callable, fl) -> Tuple[Any, Callable]:
        return cached_update(loss_fn, fl.local_steps, fl.batch_size,
                             fl.lr, fl.momentum,
                             getattr(fl, "opt_state_dtype", None))

    def stack(self, params0: Any, m: int) -> Any:
        return stack_params(params0, m)

    def mix(self, stacked: Any, w: jnp.ndarray) -> Any:
        return user_centric_aggregate(stacked, w)

    def mix_plan(self, stacked: Any, plan: StreamPlan) -> Any:
        return stream_aggregate(stacked, plan)

    def evaluate(self, acc_fn: Callable, stacked: Any, fed: FederatedData
                 ) -> Tuple[float, float]:
        return evaluate(acc_fn, stacked, fed)
