"""Mesh placement: clients sharded over a device-mesh axis (DESIGN.md §3).

The client stack (leading dim m of every leaf), the per-client datasets
and the round keys are placed `P(axis)` over a mesh; the vmapped local
update then runs as client-data-parallelism under GSPMD, and the mixing
matrix / StreamPlan application lowers to real collectives selected by
``schedule``:

  gspmd               einsum, XLA chooses collectives (baseline)
  shard_map_streams   explicit psum of k weighted copies (§Perf lever)
  shard_map_unicast   explicit all-gather + local mix (m-fold downlink)

With ``mesh=None`` a 1-D ``("clients",)`` mesh is built lazily from the
available devices (the largest divisor of m, so the shard_map schedules'
equal-shard requirement always holds).  Pass an explicit mesh + ``axis``
to co-place with tensor-parallel axes (`repro.launch.mesh.client_axes`).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.distributed import MIX_SCHEDULES, mix_schedule
from repro.core.streams import StreamPlan
from repro.data.federated import FederatedData
from repro.fl.placement.base import Placement
from repro.fl.placement.host import cached_update, evaluate


class MeshShardMap(Placement):
    """Clients sharded over ``axis`` of ``mesh``; collective mixing."""

    name = "mesh_shard_map"
    # channel codec (DESIGN.md §3b) runs the pure-jnp oracle math here:
    # plain rowwise jnp ops partition over the client axis under GSPMD,
    # whereas a pallas_call carries no sharding rule and would gather the
    # client stack to one device (bit-identical to the kernels for qsgd;
    # top-k differs only on exact magnitude ties)
    codec_backend = "jnp"

    def __init__(self, mesh: Optional[Mesh] = None, *,
                 axis: Optional[str] = None, schedule: str = "gspmd"):
        if schedule not in MIX_SCHEDULES:
            raise ValueError(f"unknown mixing schedule {schedule!r}; "
                             f"one of {sorted(MIX_SCHEDULES)}")
        self.mesh = mesh
        self.axis = axis if axis is not None else (
            mesh.axis_names[0] if mesh is not None else None)
        self.schedule = schedule
        self._auto = mesh is None
        self._auto_m = None
        self._mix_jit = None
        self._mix_plan_jit = None

    def _ensure_mesh(self, m: int) -> Mesh:
        if self._auto and m != self._auto_m:
            # re-derive the auto mesh per client count, so one instance can
            # drive sweeps over scenarios with different m
            devs = jax.devices()
            d = max(k for k in range(1, min(len(devs), m) + 1) if m % k == 0)
            self.mesh = Mesh(np.asarray(devs[:d]), ("clients",))
            self.axis = "clients"
            self._auto_m = m
            self._mix_jit = self._mix_plan_jit = None
        size = self.mesh.shape[self.axis]
        if m % size:
            raise ValueError(
                f"m={m} clients not divisible by mesh axis {self.axis!r} "
                f"(size {size}) — shard_map schedules need equal shards")
        return self.mesh

    def _shard(self, tree: Any) -> Any:
        mesh = self.mesh

        def put(l):
            spec = P(self.axis, *([None] * (l.ndim - 1)))
            return jax.device_put(l, NamedSharding(mesh, spec))

        return jax.tree_util.tree_map(put, tree)

    # ---- Placement hooks --------------------------------------------------

    def build_update(self, loss_fn: Callable, fl, *,
                     donate: bool = False) -> Tuple[Any, Callable]:
        # same cached jitted step as HostVmap: the jit re-specializes on the
        # sharded inputs, so the client vmap runs data-parallel over `axis`
        return cached_update(loss_fn, fl.local_steps, fl.batch_size,
                             fl.lr, fl.momentum,
                             getattr(fl, "opt_state_dtype", None), donate)

    def stack(self, params0: Any, m: int) -> Any:
        self._ensure_mesh(m)
        stacked = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l[None], (m,) + l.shape), params0)
        return self._shard(stacked)

    def place_data(self, fed: FederatedData) -> Tuple[Any, Any, Any]:
        self._ensure_mesh(fed.m)
        return self._shard(fed.x), self._shard(fed.y), self._shard(fed.n)

    def place_keys(self, ckeys: jnp.ndarray) -> jnp.ndarray:
        return self._shard(ckeys)

    def place_stack(self, tree: Any, m: int) -> Any:
        self._ensure_mesh(m)
        return self._shard(tree)

    def stage(self, tree: Any, m: int) -> Any:
        # paging H2D leg (DESIGN.md §3e): device_put straight from the
        # host rows to their client-axis sharding — one copy, no bounce
        # through the default device
        self._ensure_mesh(m)
        return self._shard(tree)

    # mix/mix_plan run eagerly once per round: hold one jit wrapper per
    # instance so the shard_map collective traces and compiles once, not
    # per call (jax's dispatch cache does not cache fresh shard_map objects)

    def mix(self, stacked: Any, w: jnp.ndarray) -> Any:
        if self._mix_jit is None:
            self._mix_jit = jax.jit(lambda s, ww: mix_schedule(
                self.mesh, (self.axis,), s, ww, schedule=self.schedule))
        return self._mix_jit(stacked, w)

    def mix_plan(self, stacked: Any, plan: StreamPlan) -> Any:
        if self._mix_plan_jit is None:
            self._mix_plan_jit = jax.jit(lambda s, c, a: mix_schedule(
                self.mesh, (self.axis,), s, c, a, schedule=self.schedule))
        return self._mix_plan_jit(stacked, plan.centroids, plan.assignment)

    # superstep hooks (DESIGN.md §3c): the same schedule-selected
    # collectives, called WITHOUT the per-instance jit wrapper so they
    # inline into the fused scan — the client-sharded carry stays on the
    # mesh across all fused rounds (GSPMD propagates the input shardings
    # through `lax.scan`), and the collectives run once per round inside
    # the compiled loop instead of as a per-round dispatch

    def mix_traced(self, stacked: Any, w: jnp.ndarray) -> Any:
        return mix_schedule(self.mesh, (self.axis,), stacked, w,
                            schedule=self.schedule)

    def mix_plan_traced(self, stacked: Any, centroids: jnp.ndarray,
                        assignment: jnp.ndarray) -> Any:
        return mix_schedule(self.mesh, (self.axis,), stacked, centroids,
                            assignment, schedule=self.schedule)

    def cache_key(self):
        # Mesh equality is by device assignment + axis names, so two
        # auto-built placements over the same devices share compiles
        return (type(self).__name__, self.mesh, self.axis, self.schedule)

    def evaluate(self, acc_fn: Callable, stacked: Any, fed: FederatedData
                 ) -> Tuple[float, float]:
        return evaluate(acc_fn, stacked, fed)

    def __repr__(self) -> str:
        shape = None if self.mesh is None else dict(self.mesh.shape)
        return (f"MeshShardMap(mesh={shape}, axis={self.axis!r}, "
                f"schedule={self.schedule!r})")
