"""Cohort paging engine (DESIGN.md §3e): host-backed client-state store,
double-buffered device transfers, checkpointed supersteps.

    from repro.fl import PagingConfig, run_federated
    run_federated("ucfl_k2", fed, paging=PagingConfig(cohort=8))
"""
from repro.fl.population.paging import (PagingConfig, run_async_paged,
                                        run_paged, sub_federated)
from repro.fl.population.schedule import (CohortSchedule, FixedCohort,
                                          RandomCohorts, SequentialSweep)
from repro.fl.population.store import ClientStateStore

__all__ = ["ClientStateStore", "CohortSchedule", "FixedCohort",
           "PagingConfig", "RandomCohorts", "SequentialSweep",
           "run_async_paged", "run_paged", "sub_federated"]
