"""Cohort paging engine (DESIGN.md §3e): population >> device memory.

`run_paged` trains a population of n clients with only one cohort of m
clients device-resident at a time:

  superstep t:  gather cohort rows from the `ClientStateStore`
                -> stage host->device (`Placement.stage`)
                -> run the PR-5 fused superstep on the cohort carry
                -> (meanwhile stage cohort t+1 — double buffering)
                -> scatter updated rows back to the store

The compiled superstep is THE resident engine's (`repro.fl.simulator`):
same `_build_traced_round`, same `_SUPERSTEP_FNS` cache entry — the jit
re-specializes on the cohort shape, never on the population size, so one
executable serves any n and a paged run over a `FixedCohort` is
bit-identical to a resident run on that sub-population (the parity
anchor `tests/test_population.py` pins).

Double-buffer protocol, both legs: right after the current superstep is
DISPATCHED (jax's async dispatch returns before the program finishes),
the loop drains the PREVIOUS chunk (accounting, eval reduce, scatter —
blocking pulls that wait only on already-finished compute) and then
issues the next cohort's host gather + H2D copy, so writeback and upload
both overlap the running compute.  The prefetch is skipped whenever the
next cohort intersects the current one (its rows would be stale until
the scatter lands), and an overlapping next cohort forces the pending
drain before its rows are gathered.

Checkpointing: at superstep boundaries, the store rows + engine carry
(PRNG key, clock accumulator) + History snapshot to one msgpack file.
Schedules are pure functions of the superstep index, so a resumed run
replays the exact cohort sequence — resume is bit-identical (pinned).

`run_async_paged` is the buffered-async sibling: the per-event arrival
buffer IS the page request; aggregation is cohort-local (exact in the
lockstep K=m anchor, an approximation under partial buffers — resident
async mixes over the full population stack).
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (CheckpointCorruptError, paged_checkpoints,
                              restore_paged_state, save_paged_state)
from repro.data.federated import FederatedData
from repro.fl.channel import (Channel, ChannelCost, resolve_channel,
                              round_downlink_time)
from repro.fl.comm import SYSTEMS, SystemModel
from repro.fl.faults import (FaultMeter, get_robust_aggregator,
                             inject_values, pop_with_retries,
                             resolve_fault_plan, screen_and_defend)
from repro.fl.placement import (Placement, reduce_scores, resolve_placement,
                                stack_params)
from repro.fl.population.schedule import (CohortSchedule, FixedCohort,
                                          RandomCohorts, SequentialSweep)
from repro.fl.population.store import ClientStateStore
from repro.fl.simulator import (FLConfig, History, _build_traced_round,
                                _eval_rounds, _superstep_cache, channel_extra,
                                channel_uplink, charge_round,
                                default_model_init, finalize_history,
                                init_channel, per_client_uplink_bits,
                                record_eval, resolve_strategy,
                                superstep_support)
from repro.fl.strategies import (ClientSampler, CommCost, RoundContext,
                                 Strategy)
from repro.models import lenet

# distinct cohorts whose strategy state / placed data pages stay cached
# (sweep schedules cycle through n/m cohorts — keep the working set small)
_SETUP_CACHE_MAX = 8


@dataclass(frozen=True)
class PagingConfig:
    """Knobs of the cohort paging engine (DESIGN.md §3e).

    cohort:           device-resident clients per superstep (ignored when
                      ``schedule`` is a `CohortSchedule` instance, which
                      carries its own size).
    schedule:         ``"sweep"`` (round-robin shards) | ``"random"``
                      (seeded without-replacement draw per superstep) |
                      a `CohortSchedule` instance.
    schedule_seed:    seed of the ``"random"`` schedule.
    store_dir:        disk-back the client-state store as ``.npy``
                      memmaps (None = host RAM).
    checkpoint_dir:   write superstep-boundary snapshots here (None = no
                      checkpointing).
    checkpoint_every: snapshot cadence in supersteps.
    resume:           pick up from the latest snapshot in
                      ``checkpoint_dir`` (no-op when there is none).
    prefetch:         double-buffer the next cohort's H2D copy under the
                      running superstep (skipped when cohorts overlap).
    max_chunks:       run at most this many supersteps this invocation,
                      then return the partial History (preemption hook /
                      resume tests); None = run to completion.
    """
    cohort: int = 8
    schedule: Union[str, CohortSchedule] = "sweep"
    schedule_seed: int = 0
    store_dir: Optional[str] = None
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 1
    resume: bool = False
    prefetch: bool = True
    max_chunks: Optional[int] = None

    def __post_init__(self):
        if self.cohort < 1:
            raise ValueError(f"cohort must be >= 1, got {self.cohort}")
        if self.checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1, got "
                             f"{self.checkpoint_every}")

    def resolve_schedule(self) -> CohortSchedule:
        if isinstance(self.schedule, CohortSchedule):
            return self.schedule
        if self.schedule == "sweep":
            return SequentialSweep(self.cohort)
        if self.schedule == "random":
            return RandomCohorts(self.cohort, seed=self.schedule_seed)
        raise ValueError(f"unknown cohort schedule {self.schedule!r}; "
                         "one of sweep | random | a CohortSchedule")


def sub_federated(fed: FederatedData, idx: np.ndarray) -> FederatedData:
    """The cohort's view of the population data (row-gathered)."""
    return FederatedData(x=fed.x[idx], y=fed.y[idx], n=fed.n[idx],
                         x_val=fed.x_val[idx], y_val=fed.y_val[idx],
                         group=fed.group[idx])


def _host_federated(fed: FederatedData) -> FederatedData:
    """The population's data as host numpy rows: a cohort gather is then
    one memcpy and only cohort-sized arrays ever cross H2D — the data
    half of the paging contract (the store is the state half).  Values
    are bitwise identical either way, so parity is untouched."""
    return FederatedData(*[np.asarray(leaf) for leaf in fed])


# ---------------------------------------------------------------------------
# History <-> checkpoint payload (plain lists/arrays only)


def _history_state(history: History) -> dict:
    return {"rounds": list(history.rounds),
            "mean_acc": list(history.mean_acc),
            "worst_acc": list(history.worst_acc),
            "time": list(history.time),
            "comm": [[int(c.n_streams), int(c.n_unicasts)]
                     for c in history.comm],
            "comm_bits": [[int(c.dl_bits), int(c.ul_bits)]
                          for c in history.comm_bits]}


def _history_from_state(d: dict) -> History:
    h = History()
    h.rounds = [int(r) for r in d["rounds"]]
    h.mean_acc = [float(a) for a in d["mean_acc"]]
    h.worst_acc = [float(a) for a in d["worst_acc"]]
    h.time = [float(t) for t in d["time"]]
    h.comm = [CommCost(int(s), int(u)) for s, u in d["comm"]]
    h.comm_bits = [ChannelCost(int(dl), int(ul))
                   for dl, ul in d["comm_bits"]]
    return h


class _CohortSetups:
    """Per-cohort strategy state + placed data pages, LRU by row indices.

    A cohort is its own federated sub-problem: the strategy's `setup`
    (similarity stats, mixing matrix, k-means plan) runs on the cohort's
    sub-population exactly as a resident run on that sub-fed would — the
    parity anchor's definition of correct."""

    def __init__(self, build: Callable):
        self._build = build
        self._cache: OrderedDict = OrderedDict()

    def get(self, idx: np.ndarray):
        k = idx.tobytes()
        if k in self._cache:
            self._cache.move_to_end(k)
            return self._cache[k]
        while len(self._cache) >= _SETUP_CACHE_MAX:
            self._cache.popitem(last=False)
        out = self._cache[k] = self._build(idx)
        return out


def _disjoint(a: np.ndarray, b: np.ndarray) -> bool:
    return np.intersect1d(a, b, assume_unique=True).size == 0


# ---------------------------------------------------------------------------
# the paged synchronous engine


def run_paged(algorithm: Union[str, Strategy, None] = None,
              fed: Optional[FederatedData] = None, *,
              paging: PagingConfig,
              strategy: Optional[Strategy] = None,
              sampler: Optional[ClientSampler] = None,
              fl: Optional[FLConfig] = None,
              model_init: Optional[Callable] = None,
              loss_fn: Callable = lenet.loss_fn,
              acc_fn: Callable = lenet.accuracy,
              system: Optional[SystemModel] = None,
              placement: Optional[Placement] = None,
              channel: Union[str, Channel, None] = None,
              keep_state: bool = False,
              faults: Optional[Any] = None,
              robust_agg: Optional[str] = None,
              min_quorum: Optional[int] = None,
              seed: int = 0) -> History:
    """Paged synchronous run: `run_federated` semantics per cohort, the
    population paged through the host-backed store (module docstring).
    Returns History; ``keep_state=True`` attaches the FULL population's
    final params / opt state (host-backed, as device views).  ``faults``/
    ``robust_agg``/``min_quorum`` (DESIGN.md §3g) work per cohort: the
    `FaultPlan` is resolved ONCE at the population size and each cohort's
    static adversary row is gathered into the superstep ``consts`` — so
    per-cohort rows never retrace the compiled round."""
    strategy = resolve_strategy(algorithm, strategy)
    if fed is None:
        raise TypeError("`fed` is required")
    fl = FLConfig() if fl is None else fl
    placement = resolve_placement(placement)
    channel = resolve_channel(channel)
    ok, why = superstep_support(strategy, sampler)
    if not ok:
        raise ValueError(
            f"paged execution needs the fused superstep (DESIGN.md §3e) "
            f"but this run cannot fuse: {why}")

    n = fed.m
    plan = resolve_fault_plan(faults, n)
    defense = get_robust_aggregator(robust_agg)
    robust_spec = "none" if defense is None else str(robust_agg)
    fmeter = None
    if plan is not None or defense is not None or min_quorum is not None:
        fmeter = FaultMeter(plan, robust_spec, min_quorum)
    sched = paging.resolve_schedule()
    m_c = sched.cohort
    if m_c > n:
        raise ValueError(f"cohort {m_c} > population {n}")
    fed = _host_federated(fed)

    # identical prologue key chain to `init_run` (the parity anchor): the
    # model init consumes the first split, the round chain the rest
    key = jax.random.PRNGKey(seed)
    key, kinit = jax.random.split(key)
    if model_init is None:
        model_init = default_model_init(fed)
    params0 = model_init(kinit)
    opt, update_fn = placement.build_update(loss_fn, fl, donate=False)

    # channel bound at COHORT size: links/payloads describe the m_c
    # device-resident slots (per-slot approximation for rate-adaptive
    # links — exact for the uniform-codec paths the anchors pin)
    ctx_pop = RoundContext(fed=fed, fl=fl, loss_fn=loss_fn, acc_fn=acc_fn,
                           params0=params0, seed=seed, placement=placement,
                           strategy=strategy)
    payload, link, model_bits, _, channel = init_channel(
        channel, ctx_pop, stack_params(params0, m_c), system, m_c)
    lossy = channel is not None and not channel.codec.is_identity
    codec = channel.codec if lossy else None
    ef_flag = channel.error_feedback if lossy else True
    ul_bits_pc = per_client_uplink_bits(channel, ctx_pop, payload, m_c)

    # the full population's state rows, host-resident: params + opt state
    # (+ EF residuals under a lossy channel), one broadcast template each
    row = {"params": jax.device_get(params0),
           "opt": jax.device_get(opt.init(params0))}
    if lossy:
        row["ef"] = jax.tree_util.tree_map(np.zeros_like, row["params"])
    store = ClientStateStore.create(row, n, directory=paging.store_dir)

    # THE resident engine's compiled superstep — same trace builder, same
    # cache entry (the S3 executable-reuse contract)
    round_fn = _build_traced_round(strategy, sampler, codec, ef_flag,
                                   placement, update_fn, fault_plan=plan,
                                   defense=defense, min_quorum=min_quorum)
    cache = _superstep_cache(placement, strategy, sampler, codec, ef_flag,
                             update_fn, acc_fn,
                             fault_cfg=None if plan is None else plan.cfg,
                             robust_spec=robust_spec, min_quorum=min_quorum)
    eval_fn = lambda st, ed: placement.eval_traced(acc_fn, st, ed[0], ed[1])

    def build_setup(idx: np.ndarray):
        sub = sub_federated(fed, idx)
        ctx = RoundContext(fed=sub, fl=fl, loss_fn=loss_fn, acc_fn=acc_fn,
                           params0=params0, seed=seed, placement=placement,
                           strategy=strategy)
        state = strategy.setup(ctx)
        consts = strategy.traced_state(state)
        if plan is not None:
            # cohort-gathered adversary row, a traced const input (§3g)
            consts = (consts, jnp.asarray(plan.byz_row(idx)))
        # device_put: the population lives in HOST memory, so place_data
        # yields numpy leaves here — pin them on device once per cohort
        # setup (cached), or every superstep dispatch would re-upload them
        # AND miss the jit fast path on the changed input signature.
        return (state, consts, strategy.comm(state),
                strategy.membership(state),
                jax.device_put(placement.place_data(sub)),
                (jnp.asarray(sub.x_val), jnp.asarray(sub.y_val)))

    setups = _CohortSetups(build_setup)
    chunks = list(_eval_rounds(fl.rounds, fl.eval_every))
    meta = {"population": n, "cohort": m_c, "schedule": sched.spec,
            "strategy": strategy.spec, "seed": seed, "rounds": fl.rounds,
            "eval_every": fl.eval_every, "lossy": lossy,
            "faults": "none" if plan is None else plan.cfg.spec,
            "robust_agg": robust_spec, "min_quorum": min_quorum}

    history = History()
    t_accum = 0.0
    start_chunk = 0
    if paging.resume and paging.checkpoint_dir:
        # fallback chain (DESIGN.md §3g): newest snapshot first, skipping
        # any that fail the integrity check — one torn/bit-rotted latest
        # file costs at most one checkpoint cadence of recompute
        for ck_path in paged_checkpoints(paging.checkpoint_dir):
            try:
                saved = restore_paged_state(ck_path)
            except CheckpointCorruptError as e:
                warnings.warn(
                    f"paged checkpoint {ck_path} failed its integrity "
                    f"check ({e}); falling back to the previous intact "
                    "snapshot", RuntimeWarning, stacklevel=2)
                continue
            saved_meta = dict(saved["meta"])
            # pre-§3g checkpoints carry no fault keys: they were written
            # by faults-off runs, so they resume as such
            saved_meta.setdefault("faults", "none")
            saved_meta.setdefault("robust_agg", "none")
            saved_meta.setdefault("min_quorum", None)
            if saved_meta != meta:
                raise ValueError(
                    f"checkpoint {ck_path} was written by a different run "
                    f"configuration: {saved_meta} != {meta}")
            store = ClientStateStore.from_state_dict(
                saved["store"], directory=paging.store_dir)
            history = _history_from_state(saved["history"])
            t_accum = float(saved["t_accum"])
            key = jnp.asarray(np.asarray(saved["key"], np.uint32))
            start_chunk = int(saved["chunk"]) + 1
            break

    state = None
    staged, staged_for = None, None
    pending = None      # the dispatched-not-yet-accounted previous chunk
    done_chunks = 0

    def finalize(p):
        """Drain chunk p: accounting replay, eval reduce, scatter, maybe
        checkpoint.  All of p's blocking pulls (masks, accs, rows) wait
        only on p's compute — by the time this runs, the NEXT chunk is
        already dispatched behind it, so the D2H leg of the double
        buffer overlaps that compute.  Values and append order are
        exactly the eager loop's (parity-neutral reordering)."""
        nonlocal t_accum
        p_t, p_nxt, p_idx, p_carry, p_outs, p_accs, p_cost, p_asn, \
            p_len, p_key = p
        p_masks, p_crashes, p_qs = p_outs
        masks_np = (np.asarray(p_masks)
                    if p_masks is not None
                    and (channel is not None or system is not None
                         or fmeter is not None)
                    else None)
        crashes_np = None if p_crashes is None else np.asarray(p_crashes)
        qs_np = None if p_qs is None else np.asarray(p_qs)
        for i in range(p_len):
            mrow = None if masks_np is None else masks_np[i]
            crow = None if crashes_np is None else crashes_np[i]
            eff = mrow
            if crow is not None:
                eff = ~crow if eff is None else eff & ~crow
            n_eff = m_c if eff is None else int(eff.sum())
            ok_q = min_quorum is None or n_eff >= min_quorum
            t_accum = charge_round(
                history, p_cost if ok_q else CommCost(0, 0), eff,
                m_c, payload, link, system, channel, t_accum,
                p_asn if ok_q else None, ul_bits_pc)
            if fmeter is not None:
                qrow = None if qs_np is None else qs_np[i]
                rbits = qbits = 0
                if channel is not None:
                    rbits = (n_eff * payload if ul_bits_pc is None else
                             int(np.sum(ul_bits_pc[eff]) if eff is not None
                                 else np.sum(ul_bits_pc)))
                    if qrow is not None:
                        qbits = int(np.sum(qrow <= 0)) * payload
                fmeter.charge(crow, qrow, ok_q, rbits, qbits)
        mean_acc, worst_acc = reduce_scores(p_accs)
        record_eval(history, p_nxt, mean_acc, worst_acc, t_accum)

        out = {"params": p_carry[1], "opt": p_carry[2]}
        if lossy:
            out["ef"] = p_carry[3]
        store.scatter(p_idx, out)   # the chunk's ONE blocking D2H pull

        if paging.checkpoint_dir and (
                (p_t + 1) % paging.checkpoint_every == 0
                or p_t == len(chunks) - 1):
            store.flush()
            save_paged_state(paging.checkpoint_dir, p_t, {
                "key": np.asarray(jax.device_get(p_key)),
                "t_accum": float(t_accum),
                "history": _history_state(history),
                "store": store.state_dict(),
                "meta": meta})

    for t, (rnd, nxt) in enumerate(chunks):
        if t < start_chunk:
            continue
        if paging.max_chunks is not None and done_chunks >= paging.max_chunks:
            break
        idx = sched.indices(t, n)
        if pending is not None and not _disjoint(pending[2], idx):
            finalize(pending)   # overlapping rows: scatter must land
            pending = None      # before this cohort's gather
        state, consts, cost, assignment, data, eval_data = setups.get(idx)
        if staged is not None and staged_for == idx.tobytes():
            rows = staged
        else:
            rows = placement.stage(store.gather(idx), m_c)
        staged, staged_for = None, None
        carry = (key, rows["params"], rows["opt"], rows.get("ef"))

        length = nxt - rnd + 1
        carry, outs, accs = placement.run_supersteps(
            round_fn, carry, data, consts, length, cache=cache,
            eval_fn=eval_fn, eval_data=eval_data)
        # the key chain continues on device — no host sync between chunks
        key = carry[0]

        # double buffer, both legs: the superstep above is dispatched,
        # not finished.  Drain the PREVIOUS chunk (its compute is done —
        # device programs execute in dispatch order) while this one runs,
        # then issue cohort t+1's host gather + H2D copy so the upload
        # overlaps too.  Overlapping cohorts would page stale rows (their
        # scatter hasn't landed): fall back to a post-scatter gather.
        if pending is not None:
            finalize(pending)
        # checkpointing reads this chunk's key AFTER the next chunk's
        # dispatch has donated it — snapshot a device-side copy now (the
        # copy program runs before the donation, in dispatch order).
        ck_key = (jnp.array(carry[0], copy=True) if paging.checkpoint_dir
                  else None)
        pending = (t, nxt, idx, carry, outs, accs, cost, assignment,
                   length, ck_key)
        done_chunks += 1
        if (paging.prefetch and t + 1 < len(chunks)
                and (paging.max_chunks is None
                     or done_chunks < paging.max_chunks)):
            nidx = sched.indices(t + 1, n)
            setups.get(nidx)    # warm t+1's setup + data page
            if _disjoint(nidx, idx):
                staged = placement.stage(store.gather(nidx), m_c)
                staged_for = nidx.tobytes()

    if pending is not None:
        finalize(pending)

    if state is None:       # resumed past the end / max_chunks == 0
        last = min(max(start_chunk, 0), len(chunks) - 1)
        state = setups.get(sched.indices(last, n))[0]

    final_params = jax.tree_util.tree_map(jnp.asarray, store.tree["params"])
    final_opt = jax.tree_util.tree_map(jnp.asarray, store.tree["opt"])
    history = finalize_history(history, strategy, state, keep_state,
                               final_params, final_opt)
    history.extra["paging"] = {
        "population": n, "cohort": m_c, "schedule": sched.spec,
        "store_bytes": int(store.nbytes),
        "store_dir": paging.store_dir, "chunks": len(chunks),
        "resumed_at": start_chunk if start_chunk else None}
    if fmeter is not None:
        history.extra["faults"] = fmeter.extra()
    if channel is not None:
        channel_extra(history, channel, link, model_bits, payload)
    return history


# ---------------------------------------------------------------------------
# the paged buffered-async engine (DESIGN.md §3a + §3e)


def run_async_paged(algorithm: Union[str, Strategy, None] = None,
                    fed: Optional[FederatedData] = None, *,
                    paging: PagingConfig,
                    strategy: Optional[Strategy] = None,
                    async_cfg: Optional[Any] = None,
                    fl: Optional[FLConfig] = None,
                    model_init: Optional[Callable] = None,
                    loss_fn: Callable = lenet.loss_fn,
                    acc_fn: Callable = lenet.accuracy,
                    system: Optional[SystemModel] = None,
                    placement: Optional[Placement] = None,
                    channel: Union[str, Channel, None] = None,
                    keep_state: bool = False,
                    faults: Optional[Any] = None,
                    robust_agg: Optional[str] = None,
                    min_quorum: Optional[int] = None,
                    seed: int = 0) -> History:
    """Store-backed buffered-async run: each event's arrival buffer is
    the page request — its rows are gathered, updated, aggregated
    COHORT-LOCALLY and scattered back; device memory scales with
    ``buffer_k``, not the population.  Exact lockstep anchor: with
    ``buffer_k == population`` on the reliable system this is bit-
    identical to the resident `run_async` (pinned); under partial
    buffers the cohort-local mix is the paged approximation of the
    resident full-stack mix."""
    from repro.fl.runtime.clock import VirtualClock
    from repro.fl.runtime.engine import AsyncConfig

    strategy = resolve_strategy(algorithm, strategy)
    if fed is None:
        raise TypeError("`fed` is required")
    cfg = AsyncConfig() if async_cfg is None else async_cfg
    fl = FLConfig() if fl is None else fl
    system = SYSTEMS["wired"] if system is None else system
    placement = resolve_placement(placement)
    channel = resolve_channel(channel)

    n = fed.m
    k_buf = min(cfg.buffer_k, n)
    tau = np.inf if cfg.max_staleness is None else float(cfg.max_staleness)
    fed = _host_federated(fed)
    plan = resolve_fault_plan(faults, n)
    defense = get_robust_aggregator(robust_agg)
    robust_spec = "none" if defense is None else str(robust_agg)
    fmeter = None
    if plan is not None or defense is not None or min_quorum is not None:
        fmeter = FaultMeter(plan, robust_spec, min_quorum)
    attempts: dict = {}         # per-client consecutive-crash counter

    key = jax.random.PRNGKey(seed)
    key, kinit = jax.random.split(key)
    if model_init is None:
        model_init = default_model_init(fed)
    params0 = model_init(kinit)
    opt, vmapped_update = placement.build_update(loss_fn, fl, donate=False)

    # link/payload resolved over the POPULATION (the clock serves all n
    # clients), exactly like the resident async engine
    ctx_pop = RoundContext(fed=fed, fl=fl, loss_fn=loss_fn, acc_fn=acc_fn,
                           params0=params0, seed=seed, placement=placement,
                           strategy=strategy)
    payload, link, model_bits, _, channel = init_channel(
        channel, ctx_pop, stack_params(params0, k_buf), system, n)
    lossy = channel is not None and not channel.codec.is_identity
    ul_bits_pc = per_client_uplink_bits(channel, ctx_pop, payload, n)

    def _ul_bits(c: int):
        return payload if ul_bits_pc is None else int(ul_bits_pc[c])

    row = {"params": jax.device_get(params0),
           "opt": jax.device_get(opt.init(params0))}
    if lossy:
        row["ef"] = jax.tree_util.tree_map(np.zeros_like, row["params"])
    store = ClientStateStore.create(row, n, directory=paging.store_dir)

    def build_setup(idx: np.ndarray):
        sub = sub_federated(fed, idx)
        ctx = RoundContext(fed=sub, fl=fl, loss_fn=loss_fn, acc_fn=acc_fn,
                           params0=params0, seed=seed, placement=placement,
                           strategy=strategy)
        ctx.staleness_discount = cfg.staleness_discount
        ctx.staleness_schedule = cfg.staleness_schedule
        ctx.staleness_alpha = cfg.staleness_alpha
        # device_put for the same reason as run_paged: the population is
        # host-resident, so pin each cohort's batch data on device once.
        return [strategy.setup(ctx), ctx, sub,
                jax.device_put(placement.place_data(sub))]

    setups = _CohortSetups(build_setup)

    clock = VirtualClock(system, seed=seed, link=link)
    for i in range(n):
        clock.schedule(i, 0.0, ul_bits=_ul_bits(i))
    version = np.zeros(n, dtype=np.int64)

    history = History()
    t_done = 0.0
    state = None

    for event in range(fl.rounds):
        # crashed arrivals requeue with backoff (no new compute draw) and
        # die past max_retries — shared loop with the resident engine
        buffered = []
        while len(buffered) < k_buf:
            nxt_arrival = pop_with_retries(clock, plan, cfg.max_retries,
                                           cfg.retry_backoff, attempts,
                                           fmeter)
            if nxt_arrival is None:
                break
            buffered.append(nxt_arrival[1])
        if not buffered:
            warnings.warn(
                f"async paged run ended early at event {event}/"
                f"{fl.rounds}: every remaining client exhausted its crash "
                f"retries (dead: {sorted(fmeter.dead) if fmeter else []})",
                RuntimeWarning, stacklevel=2)
            break
        idx = np.sort(np.asarray(buffered, dtype=np.int64))
        k = idx.size
        entry = setups.get(idx)
        state, ctx, sub, (x_c, y_c, n_c) = entry
        age = (event - version[idx]).astype(np.int64)
        fresh = age <= tau

        rows = placement.stage(store.gather(idx), k)
        stacked, opt_state = rows["params"], rows["opt"]
        ef = rows.get("ef")

        key, kround = jax.random.split(key)
        ckeys = placement.place_keys(jax.random.split(kround, k))
        prev, prev_opt = stacked, opt_state
        upd, upd_opt = vmapped_update(stacked, opt_state, x_c, y_c, n_c,
                                      ckeys)
        if fresh.all():
            mask = None
            stacked, opt_state = upd, upd_opt
        else:
            # stale-dropped rows keep their server-known models (they
            # still re-download the mix below, like the resident engine)
            mask = jnp.asarray(fresh)
            stacked = placement.select(mask, upd, prev)
            opt_state = placement.select(mask, upd_opt, prev_opt)

        if plan is not None and plan.value_faults:
            # fault injection (DESIGN.md §3g) on the cohort stack; the
            # adversary row is the plan's, gathered at the cohort indices
            stacked = inject_values(plan, jnp.asarray(plan.byz_row(idx)),
                                    stacked, prev,
                                    jax.random.fold_in(kround, 3),
                                    rows=mask)

        if lossy:
            stacked, ef = channel_uplink(placement, channel, stacked, prev,
                                         ef, kround, mask)

        q = None
        if defense is not None:
            stacked, q = screen_and_defend(defense, stacked, prev)

        n_fresh = int(fresh.sum())
        quorum_ok = min_quorum is None or n_fresh >= min_quorum
        if quorum_ok:
            ctx.rnd, ctx.key, ctx.participation = \
                event, jax.random.fold_in(kround, 1), mask
            ctx.staleness = (jnp.asarray(age, jnp.float32)
                             if age.any() else None)
            ctx.quarantine = q
            stacked, state = strategy.aggregate(state, stacked, prev, ctx)
            ctx.quarantine = None
            entry[0] = state
        else:
            # below quorum: the event is undone — the cohort's rows stay
            # at their pre-event state and the uploads are wasted
            stacked, opt_state = prev, prev_opt

        # every cohort row is a buffered client: all of them download the
        # new mix and restart.  The cohort-local strategy already reports
        # cohort-sized costs; cap streams at the cohort like the resident
        # event charging (exact in lockstep, where cohort == population).
        ul_total = (sum(_ul_bits(c) for c in buffered)
                    if channel is not None else 0)
        if quorum_ok:
            cost = strategy.comm(state)
            cost = CommCost(min(cost.n_streams, k), cost.n_unicasts)
        else:
            cost = CommCost(0, 0)
        history.comm.append(cost)
        if channel is not None:
            history.comm_bits.append(ChannelCost(
                dl_bits=(cost.n_streams + cost.n_unicasts) * payload,
                ul_bits=ul_total))
        if quorum_ok:
            if link is not None:
                # cohort-local membership indexes cohort rows; the link
                # clock indexes by population id — translate (exact in
                # lockstep, where the cohort IS the population)
                memb = strategy.membership(state)
                if memb is not None:
                    full = np.zeros(n, dtype=np.int64)
                    full[idx] = np.asarray(memb, np.int64)
                    memb = full
                duration = round_downlink_time(link, cost, payload,
                                               buffered, memb)
            else:
                duration = cost.n_streams + cost.n_unicasts
            done = clock.serve(duration, overlap=True)
        else:
            done = clock.now
        t_done = max(t_done, done)
        for c in buffered:
            clock.schedule(c, done, ul_bits=_ul_bits(c))
            if quorum_ok:
                version[c] = event + 1
        if fmeter is not None:
            qrow = None if q is None else np.asarray(q)
            qbits = 0
            if channel is not None and qrow is not None and quorum_ok:
                qbits = int(np.sum(qrow <= 0)) * payload
            fmeter.charge(None, qrow, quorum_ok,
                          ul_total if channel is not None else 0, qbits)

        out = {"params": stacked, "opt": opt_state}
        if lossy:
            out["ef"] = ef
        store.scatter(idx, out)

        if event % fl.eval_every == 0 or event == fl.rounds - 1:
            # `stacked` is still device-resident — cohort-local eval, the
            # resident engine's full-population eval in the lockstep anchor
            mean_acc, worst_acc = placement.evaluate(acc_fn, stacked, sub)
            record_eval(history, event, mean_acc, worst_acc, t_done)

    if state is None:
        raise ValueError("fl.rounds must be >= 1 for the async runtime")
    final_params = jax.tree_util.tree_map(jnp.asarray, store.tree["params"])
    final_opt = jax.tree_util.tree_map(jnp.asarray, store.tree["opt"])
    history = finalize_history(history, strategy, state, keep_state,
                               final_params, final_opt)
    history.extra["async"] = {"buffer_k": k_buf,
                              "max_staleness": cfg.max_staleness,
                              "staleness_schedule": cfg.staleness_schedule,
                              "staleness_discount": cfg.staleness_discount,
                              "staleness_alpha": cfg.staleness_alpha,
                              "max_retries": cfg.max_retries,
                              "retry_backoff": cfg.retry_backoff,
                              "events": fl.rounds}
    history.extra["paging"] = {
        "population": n, "cohort": k_buf, "schedule": "arrival-buffer",
        "store_bytes": int(store.nbytes),
        "store_dir": paging.store_dir, "chunks": fl.rounds,
        "resumed_at": None}
    if fmeter is not None:
        history.extra["faults"] = fmeter.extra()
    if channel is not None:
        channel_extra(history, channel, link, model_bits, payload)
    return history
