"""Host-backed client-state store (DESIGN.md §3e).

The paging engine keeps the FULL per-client state population — model
params, optimizer state and (lossy channels) error-feedback residuals —
in host memory, optionally memory-mapped to disk, with every leaf laid
out ``(n, ...)`` so a sampled cohort is one contiguous row gather.  Only
the active cohort's rows ever live on device: device memory scales with
the cohort size m, host/disk with the population n.

The store is deliberately dumb: numpy rows in, numpy rows out.  All
device placement (sharding, H2D staging) happens in the paging layer
through `Placement.stage`, and a device->host->device round trip of the
row dtypes is bitwise lossless — which is what makes the paged engine's
bit-parity anchor against the resident engine possible.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import numpy as np


class ClientStateStore:
    """Population-sized per-client state, host-resident, row-gatherable.

    ``tree`` is any pytree whose leaves are (n, ...) numpy arrays (plain
    or ``np.memmap`` when ``directory`` is set); row i is client i's
    state.  Build one with `create` (broadcast a single-client template)
    or `from_state_dict` (checkpoint restore).
    """

    def __init__(self, tree: Any, n: int, directory: Optional[str] = None):
        for leaf in jax.tree_util.tree_leaves(tree):
            if leaf.shape[0] != n:
                raise ValueError(
                    f"store leaf has leading dim {leaf.shape[0]}, "
                    f"expected population size {n}")
        self.tree = tree
        self.n = n
        self.directory = directory

    # ---- construction -----------------------------------------------------

    @classmethod
    def create(cls, template: Any, n: int,
               directory: Optional[str] = None) -> "ClientStateStore":
        """Broadcast a single-client ``template`` pytree (leaf shapes are
        the PER-CLIENT shapes, no leading dim) to all n rows.  With
        ``directory``, each leaf becomes a disk-backed ``.npy`` memmap —
        populations far beyond host RAM stay pageable."""
        leaves, treedef = jax.tree_util.tree_flatten(template)
        out = []
        for i, leaf in enumerate(leaves):
            row = np.asarray(leaf)
            arr = cls._alloc(directory, i, (n,) + row.shape, row.dtype)
            arr[...] = row[None]
            out.append(arr)
        return cls(jax.tree_util.tree_unflatten(treedef, out), n, directory)

    @classmethod
    def from_state_dict(cls, d: Any,
                        directory: Optional[str] = None) -> "ClientStateStore":
        """Rebuild from `state_dict` output (checkpoint restore decodes
        leaves as read-only device arrays — copied into fresh writable
        host rows, or into ``directory``'s memmaps)."""
        n = int(d["n"])
        leaves, treedef = jax.tree_util.tree_flatten(d["tree"])
        out = []
        for i, leaf in enumerate(leaves):
            src = np.asarray(leaf)
            arr = cls._alloc(directory, i, src.shape, src.dtype)
            arr[...] = src
            out.append(arr)
        return cls(jax.tree_util.tree_unflatten(treedef, out), n, directory)

    @staticmethod
    def _alloc(directory: Optional[str], i: int, shape, dtype) -> np.ndarray:
        if directory is None:
            return np.empty(shape, dtype)
        os.makedirs(directory, exist_ok=True)
        return np.lib.format.open_memmap(
            os.path.join(directory, f"leaf_{i:04d}.npy"),
            mode="w+", dtype=dtype, shape=tuple(shape))

    # ---- the paging surface -----------------------------------------------

    def gather(self, idx: np.ndarray) -> Any:
        """Copy the cohort rows ``idx`` (k,) out as contiguous (k, ...)
        arrays — the H2D staging source (`Placement.stage` consumes the
        result without another host-side copy)."""
        idx = np.asarray(idx)
        return jax.tree_util.tree_map(
            lambda l: np.ascontiguousarray(l[idx]), self.tree)

    def scatter(self, idx: np.ndarray, rows: Any) -> None:
        """Write updated cohort rows back.  ``rows`` may be device arrays
        — fetched with ONE blocking transfer here (the paged superstep's
        D2H leg)."""
        idx = np.asarray(idx)
        host = jax.device_get(rows)

        def put(leaf, r):
            leaf[idx] = np.asarray(r, dtype=leaf.dtype)
            return leaf

        jax.tree_util.tree_map(put, self.tree, host)

    # ---- bookkeeping ------------------------------------------------------

    @property
    def nbytes(self) -> int:
        return sum(l.nbytes for l in jax.tree_util.tree_leaves(self.tree))

    @property
    def bytes_per_client(self) -> int:
        return self.nbytes // max(self.n, 1)

    def flush(self) -> None:
        for leaf in jax.tree_util.tree_leaves(self.tree):
            if isinstance(leaf, np.memmap):
                leaf.flush()

    def state_dict(self) -> Any:
        """Checkpoint payload: the full population rows + size."""
        return {"n": self.n, "tree": self.tree}

    def __repr__(self) -> str:
        backing = "memmap" if self.directory else "ram"
        return (f"ClientStateStore(n={self.n}, {backing}, "
                f"{self.nbytes / 2**20:.1f} MiB)")
