"""Cohort schedules: which population rows each paged superstep trains.

A schedule is a PURE function of the superstep index — no internal state
— so a checkpointed run resumed at chunk t re-derives exactly the cohort
sequence the interrupted run would have used (the resume bit-parity
contract, DESIGN.md §3e).
"""
from __future__ import annotations

import abc
from typing import Sequence

import numpy as np


class CohortSchedule(abc.ABC):
    """Maps a superstep index to the sorted cohort row indices."""

    cohort: int

    @abc.abstractmethod
    def indices(self, step: int, n: int) -> np.ndarray:
        """The (cohort,) sorted int64 row indices for superstep ``step``
        of a population of ``n`` clients."""

    @property
    @abc.abstractmethod
    def spec(self) -> str:
        """Identity string recorded in checkpoints — a resumed run
        refuses a checkpoint written under a different schedule."""

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec!r})"


class SequentialSweep(CohortSchedule):
    """Round-robin over the population's n/cohort contiguous shards:
    superstep t trains shard ``t % (n // cohort)``.  Every client is
    visited once per sweep — the epoch-style default."""

    def __init__(self, cohort: int):
        if cohort < 1:
            raise ValueError(f"cohort must be >= 1, got {cohort}")
        self.cohort = cohort

    def indices(self, step: int, n: int) -> np.ndarray:
        if n % self.cohort:
            raise ValueError(
                f"SequentialSweep needs population {n} divisible by "
                f"cohort {self.cohort}")
        s = step % (n // self.cohort)
        return np.arange(s * self.cohort, (s + 1) * self.cohort,
                         dtype=np.int64)

    @property
    def spec(self) -> str:
        return f"sweep:{self.cohort}"


class RandomCohorts(CohortSchedule):
    """Uniform without-replacement cohort per superstep.  The draw is
    seeded by ``(seed, step)`` — a pure function of the step, never a
    stream — so resume replays the exact cohort sequence."""

    def __init__(self, cohort: int, seed: int = 0):
        if cohort < 1:
            raise ValueError(f"cohort must be >= 1, got {cohort}")
        self.cohort = cohort
        self.seed = seed

    def indices(self, step: int, n: int) -> np.ndarray:
        if self.cohort > n:
            raise ValueError(f"cohort {self.cohort} > population {n}")
        rng = np.random.default_rng([self.seed, step])
        return np.sort(rng.choice(n, self.cohort,
                                  replace=False)).astype(np.int64)

    @property
    def spec(self) -> str:
        return f"random:{self.cohort}:{self.seed}"


class FixedCohort(CohortSchedule):
    """The same explicit cohort every superstep — the paged-vs-resident
    bit-parity anchor's schedule (a resident run on the sub-population is
    then the exact reference)."""

    def __init__(self, idx: Sequence[int]):
        arr = np.sort(np.asarray(idx, dtype=np.int64))
        if arr.size == 0:
            raise ValueError("FixedCohort needs at least one client")
        if np.unique(arr).size != arr.size:
            raise ValueError("FixedCohort indices must be unique")
        self.idx = arr
        self.cohort = int(arr.size)

    def indices(self, step: int, n: int) -> np.ndarray:
        if self.idx[-1] >= n:
            raise ValueError(
                f"FixedCohort index {int(self.idx[-1])} out of range for "
                f"population {n}")
        return self.idx

    @property
    def spec(self) -> str:
        return "fixed:" + ",".join(str(int(i)) for i in self.idx)
