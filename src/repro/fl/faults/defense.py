"""Screening + robust aggregation (DESIGN.md §3g).

The defense layer runs between the codec uplink and the strategy's
aggregation — on the SERVER-side decoded updates, before any mixing:

    screen:  non-finite rows are quarantined (q=0) and their deltas
             zeroed, so 0·NaN can never poison a personalized stream;
    robust:  the selected `RobustAggregator` transforms the surviving
             (m, D) flat deltas — clip | trimmed_mean | median | krum.

The returned quarantine weights ``q`` (1 kept, 0 quarantined) are routed
through `quarantine_reweight` inside `RoundContext.mix`/`TracedMix`, so
every registered strategy — including UCFL's personalized mixing
matrices — renormalizes the surviving mass per row and degrades
gracefully, with no strategy code changed.

``get_robust_aggregator("none")`` (and None) resolve to None — no screen,
no transform: byte-for-byte the undefended engine, which is both the
parity anchor and the bench's "attack demonstrably degrades" baseline.

All transforms are pure jnp on static shapes: they fuse into the PR-5
superstep unchanged and run under both placements.  Under partial
participation, non-transmitting rows enter with Δ=0; the order statistics
(trimmed_mean / median) treat those zeros as data — exact under the full
participation the anchors pin, a documented approximation under samplers.
"""
from __future__ import annotations

import abc
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax.numpy as jnp

from repro.fl.channel import stacked_ravel, stacked_unravel

ROBUST_AGGS: Dict[str, Callable[..., "RobustAggregator"]] = {}


def register_robust(name: str):
    def deco(cls):
        cls.name = name
        ROBUST_AGGS[name] = cls
        return cls
    return deco


class RobustAggregator(abc.ABC):
    """One robust transform on the (m, D) flat client deltas."""

    name: str

    @property
    def spec(self) -> str:
        return self.name

    @abc.abstractmethod
    def transform(self, delta: jnp.ndarray, keep: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(delta', keep'): ``delta`` is the screened (m, D) update stack
        (quarantined rows already zeroed), ``keep`` the (m,) float32
        survival weights.  Selection rules (krum) zero more of ``keep``;
        value rules (clip/trim/median) reshape ``delta``."""

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec!r})"


def _nan_where(delta: jnp.ndarray, keep: jnp.ndarray) -> jnp.ndarray:
    """Quarantined rows as NaN, so nan-aware order statistics skip them
    instead of counting their zeroed deltas."""
    return jnp.where(keep[:, None] > 0, delta, jnp.float32(jnp.nan))


@register_robust("clip")
class Clip(RobustAggregator):
    """Per-row L2 norm clip at a static bound ``c`` — the cheapest screen
    against magnitude attacks; direction attacks (sign flip) pass."""

    def __init__(self, c: float = 1.0):
        if c <= 0:
            raise ValueError(f"clip bound must be > 0, got {c}")
        self.c = float(c)

    @property
    def spec(self) -> str:
        return f"clip:{self.c:g}"

    def transform(self, delta, keep):
        norm = jnp.linalg.norm(delta, axis=1, keepdims=True)
        scale = jnp.minimum(1.0, self.c / jnp.maximum(norm, 1e-12))
        return delta * scale, keep


@register_robust("trimmed_mean")
class TrimmedMean(RobustAggregator):
    """Coordinate-wise winsorization at the (f, 1−f) quantiles of the
    surviving rows: every entry is clamped into the robust interval, so
    any downstream weighted mean IS a winsorized (trimmed-family) mean —
    the form that composes with per-client mixing matrices."""

    def __init__(self, f: float = 0.1):
        if not 0.0 < f < 0.5:
            raise ValueError("trimmed_mean fraction must be in (0, 0.5), "
                             f"got {f}")
        self.f = float(f)

    @property
    def spec(self) -> str:
        return f"trimmed_mean:{self.f:g}"

    def transform(self, delta, keep):
        nan_view = _nan_where(delta, keep)
        # inner order statistics ("higher"/"lower"), NOT interpolated:
        # linear interpolation would blend a fraction of an extreme
        # (possibly adversarial, possibly huge) value into the bound itself
        lo = jnp.nanquantile(nan_view, self.f, axis=0, method="higher")
        hi = jnp.nanquantile(nan_view, 1.0 - self.f, axis=0, method="lower")
        clamped = jnp.clip(delta, lo, hi)
        # all rows quarantined -> NaN bounds: keep the (zeroed) deltas
        return jnp.where(jnp.isnan(lo)[None, :], delta, clamped), keep


@register_robust("median")
class Median(RobustAggregator):
    """Coordinate-wise median of the surviving rows, broadcast to every
    row: the strongest value defense (breakdown 1/2) but personalization-
    free — all clients receive the same robust delta."""

    @property
    def spec(self) -> str:
        return "median"

    def transform(self, delta, keep):
        med = jnp.nanmedian(_nan_where(delta, keep), axis=0)
        med = jnp.where(jnp.isnan(med), 0.0, med)
        return jnp.broadcast_to(med[None, :], delta.shape), keep


@register_robust("krum")
class Krum(RobustAggregator):
    """Multi-Krum selection (Blanchard et al. 2017): score each client by
    the sum of its m−f−2 smallest squared distances to the others and
    quarantine the f highest-scoring clients (``f = round(frac·m)``
    assumed adversaries).  A pure selection rule: ``delta`` is untouched,
    ``keep`` shrinks — the quarantine reweighting renormalizes whatever
    mixing rule runs downstream."""

    def __init__(self, frac: float = 0.25):
        if not 0.0 < frac < 0.5:
            raise ValueError("krum byzantine fraction must be in (0, 0.5), "
                             f"got {frac}")
        self.frac = float(frac)

    @property
    def spec(self) -> str:
        return f"krum:{self.frac:g}"

    def transform(self, delta, keep):
        m = delta.shape[0]
        f = int(round(self.frac * m))
        if m - f - 2 < 1:       # cohort too small to score: keep everyone
            return delta, keep
        sq = jnp.sum((delta[:, None, :] - delta[None, :, :]) ** 2, axis=-1)
        inf = jnp.float32(jnp.inf)
        drop = keep <= 0
        sq = jnp.where(jnp.eye(m, dtype=bool) | drop[None, :]
                       | drop[:, None], inf, sq)
        nearest = jnp.sort(sq, axis=1)[:, :m - f - 2]
        score = jnp.sum(nearest, axis=1)
        score = jnp.where(drop, inf, score)
        # keep the m−f lowest-scoring clients (among survivors)
        cut = jnp.sort(score)[m - f - 1]
        selected = (score <= cut) & ~drop
        return delta, keep * selected.astype(keep.dtype)


def get_robust_aggregator(spec: Union[str, RobustAggregator, None]
                          ) -> Optional[RobustAggregator]:
    """``none | clip:<c> | trimmed_mean:<f> | median | krum:<f>`` ->
    `RobustAggregator` (None = no defense, the parity path)."""
    if spec is None or isinstance(spec, RobustAggregator):
        return spec
    family, _, param = str(spec).partition(":")
    if family == "none":
        if param:
            raise ValueError(f"robust aggregator 'none' takes no parameter, "
                             f"got {spec!r}")
        return None
    cls = ROBUST_AGGS.get(family)
    if cls is None:
        raise ValueError(f"unknown robust aggregator {spec!r}; one of "
                         f"none | {' | '.join(sorted(ROBUST_AGGS))}")
    try:
        return cls(float(param)) if param else cls()
    except TypeError:
        raise ValueError(f"robust aggregator {family!r} takes no parameter, "
                         f"got {spec!r}") from None


def screen_and_defend(agg: RobustAggregator, stacked: Any, prev: Any
                      ) -> Tuple[Any, jnp.ndarray]:
    """The full defense pipeline on the server-side decoded stack:
    non-finite screen -> robust transform.  Returns ``(stacked',
    quarantine)`` where ``quarantine`` is the (m,) float32 survival row
    (1 kept, 0 quarantined) for `quarantine_reweight`."""
    flat_prev = stacked_ravel(prev)
    delta = stacked_ravel(stacked) - flat_prev
    finite = jnp.all(jnp.isfinite(delta), axis=1)
    keep = finite.astype(jnp.float32)
    delta = jnp.where(finite[:, None], delta, 0.0)
    delta, keep = agg.transform(delta, keep)
    return stacked_unravel(flat_prev + delta, stacked), keep
