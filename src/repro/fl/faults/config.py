"""Fault-injection configuration (DESIGN.md §3g).

`FaultConfig` describes WHAT goes wrong — per-round client crash
probability, non-finite uploads, scaled/sign-flipped Byzantine updates,
update bit-rot — and `FaultPlan` is its once-per-run resolution at a
known population size (mirroring the hierarchy tier's `FleetPlan`): the
static Byzantine client set is drawn here from a private numpy Generator,
so the engines' JAX key schedule is never touched and the same seed gives
the same adversaries on every engine and placement.

The whole subsystem is off by default: ``resolve_fault_plan(None, m)``
and an all-zero-rate config both resolve to ``None``, and the engines'
``plan is None`` path is byte-for-byte the pre-faults code — the
faults-off parity anchor (tests/test_faults.py pins it bitwise).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union

import numpy as np

_BYZ_MODES = ("sign_flip", "scale")


@dataclass(frozen=True)
class FaultConfig:
    """Knobs of the per-round fault injector (DESIGN.md §3g).

    crash:          per-round probability each client crashes (no-show:
                    its update never reaches the server; sync engines
                    roll the row back, the async runtime retries the
                    arrival with exponential backoff).
    nan:            per-round probability a client uploads a non-finite
                    (NaN) update.
    byz:            fraction of the population that is Byzantine — a
                    STATIC client set drawn once per run from ``seed``
                    (``round(byz * m)`` clients), not a per-round coin.
    byz_mode:       what Byzantine clients transmit: ``sign_flip``
                    (−byz_scale · Δ, gradient-ascent attack) or ``scale``
                    (+byz_scale · Δ, magnitude attack).
    byz_scale:      magnitude multiplier of either mode.
    bitrot:         per-round probability a client's upload suffers
                    memory bit-rot; affected rows get one random IEEE-754
                    bit flipped in a ``bitrot_density`` fraction of their
                    update entries.
    bitrot_density: per-entry flip probability within a bit-rotted row.
    seed:           Byzantine-set draw + the async arrival-crash stream;
                    independent of the engines' JAX key schedule.
    """
    crash: float = 0.0
    nan: float = 0.0
    byz: float = 0.0
    byz_mode: str = "sign_flip"
    byz_scale: float = 10.0
    bitrot: float = 0.0
    bitrot_density: float = 1e-4
    seed: int = 0

    def __post_init__(self):
        for name in ("crash", "nan", "byz", "bitrot"):
            v = float(getattr(self, name))
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"faults: {name} must be a probability in "
                                 f"[0, 1], got {v}")
        if self.byz_mode not in _BYZ_MODES:
            raise ValueError(f"faults: unknown byz mode {self.byz_mode!r}; "
                             f"one of {' | '.join(_BYZ_MODES)}")
        if float(self.byz_scale) <= 0.0:
            raise ValueError("faults: byz_scale must be > 0, got "
                             f"{self.byz_scale}")
        if not 0.0 < float(self.bitrot_density) <= 1.0:
            raise ValueError("faults: bitrot_density must be in (0, 1], got "
                             f"{self.bitrot_density}")

    @property
    def active(self) -> bool:
        """Whether any fault can ever fire — all-zero rates are the
        faults-off parity path (`resolve_fault_plan` returns None)."""
        return (self.crash > 0 or self.nan > 0 or self.byz > 0
                or self.bitrot > 0)

    @property
    def spec(self) -> str:
        """Spec string that reparses to this config (History bookkeeping
        + checkpoint meta)."""
        parts = []
        if self.crash > 0:
            parts.append(f"crash:{self.crash:g}")
        if self.nan > 0:
            parts.append(f"nan:{self.nan:g}")
        if self.byz > 0:
            parts.append(f"byz:{self.byz:g}:{self.byz_mode}"
                         f":{self.byz_scale:g}")
        if self.bitrot > 0:
            parts.append(f"bitrot:{self.bitrot:g}:{self.bitrot_density:g}")
        if self.seed:
            parts.append(f"seed:{self.seed}")
        return ",".join(parts) if parts else "none"


def parse_fault_spec(spec: str) -> FaultConfig:
    """``crash:<p>,nan:<p>,byz:<f>[:<mode>[:<scale>]],bitrot:<p>[:<d>],
    seed:<s>`` -> `FaultConfig` (the ``--faults`` CLI grammar)."""
    kw = {}
    for part in str(spec).split(","):
        part = part.strip()
        if not part or part == "none":
            continue
        kind, _, rest = part.partition(":")
        args = rest.split(":") if rest else []
        try:
            if kind in ("crash", "nan", "bitrot") and 1 <= len(args) <= (
                    2 if kind == "bitrot" else 1):
                kw[kind] = float(args[0])
                if kind == "bitrot" and len(args) == 2:
                    kw["bitrot_density"] = float(args[1])
            elif kind == "byz" and 1 <= len(args) <= 3:
                kw["byz"] = float(args[0])
                if len(args) >= 2:
                    kw["byz_mode"] = args[1]
                if len(args) == 3:
                    kw["byz_scale"] = float(args[2])
            elif kind == "seed" and len(args) == 1:
                kw["seed"] = int(args[0])
            else:
                raise ValueError
        except ValueError:
            raise ValueError(
                f"bad fault spec entry {part!r}; entries are "
                "crash:<p> | nan:<p> | byz:<frac>[:<mode>[:<scale>]] | "
                "bitrot:<p>[:<density>] | seed:<int>") from None
    return FaultConfig(**kw)


class FaultPlan:
    """A `FaultConfig` resolved at population size ``m`` (once, in
    `init_run` — the `FleetPlan` pattern): the static Byzantine client
    set plus the async runtime's private arrival-crash stream."""

    def __init__(self, cfg: FaultConfig, m: int):
        self.cfg = cfg
        self.m = int(m)
        rng = np.random.default_rng(cfg.seed)
        n_byz = int(round(float(cfg.byz) * self.m))
        byz = np.zeros(self.m, dtype=bool)
        if n_byz:
            byz[rng.permutation(self.m)[:n_byz]] = True
        self.byz_mask = byz
        # arrival-level crash decisions (async runtime, DESIGN.md §3g):
        # one uniform draw per popped arrival, deterministic in the seed
        # and independent of both the clock's and the engines' streams
        self._rng = np.random.default_rng(np.random.SeedSequence(
            [int(cfg.seed), 0x5FA17]))

    @property
    def value_faults(self) -> bool:
        """Whether the traced value-fault transform does anything (the
        crash axis is handled by row rollback / arrival retry instead)."""
        return (self.cfg.nan > 0 or self.cfg.bitrot > 0
                or bool(self.byz_mask.any()))

    def byz_row(self, idx: Optional[np.ndarray] = None) -> np.ndarray:
        """(m,) — or cohort-gathered (k,) — float32 Byzantine indicator
        row, threaded through the superstep ``consts`` so per-cohort
        adversary sets never retrace the compiled round."""
        mask = self.byz_mask if idx is None else self.byz_mask[idx]
        return mask.astype(np.float32)

    def arrival_crash(self) -> bool:
        """The async runtime's crash coin for one popped arrival."""
        return bool(self._rng.random() < self.cfg.crash)

    def __repr__(self) -> str:
        return (f"FaultPlan({self.cfg.spec!r}, m={self.m}, "
                f"byzantine={np.flatnonzero(self.byz_mask).tolist()})")


def resolve_faults(faults: Union[str, FaultConfig, None]
                   ) -> Optional[FaultConfig]:
    """None | spec string | FaultConfig -> FaultConfig (or None).  An
    all-zero-rate config normalizes to None — the parity path."""
    if faults is None:
        return None
    if isinstance(faults, str):
        faults = parse_fault_spec(faults)
    if not isinstance(faults, FaultConfig):
        raise TypeError(f"cannot resolve faults from {faults!r}")
    return faults if faults.active else None


def resolve_fault_plan(faults: Union[str, FaultConfig, None],
                       m: int) -> Optional[FaultPlan]:
    """The engines' entry point: spec-ish -> `FaultPlan` at population m
    (None whenever no fault can ever fire)."""
    cfg = resolve_faults(faults)
    return None if cfg is None else FaultPlan(cfg, m)
