"""Deterministic seeded fault injection (DESIGN.md §3g).

Pure traced transforms on the stacked client update — they run unmodified
inside the PR-5 fused superstep on both placements.  Key derivation: the
engines hand in ``kfault = fold_in(kround, 3)`` (indices 1 and 2 are the
strategies' and the codec's derivations); every fault kind folds its own
constant off ``kfault``, so adding a fault axis never shifts another's
draws and a zero-rate axis is a compile-time no-op (the trace literally
does not contain it).

The value path works on the (m, D) flat delta view (`stacked_ravel`):
Byzantine scaling, NaN rows and bit-rot all corrupt WHAT THE CLIENT
TRANSMITS (Δ = update − prev), never the client's own resident state —
crash is the only fault that touches the client row itself (rollback to
``prev``/``prev_opt``, exactly a sampler no-show).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.fl.channel import stacked_ravel, stacked_unravel
from repro.fl.faults.config import FaultPlan


def crash_mask(plan: Optional[FaultPlan], kfault,
               m: int) -> Optional[jnp.ndarray]:
    """(m,) bool — True where the client crashes this round (sync
    engines; the async runtime draws crashes at the ARRIVAL level via
    `FaultPlan.arrival_crash` instead).  None when crashes are off."""
    if plan is None or plan.cfg.crash <= 0.0:
        return None
    return jax.random.bernoulli(jax.random.fold_in(kfault, 0),
                                plan.cfg.crash, (m,))


def inject_values(plan: FaultPlan, byz_row: jnp.ndarray, stacked: Any,
                  prev: Any, kfault,
                  rows: Optional[jnp.ndarray] = None) -> Any:
    """Apply the value faults (Byzantine scale/flip, NaN, bit-rot) to the
    transmitted update.  ``byz_row`` is the plan's (m,)/(k,) static
    adversary indicator (a traced ``consts`` input, so per-cohort rows
    never retrace the superstep); ``rows`` optionally restricts every
    fault to the rows that actually transmit this round (sampler
    participants / the async fresh cohort)."""
    if not plan.value_faults:
        return stacked
    cfg = plan.cfg
    flat_prev = stacked_ravel(prev)
    delta = stacked_ravel(stacked) - flat_prev
    m = delta.shape[0]

    hit = (jnp.ones((m,), bool) if rows is None
           else jnp.asarray(rows, bool))
    byz = (jnp.asarray(byz_row, jnp.float32) > 0.0) & hit
    factor = jnp.float32(-cfg.byz_scale if cfg.byz_mode == "sign_flip"
                         else cfg.byz_scale)
    delta = jnp.where(byz[:, None], factor * delta, delta)

    if cfg.bitrot > 0.0:
        rot = jax.random.bernoulli(jax.random.fold_in(kfault, 2),
                                   cfg.bitrot, (m,)) & hit
        elem = jax.random.bernoulli(jax.random.fold_in(kfault, 3),
                                    cfg.bitrot_density, delta.shape)
        bit = jax.random.randint(jax.random.fold_in(kfault, 4),
                                 delta.shape, 0, 32, dtype=jnp.int32)
        as_int = jax.lax.bitcast_convert_type(delta, jnp.int32)
        flipped = jax.lax.bitcast_convert_type(
            as_int ^ (jnp.int32(1) << bit), jnp.float32)
        delta = jnp.where(rot[:, None] & elem, flipped, delta)

    if cfg.nan > 0.0:
        bad = jax.random.bernoulli(jax.random.fold_in(kfault, 1),
                                   cfg.nan, (m,)) & hit
        delta = jnp.where(bad[:, None], jnp.float32(jnp.nan), delta)

    return stacked_unravel(flat_prev + delta, stacked)
