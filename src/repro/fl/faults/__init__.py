"""Fault-injection + resilient-runtime subsystem (DESIGN.md §3g).

Deterministic seeded client/device failure models (crash, NaN, Byzantine
scaling, bit-rot) injected as pure traced transforms; a screening +
robust-aggregation defense layer (`none | clip | trimmed_mean | median |
krum`) routed through quarantine reweighting so every strategy degrades
gracefully; async retry/backoff with a per-client cap; and run-level
fault accounting in ``History.extra["faults"]``.

Everything is off by default, and off is bit-identical to the seed
engines (the faults-off parity anchor, tests/test_faults.py).
"""
from repro.fl.faults.config import (FaultConfig, FaultPlan, parse_fault_spec,
                                    resolve_fault_plan, resolve_faults)
from repro.fl.faults.defense import (ROBUST_AGGS, RobustAggregator,
                                     get_robust_aggregator, register_robust,
                                     screen_and_defend)
from repro.fl.faults.inject import crash_mask, inject_values
from repro.fl.faults.runtime import FaultMeter, pop_with_retries

__all__ = ["FaultConfig", "FaultPlan", "parse_fault_spec",
           "resolve_fault_plan", "resolve_faults",
           "ROBUST_AGGS", "RobustAggregator", "get_robust_aggregator",
           "register_robust", "screen_and_defend",
           "crash_mask", "inject_values",
           "FaultMeter", "pop_with_retries"]
