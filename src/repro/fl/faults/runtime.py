"""Resilient-runtime bookkeeping + async retry loop (DESIGN.md §3g).

`FaultMeter` accumulates the per-round fault/defense counters every
engine books into ``History.extra["faults"]`` — crashes, quarantines,
quorum-skipped rounds, wasted uplink bits, async retries and dead
clients — so a defended run's degradation is auditable, not silent.

`pop_with_retries` is the shared arrival loop of both async engines
(resident `run_async` and `run_async_paged`): a popped arrival whose
crash coin fires is requeued at ``t + backoff · 2**attempt`` WITHOUT a
new compute draw (`VirtualClock.requeue`), so the clock's draw sequence
— and with it the faults-off parity anchor — never shifts; a client that
crashes ``max_retries + 1`` consecutive times is dead for the run.
"""
from __future__ import annotations

from typing import Dict, Optional, Set, Tuple

import numpy as np

from repro.fl.faults.config import FaultPlan


class FaultMeter:
    """Run-level fault/defense counters -> ``History.extra["faults"]``."""

    def __init__(self, plan: Optional[FaultPlan], robust_spec: str,
                 min_quorum: Optional[int]):
        self.plan = plan
        self.robust_spec = robust_spec
        self.min_quorum = min_quorum
        self.crashed = 0
        self.quarantined = 0
        self.skipped = 0
        self.rounds = 0
        self.wasted_ul_bits = 0
        self.retries = 0
        self.dead: Set[int] = set()

    def charge(self, crash_row: Optional[np.ndarray],
               q_row: Optional[np.ndarray], quorum_ok: bool,
               round_ul_bits: int, quarantined_ul_bits: int = 0) -> None:
        """Book one round/event: ``crash_row`` the (m,) host crash mask
        (None = no crash axis), ``q_row`` the (m,) quarantine survival
        row (None = no defense), ``round_ul_bits`` the bits every
        participant uploaded this round (all wasted when the quorum
        fails), ``quarantined_ul_bits`` the quarantined rows' share
        (wasted even when the round lands)."""
        self.rounds += 1
        if crash_row is not None:
            self.crashed += int(np.sum(crash_row))
        if q_row is not None:
            self.quarantined += int(np.sum(q_row <= 0))
        if not quorum_ok:
            self.skipped += 1
            self.wasted_ul_bits += int(round_ul_bits)
        else:
            self.wasted_ul_bits += int(quarantined_ul_bits)

    def extra(self) -> Dict:
        cfg = None if self.plan is None else self.plan.cfg
        return {
            "faults": "none" if cfg is None else cfg.spec,
            "byzantine_clients": ([] if self.plan is None else
                                  np.flatnonzero(self.plan.byz_mask)
                                  .tolist()),
            "robust_agg": self.robust_spec,
            "min_quorum": self.min_quorum,
            "rounds": self.rounds,
            "crashed_total": self.crashed,
            "quarantined_total": self.quarantined,
            "skipped_rounds": self.skipped,
            "wasted_ul_bits": self.wasted_ul_bits,
            "retries": self.retries,
            "dead_clients": sorted(self.dead),
        }


def pop_with_retries(clock, plan: Optional[FaultPlan], max_retries: int,
                     backoff: float, attempts: Dict[int, int],
                     meter: Optional[FaultMeter] = None
                     ) -> Optional[Tuple[float, int]]:
    """Pop the next arrival that survives its crash coin.

    Crashed arrivals are requeued at ``t + backoff · 2**attempt``
    (deterministic exponential backoff, no new compute draw); a client
    whose consecutive-crash count exceeds ``max_retries`` is marked dead
    and never rescheduled.  Returns ``(t, client)``, or None once the
    heap drains (every remaining client dead) — the engines end the run
    early with a pointed warning then."""
    while len(clock):
        t, c = clock.pop()
        if plan is None or not plan.arrival_crash():
            attempts[c] = 0         # success resets the backoff ladder
            return t, c
        a = attempts.get(c, 0)
        if a >= max_retries:
            if meter is not None:
                meter.dead.add(int(c))
            continue                # cap exhausted: gone for the run
        attempts[c] = a + 1
        if meter is not None:
            meter.retries += 1
        clock.requeue(c, t + float(backoff) * (2.0 ** a))
    return None
