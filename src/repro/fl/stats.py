"""Pre-round client statistics on stacked federated data.

Used by the UCFL strategy's `setup` (Eq. 6 inputs) but generic: any
strategy that needs full-dataset gradients or the Eq. 7 variance proxy at
the common initialization can reuse these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.similarity import flatten_pytree
from repro.data.federated import FederatedData


def full_client_gradients(loss_fn, params, fed: FederatedData) -> jnp.ndarray:
    """ĝ_i over each client's (padded) dataset; (m, D) float32."""

    def one(x_i, y_i):
        g, _ = jax.grad(loss_fn, has_aux=True)(params, {"x": x_i, "y": y_i})
        return flatten_pytree(g)

    return jax.vmap(one)(fed.x, fed.y)


def sigma2_estimates(loss_fn, params, fed: FederatedData, k_batches: int
                     ) -> jnp.ndarray:
    """Eq. 7 on contiguous K-way splits of each client's data."""
    n_max = fed.x.shape[1]
    bs = n_max // k_batches

    def one(x_i, y_i):
        gfull, _ = jax.grad(loss_fn, has_aux=True)(
            params, {"x": x_i, "y": y_i})
        gfull = flatten_pytree(gfull)
        devs = []
        for k in range(k_batches):
            sl = {"x": x_i[k * bs:(k + 1) * bs], "y": y_i[k * bs:(k + 1) * bs]}
            gk, _ = jax.grad(loss_fn, has_aux=True)(params, sl)
            devs.append(jnp.sum((flatten_pytree(gk) - gfull) ** 2))
        return jnp.mean(jnp.stack(devs))

    return jax.vmap(one)(fed.x, fed.y)
