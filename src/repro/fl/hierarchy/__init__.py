"""Hierarchical edge-aggregation tier (DESIGN.md §3f).

Each user owns a heterogeneous device fleet: every engine round first
runs an edge sub-round (per-device local updates, device→user uplinks
through the edge codec with error feedback, `EdgeAggregator` weighting)
and the resulting user pseudo-update feeds the existing user→server
round unchanged — all registered strategies run two-level unmodified.

    run_federated("ucfl_k2", fed,
                  hierarchy=HierarchyConfig(devices_per_user="ragged:2-4",
                                            edge_link="tiered:4",
                                            edge_codec="qsgd:4"))

``hierarchy=HierarchyConfig(devices_per_user=1)`` (identity edge codec,
mean aggregator, zero latency) is bit-identical to the flat engine on
both placements — the §3f parity anchor.
"""
from repro.fl.hierarchy.config import (HierarchyConfig, partition_fleet_data,
                                       resolve_fleet_spec, resolve_hierarchy)
from repro.fl.hierarchy.edge import (EDGE_AGGREGATORS, DropStragglers,
                                     EdgeAggregator, EdgeState, MeanEdge,
                                     build_fleet_update, cached_fleet_update,
                                     get_edge_aggregator,
                                     register_edge_aggregator)
from repro.fl.hierarchy.meter import (EdgeMeter, FleetPlan, fleet_plan,
                                      init_fleet_run)

__all__ = [
    "EDGE_AGGREGATORS", "DropStragglers", "EdgeAggregator", "EdgeMeter",
    "EdgeState", "FleetPlan", "HierarchyConfig", "MeanEdge",
    "build_fleet_update", "cached_fleet_update", "fleet_plan",
    "get_edge_aggregator", "init_fleet_run", "partition_fleet_data",
    "register_edge_aggregator", "resolve_fleet_spec", "resolve_hierarchy",
]
