"""Edge sub-round: per-device local updates + edge aggregation (§3f).

`build_fleet_update` compiles the whole edge sub-round of one user fleet
into a drop-in replacement for the engine's per-user update step — same
``update_fn(stacked, opt_state, x, y, n, ckeys) -> (stacked', opt_state')``
signature, with the device axis nested INSIDE: params/opt broadcast to
(m, d_max, ...), a ``vmap(vmap(client_update))`` over (user, device), the
device→user uplink through the edge codec with per-device error feedback,
and the `EdgeAggregator`'s weighted combine back to the (m, ...) user
stack.  The engine (sync, superstep, async) never learns about devices:
`EdgeState` rides in the opt-state slot, which the engine treats as
opaque, so sampler rollback, scan carries, donation and async cohort
gathers all work unchanged.

Flat-parity discipline (the PR 3–7 anchor rule): with one device per
user, the identity edge codec, the mean aggregator and no dropout, the
edge tier is MATHEMATICALLY the identity — and it is implemented AS the
identity (a degenerate shortcut running the flat per-user step on
squeezed views), because ``prev + 1.0·(new − prev)`` is not ``new`` in
IEEE-754.  Same precedent as `apply_uplink` returning its inputs
untouched for identity codecs.

Key derivation: per-device minibatch keys are ``vmap(split(·, d_max))``
of the engine's per-user keys; the edge codec key is
``fold_in(ckeys[0], 0x65646765)`` ("edge") and the device-dropout key its
``fold_in(·, 1)`` — disjoint from the engine's reserved indices 1
(strategy) and 2 (server codec), and never drawn on the flat path.
"""
from __future__ import annotations

import abc
import functools
import math
from typing import Any, ClassVar, Dict, NamedTuple, Optional, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.channel import stacked_ravel, stacked_unravel

_EDGE_SALT = 0x65646765     # "edge" — the edge codec's fold_in index


class EdgeState(NamedTuple):
    """The hierarchy run's opt-state slot: per-device optimizer states
    (m, d_max, ...) plus the per-device edge-EF residual stack (None for
    identity edge codecs).  Every leaf keeps the user axis leading, so
    the engine's row-wise select/gather/scatter machinery applies
    unchanged."""
    dev_opt: Any
    edge_ef: Any


class EdgeAggregator(abc.ABC):
    """How a user combines its devices' decoded updates (DESIGN.md §3f).

    ``weights(n, mask)`` is the traced rule: per-device sample counts
    (m, d_max) + participation mask -> normalized weight matrix (rows sum
    to 1 over surviving devices, all-zero rows when a user's whole fleet
    dropped — that user keeps its previous model).  Aggregators with
    host-side weighting set ``traceable=False`` and implement
    ``weights_host`` instead; the engine then routes the run through the
    eventful loop (same fallback contract as non-traceable strategies).
    ``static_keep`` may bake a host-side device-drop mask from the
    resolved fleet/rates (straggler dropping) — returning one marks the
    update non-row-local, so partial async events take the full-width
    update path."""

    name: ClassVar[str]
    traceable: ClassVar[bool] = True

    @property
    def spec(self) -> str:
        return self.name

    def static_keep(self, counts: np.ndarray, valid: np.ndarray,
                    rates_dl: Optional[np.ndarray]) -> Optional[np.ndarray]:
        """(m, d_max) bool device-keep mask resolved at plan time, or None
        (keep every valid device; the row-local default)."""
        return None

    def weights(self, n: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
        """Traced (m, d_max) normalized weights; pure jnp."""
        raise NotImplementedError(
            f"{type(self).__name__} sets traceable=True but does not "
            "implement weights")

    def weights_host(self, n: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """Host-side sibling for ``traceable=False`` aggregators."""
        raise NotImplementedError(
            f"{type(self).__name__} sets traceable=False but does not "
            "implement weights_host")

    # value objects: spec identity drives the fleet-update jit cache
    def __eq__(self, other) -> bool:
        return isinstance(other, EdgeAggregator) and self.spec == other.spec

    def __hash__(self) -> int:
        return hash((type(self).__name__, self.spec))

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec!r})"


EDGE_AGGREGATORS: Dict[str, Type[EdgeAggregator]] = {}


def register_edge_aggregator(cls: Type[EdgeAggregator]
                             ) -> Type[EdgeAggregator]:
    EDGE_AGGREGATORS[cls.name] = cls
    return cls


@register_edge_aggregator
class MeanEdge(EdgeAggregator):
    """Sample-weighted mean over surviving devices (the FedAvg-at-the-edge
    default): w_id ∝ n_id · mask_id, rows normalized; a row with no
    survivors aggregates nothing (all-zero weights)."""

    name = "mean"

    def weights(self, n, mask):
        wn = n.astype(jnp.float32) * mask.astype(jnp.float32)
        s = jnp.sum(wn, axis=1, keepdims=True)
        return jnp.where(s > 0.0, wn / jnp.maximum(s, 1e-12), 0.0)


@register_edge_aggregator
class DropStragglers(MeanEdge):
    """Mean weighting after statically dropping each user's slowest
    ``frac`` of devices (never its last one): ranked by edge downlink
    rate when an edge link is resolved, by device index (tail first)
    otherwise.  The keep mask is baked per-user at plan time, so partial
    async events fall back to the full-width update path
    (``row_local=False`` in the plan)."""

    name = "drop_stragglers"

    def __init__(self, frac: float = 0.5):
        if not 0.0 <= float(frac) < 1.0:
            raise ValueError("drop_stragglers frac must be in [0, 1), "
                             f"got {frac}")
        self.frac = float(frac)

    @property
    def spec(self) -> str:
        return f"{self.name}:{self.frac:g}"

    def static_keep(self, counts, valid, rates_dl):
        keep = np.asarray(valid, bool).copy()
        for i in range(keep.shape[0]):
            c = int(counts[i])
            n_drop = min(c - 1, int(math.floor(self.frac * c)))
            if n_drop <= 0:
                continue
            devs = np.arange(c)
            if rates_dl is not None:
                order = devs[np.argsort(rates_dl[i, :c], kind="stable")]
            else:
                order = devs[::-1]
            keep[i, order[:n_drop]] = False
        return keep


def get_edge_aggregator(spec) -> EdgeAggregator:
    """``"mean" | "drop_stragglers:<frac>"`` -> EdgeAggregator (instances
    pass through)."""
    if isinstance(spec, EdgeAggregator):
        return spec
    family, _, param = str(spec).partition(":")
    cls = EDGE_AGGREGATORS.get(family)
    if cls is None:
        raise ValueError(f"unknown edge aggregator {spec!r}; one of "
                         f"{sorted(EDGE_AGGREGATORS)}")
    if not param:
        return cls()
    try:
        return cls(float(param))
    except TypeError:
        raise ValueError(f"edge aggregator {family!r} takes no "
                         "parameter") from None
    except ValueError as e:
        if "could not convert" in str(e):
            raise ValueError(
                f"bad edge-aggregator parameter in {spec!r}") from None
        raise


# ---------------------------------------------------------------------------
# the fleet update step


def _squeeze_device_axis(tree):
    return jax.tree_util.tree_map(
        lambda l: l.reshape((l.shape[0],) + l.shape[2:]), tree)


def _unsqueeze_device_axis(tree):
    return jax.tree_util.tree_map(
        lambda l: l.reshape((l.shape[0], 1) + l.shape[1:]), tree)


def build_fleet_update(plan, client_update, *, backend: str,
                       edge_hook=None, donate: bool = False):
    """The edge sub-round as ONE engine-shaped update step; see module
    docstring.  ``plan`` is the resolved `FleetPlan`; ``client_update`` the
    per-client local-SGD step (`make_client_update`); ``edge_hook`` an
    optional traced weight refiner (`Strategy.edge_weights`, only passed
    when a strategy overrides it)."""
    cfg = plan.cfg
    codec, agg = plan.codec, cfg.edge_aggregator
    D = plan.d_max
    tm = jax.tree_util.tree_map

    if plan.flat_exact and edge_hook is None:
        # D == 1, identity edge codec, mean weights, no dropout: the edge
        # tier is the identity and runs AS the flat per-user step on
        # squeezed (m, ...) views — prev + 1.0·(new − prev) would NOT be
        # bit-equal to new, so the shortcut is what makes the flat-parity
        # anchor exact (edge latency/link stay meter-only and don't break
        # eligibility)
        def fleet_update(stacked, est, x, y, n, ckeys):
            new_p, new_o = jax.vmap(client_update)(
                stacked, _squeeze_device_axis(est.dev_opt),
                _squeeze_device_axis(x), _squeeze_device_axis(y),
                _squeeze_device_axis(n), ckeys)
            return new_p, EdgeState(_unsqueeze_device_axis(new_o),
                                    est.edge_ef)

        return jax.jit(fleet_update,
                       donate_argnums=(0, 1) if donate else ())

    keep_const = None if plan.keep is None else jnp.asarray(plan.keep)

    def device_phase(stacked, est, x, y, n, ckeys):
        """Per-device local updates + the edge channel crossing: returns
        (new_dev_opt, decoded per-device deltas, new edge EF, mask)."""
        dkeys = jax.vmap(lambda k: jax.random.split(k, D))(ckeys)
        dev_prev = tm(lambda l: jnp.broadcast_to(
            l[:, None], (l.shape[0], D) + l.shape[1:]), stacked)
        new_dev, new_opt = jax.vmap(jax.vmap(client_update))(
            dev_prev, est.dev_opt, x, y, n, dkeys)
        delta = tm(jnp.subtract, new_dev, dev_prev)
        ekey = jax.random.fold_in(ckeys[0], _EDGE_SALT)
        if codec.is_identity:
            dec, new_ef = delta, est.edge_ef
        else:
            # same EF algebra as the user→server hop (§3b), on the
            # (m·d_max, F) device-flat view — each DEVICE is one codec row
            v = tm(jnp.add, delta, est.edge_ef)
            merged = tm(lambda l: l.reshape((-1,) + l.shape[2:]), v)
            flat = stacked_ravel(merged)
            dec_flat = codec.roundtrip(flat, ekey, backend=backend)
            dec = tm(lambda a, b: a.reshape(b.shape),
                     stacked_unravel(dec_flat, merged), v)
            new_ef = (tm(jnp.subtract, v, dec)
                      if cfg.edge_error_feedback else est.edge_ef)
        # validity is derived IN-TRACE from n > 0 (row-local: survives the
        # async cohort gather); the static straggler mask, if any, marks
        # the plan non-row-local and async partial events go full-width
        mask = n > 0
        if keep_const is not None:
            mask = mask & keep_const
        if cfg.device_dropout > 0.0:
            up = jax.random.bernoulli(jax.random.fold_in(ekey, 1),
                                      1.0 - cfg.device_dropout, mask.shape)
            mask = mask & up
        return new_opt, dec, new_ef, mask

    def combine(stacked, dec, w):
        wf = w.astype(jnp.float32)

        def leaf(p, dl):
            wexp = wf.reshape(wf.shape + (1,) * (dl.ndim - 2))
            return (p + jnp.sum(wexp * dl, axis=1)).astype(p.dtype)

        return tm(leaf, stacked, dec)

    if agg.traceable:
        def fleet_update(stacked, est, x, y, n, ckeys):
            new_opt, dec, new_ef, mask = device_phase(stacked, est,
                                                      x, y, n, ckeys)
            w = agg.weights(n, mask)
            if edge_hook is not None:
                w = edge_hook(w, n)
            return combine(stacked, dec, w), EdgeState(new_opt, new_ef)

        return jax.jit(fleet_update,
                       donate_argnums=(0, 1) if donate else ())

    # eventful fallback (host-side edge weighting): jitted device phase,
    # host weights, jitted combine — no donation (the host crossing keeps
    # both sides alive) and no superstep (`superstep_support` routes the
    # run to the per-round loop)
    if edge_hook is not None:
        raise ValueError(
            f"strategy edge_weights hooks are traced; edge aggregator "
            f"{agg.spec!r} weights host-side (traceable=False)")
    phase_jit = jax.jit(device_phase)
    combine_jit = jax.jit(combine)

    def fleet_update(stacked, est, x, y, n, ckeys):
        new_opt, dec, new_ef, mask = phase_jit(stacked, est, x, y, n, ckeys)
        w = agg.weights_host(np.asarray(n), np.asarray(mask))
        new_stacked = combine_jit(stacked, dec,
                                  jnp.asarray(w, dtype=jnp.float32))
        return new_stacked, EdgeState(new_opt, new_ef)

    return fleet_update


@functools.lru_cache(maxsize=16)
def cached_fleet_update(backend: str, loss_fn, local_steps: int,
                        batch_size: int, lr: float, momentum: float,
                        state_dtype, donate: bool, plan, edge_hook=None):
    """(opt, fleet update step) memoized like `cached_update` — the plan's
    hash folds in fleet shape, static keep mask and the BOUND edge codec,
    so two runs over different fleets/links never share an executable
    while sweeps re-entering with one config reuse theirs.  The returned
    step's OBJECT identity also keys the superstep cache
    (`_superstep_cache`), giving each hierarchy config its own fused
    program for free."""
    from repro.fl.placement.host import _UpdateConfig, make_client_update
    from repro.optim import sgd
    opt = sgd(lr, momentum=momentum, state_dtype=state_dtype)
    client_update = make_client_update(
        loss_fn, opt, _UpdateConfig(local_steps, batch_size))
    return opt, build_fleet_update(plan, client_update, backend=backend,
                                   edge_hook=edge_hook, donate=donate)
