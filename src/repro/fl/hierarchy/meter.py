"""Fleet resolution + edge-hop accounting (DESIGN.md §3f).

`fleet_plan` resolves a `HierarchyConfig` against one run — per-user
device counts, the static validity/straggler masks, the edge link at
m·d_max reshaped (m, d_max), the BOUND edge codec (rate-adaptive edge
codecs pick their per-device parameters here, same precedent as
`init_channel`) and the per-user edge sub-round time.  The plan is the
single resolution point: the fleet update step closes over it, and the
`EdgeMeter` charges from it, so the two cannot drift.

`EdgeMeter` owns the device→user hop's books: per-round `ChannelCost`
(every participating device uploads one edge payload and downloads the
user model once per sub-round) and the edge time charged to BOTH clocks —
the sync engine adds ``max over participating users`` of the per-user
edge time to each round (`charge_round(edge=...)`), the async engine adds
each user's own edge time to its arrival draw (`VirtualClock.schedule
(extra=...)``).  With no edge link and zero latency every charge is
exactly 0.0 — `t + 0.0` is bit-exact, preserving the flat-parity anchor.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedData
from repro.fl.channel import (ChannelCost, LinkProfile, get_link_profile,
                              tree_bits)
from repro.fl.comm import SYSTEMS, SystemModel
from repro.fl.hierarchy.config import (HierarchyConfig, partition_fleet_data,
                                       resolve_fleet_spec)
from repro.fl.hierarchy.edge import EdgeState, cached_fleet_update


class FleetPlan:
    """One run's resolved hierarchy (see module docstring).  Hashable by
    (config, counts, keep mask, bound codec) — the fleet-update cache key."""

    def __init__(self, cfg: HierarchyConfig, m: int, params0: Any,
                 system: Optional[SystemModel]):
        self.cfg = cfg
        self.counts = resolve_fleet_spec(cfg.devices_per_user, m,
                                         seed=cfg.seed)
        self.m = m
        self.d_max = int(self.counts.max())
        self.valid = (np.arange(self.d_max)[None, :]
                      < self.counts[:, None])
        self.model_bits = tree_bits(params0)
        sysm = SYSTEMS["wired"] if system is None else system
        n_dev = m * self.d_max
        self.link = (get_link_profile(cfg.edge_link, sysm,
                                      self.model_bits, n_dev)
                     if cfg.edge_link is not None else None)
        # rate-adaptive edge codecs bind per-DEVICE (row = one device);
        # with no edge link they bind against the uniform from_system
        # profile and collapse to their minimum spec (the §3b precedent)
        bind_target = (self.link if self.link is not None
                       else LinkProfile.from_system(sysm, self.model_bits,
                                                    n_dev))
        self.codec = cfg.edge_codec.bind_link(bind_target, params0)
        self.payload_bits = int(self.codec.payload_bits(params0))
        self.pc_bits = np.asarray(
            self.codec.per_client_bits(params0, n_dev),
            np.int64).reshape(m, self.d_max)
        self.rates_dl = (self.link.dl_rate.reshape(m, self.d_max)
                         if self.link is not None else None)
        self.keep = cfg.edge_aggregator.static_keep(
            self.counts, self.valid, self.rates_dl)
        self.participating = (self.valid if self.keep is None
                              else (self.valid & self.keep))
        if self.link is not None:
            ratio = self.link.ul_ratio.reshape(m, self.d_max)
            hop = (self.payload_bits / self.rates_dl
                   + self.pc_bits * ratio / self.rates_dl)
            self.user_time = (float(cfg.edge_latency)
                              + np.where(self.participating, hop,
                                         0.0).max(axis=1))
        else:
            self.user_time = np.full(m, float(cfg.edge_latency))

    @property
    def row_local(self) -> bool:
        """Whether the fleet update is a pure row function of its inputs
        (no baked per-user constants): False under static straggler
        dropping — partial async events then take the full-width path."""
        return self.keep is None

    @property
    def flat_exact(self) -> bool:
        """Whether the fleet update may take the bit-exact flat shortcut
        (`repro.fl.hierarchy.edge`): latency/link stay out of the
        condition — they are meter-only and never touch the values."""
        return (self.d_max == 1 and self.codec.is_identity
                and self.cfg.edge_aggregator.spec == "mean"
                and self.cfg.device_dropout == 0.0)

    def _key(self):
        return (self.cfg, self.m, self.counts.tobytes(),
                None if self.keep is None else self.keep.tobytes(),
                self.codec)

    def __eq__(self, other) -> bool:
        return isinstance(other, FleetPlan) and self._key() == other._key()

    def __hash__(self) -> int:
        return hash(self._key())

    def __repr__(self) -> str:
        return (f"FleetPlan(m={self.m}, d_max={self.d_max}, "
                f"codec={self.codec.spec!r}, "
                f"agg={self.cfg.edge_aggregator.spec!r})")


def fleet_plan(cfg: HierarchyConfig, m: int, params0: Any,
               system: Optional[SystemModel]) -> FleetPlan:
    return FleetPlan(cfg, m, params0, system)


class EdgeMeter:
    """Per-round books of the device→user hop; built once per run from
    the plan (`run_federated`/`run_async` attach `extra()` as
    ``History.extra["hierarchy"]``)."""

    def __init__(self, plan: FleetPlan):
        self.plan = plan
        part = plan.participating
        self._n_dev = part.sum(axis=1).astype(np.int64)
        self._dl = self._n_dev * plan.payload_bits
        self._ul = np.where(part, plan.pc_bits, 0).sum(axis=1)
        self.user_time = plan.user_time
        self.costs: List[ChannelCost] = []

    def charge(self, mask_np: Optional[np.ndarray]) -> float:
        """One sync round's edge hop: records the participating users'
        device bits, returns the round's edge time (slowest participating
        user's sub-round — the analytic-clock sibling of the per-arrival
        charging in async)."""
        if mask_np is None:
            idx = slice(None)
            empty = self._dl.size == 0
        else:
            idx = np.where(mask_np)[0]
            empty = idx.size == 0
        if empty:
            self.costs.append(ChannelCost(0, 0))
            return 0.0
        self.costs.append(ChannelCost(int(self._dl[idx].sum()),
                                      int(self._ul[idx].sum())))
        return float(self.user_time[idx].max())

    def charge_event(self, buffered) -> None:
        """One async event's edge hop (bits only — each arrival's edge
        TIME is already inside its clock draw via ``schedule(extra=)``):
        every buffered user ran one edge sub-round before uploading."""
        idx = np.asarray(buffered, np.int64)
        self.costs.append(ChannelCost(int(self._dl[idx].sum()),
                                      int(self._ul[idx].sum())))

    def time_of(self, client: int) -> float:
        """User's edge sub-round time — the async arrival's ``extra``."""
        return float(self.user_time[client])

    def extra(self) -> dict:
        plan = self.plan
        return {
            "devices_per_user": plan.counts.tolist(),
            "d_max": plan.d_max,
            "edge_codec": plan.codec.spec,
            "edge_aggregator": plan.cfg.edge_aggregator.spec,
            "edge_error_feedback": bool(plan.cfg.edge_error_feedback),
            "edge_link": (plan.link.name if plan.link is not None
                          else None),
            "edge_latency": float(plan.cfg.edge_latency),
            "device_dropout": float(plan.cfg.device_dropout),
            "edge_payload_bits": plan.payload_bits,
            "user_edge_time": plan.user_time.tolist(),
            # the device→user hop's per-round bits — `History.comm_bits`
            # stays the user→server hop, so the two hops stay separable
            "comm_bits": list(self.costs),
            "edge_dl_bits_total": int(sum(c.dl_bits for c in self.costs)),
            "edge_ul_bits_total": int(sum(c.ul_bits for c in self.costs)),
        }


def init_fleet_run(cfg: HierarchyConfig, placement, loss_fn, fl,
                   fed: FederatedData, params0: Any, *,
                   system: Optional[SystemModel], donate: bool,
                   strategy=None):
    """Hierarchy sibling of the `init_run` placement block: resolves the
    plan, builds/caches the fleet update, places the device-partitioned
    data and the (m, d_max, ...) `EdgeState`.  Returns
    ``(update_fn, stacked, opt_state, data, plan)``."""
    from repro.fl.strategies import Strategy
    m = fed.m
    plan = fleet_plan(cfg, m, params0, system)
    edge_hook = None
    if (strategy is not None
            and type(strategy).edge_weights is not Strategy.edge_weights):
        edge_hook = strategy.edge_weights
    opt, update_fn = cached_fleet_update(
        placement.codec_backend, loss_fn, fl.local_steps, fl.batch_size,
        fl.lr, fl.momentum, getattr(fl, "opt_state_dtype", None),
        donate, plan, edge_hook)
    stacked = placement.stack(params0, m)
    dev0 = jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[:, None],
                                   (l.shape[0], plan.d_max) + l.shape[1:]),
        stacked)
    dev_opt = jax.vmap(jax.vmap(opt.init))(dev0)
    edge_ef = (None if plan.codec.is_identity else
               jax.tree_util.tree_map(
                   lambda l: jnp.zeros(l.shape, jnp.float32), dev0))
    data = placement.place_fleet(
        partition_fleet_data(fed, plan.counts, plan.d_max), m)
    return update_fn, stacked, EdgeState(dev_opt, edge_ef), data, plan
