"""Hierarchy tier configuration (DESIGN.md §3f).

Each *user* (the paper's flat client) owns a fleet of unequal *devices*.
`HierarchyConfig` describes the two-level round: how many devices each
user has (ragged — padded to a static `d_max` so the edge sub-round stays
traceable), how device uploads cross the edge channel (codec + error
feedback at per-device `LinkProfile` rates), and how the user combines
them into its pseudo-update (`EdgeAggregator`, optional Bernoulli device
dropout, optional straggler dropping).

The flat configuration — one device per user, identity edge codec, mean
aggregator, zero edge latency, no edge link — is BIT-IDENTICAL to the
flat engine on both placements: `resolve_fleet_spec` then yields d_max=1,
`partition_fleet_data` is a pure `[:, None]` view of the flat client
arrays, and the fleet update takes a degenerate shortcut that IS the flat
per-user step (see `repro.fl.hierarchy.edge`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Tuple, Union

import numpy as np

from repro.data.federated import FederatedData
from repro.fl.channel import get_codec


@dataclass(frozen=True)
class HierarchyConfig:
    """Knobs of the edge-aggregation tier (DESIGN.md §3f).

    devices_per_user:    int (uniform fleet), ``"uniform:<D>"``,
                         ``"ragged:<min>-<max>"`` (deterministic per-user
                         counts from ``seed``) or an explicit per-user
                         tuple.  1 = the flat-parity anchor.
    edge_aggregator:     `EdgeAggregator` spec string or instance —
                         ``mean`` (sample-weighted) or
                         ``drop_stragglers:<frac>`` (drop each user's
                         slowest ``frac`` devices before weighting).
    edge_codec:          device→user uplink `Codec` spec/instance; the
                         identity codec skips the edge value path entirely
                         (flat-parity anchor).
    edge_error_feedback: carry per-device EF residuals across sub-rounds
                         (same algebra as the user→server channel, §3b).
    edge_link:           per-device link spec (``uniform | tiered:<f> |
                         lognormal:<s>``) resolved at m·d_max and reshaped
                         (m, d_max), or None — no edge link: the backhaul
                         is free and the edge hop charges only
                         ``edge_latency``.
    edge_latency:        fixed per-sub-round latency added to every user's
                         edge hop (units of T_dl).  0 = flat anchor.
    device_dropout:      per-(event, device) Bernoulli drop probability at
                         the edge — a dropped device's upload is lost for
                         that sub-round (its EF residual still carries the
                         tail forward).
    seed:                ragged-fleet / edge-link derivations only; the
                         engines' JAX key schedule is never touched.
    """
    devices_per_user: Union[int, str, Tuple[int, ...]] = 1
    edge_aggregator: Any = "mean"
    edge_codec: Any = "identity"
    edge_error_feedback: bool = True
    edge_link: Optional[str] = None
    edge_latency: float = 0.0
    device_dropout: float = 0.0
    seed: int = 0

    def __post_init__(self):
        from repro.fl.hierarchy.edge import get_edge_aggregator
        object.__setattr__(self, "edge_codec", get_codec(self.edge_codec))
        object.__setattr__(self, "edge_aggregator",
                           get_edge_aggregator(self.edge_aggregator))
        if isinstance(self.devices_per_user, list):
            object.__setattr__(self, "devices_per_user",
                               tuple(int(d) for d in self.devices_per_user))
        # fail at construction, not inside a traced fleet update
        resolve_fleet_spec(self.devices_per_user, m=2, seed=self.seed)
        if not 0.0 <= float(self.device_dropout) < 1.0:
            raise ValueError("device_dropout must be in [0, 1), got "
                             f"{self.device_dropout}")
        if float(self.edge_latency) < 0.0:
            raise ValueError("edge_latency must be >= 0, got "
                             f"{self.edge_latency}")

    def __hash__(self):
        return hash((self.devices_per_user, self.edge_aggregator.spec,
                     self.edge_codec, self.edge_error_feedback,
                     self.edge_link, self.edge_latency,
                     self.device_dropout, self.seed))


def resolve_hierarchy(hierarchy) -> Optional[HierarchyConfig]:
    """None | int | spec-ish | HierarchyConfig -> HierarchyConfig (or None).
    An int is the `devices_per_user` convenience (CLI `--devices-per-user`)."""
    if hierarchy is None or isinstance(hierarchy, HierarchyConfig):
        return hierarchy
    if isinstance(hierarchy, (int, str, tuple, list)):
        return HierarchyConfig(devices_per_user=hierarchy)
    raise TypeError(f"cannot resolve hierarchy from {hierarchy!r}")


def resolve_fleet_spec(spec, m: int, seed: int = 0) -> np.ndarray:
    """devices-per-user spec -> (m,) int64 device counts (all >= 1).

    ``ragged:<min>-<max>`` draws each user's count uniformly from
    [min, max] with a private numpy Generator — deterministic in ``seed``
    and independent of the engines' JAX key schedule."""
    if isinstance(spec, (tuple, list)):
        counts = np.asarray(spec, np.int64)
        if counts.shape != (m,):
            raise ValueError(f"devices_per_user tuple must have one entry "
                             f"per user (m={m}), got shape {counts.shape}")
    elif isinstance(spec, (int, np.integer)):
        counts = np.full(m, int(spec), np.int64)
    else:
        family, _, param = str(spec).partition(":")
        if family == "uniform":
            try:
                counts = np.full(m, int(param), np.int64)
            except ValueError:
                raise ValueError(
                    f"bad devices-per-user spec {spec!r}") from None
        elif family == "ragged":
            try:
                lo, _, hi = param.partition("-")
                lo, hi = int(lo), int(hi)
            except ValueError:
                raise ValueError(
                    f"bad devices-per-user spec {spec!r}; expected "
                    "ragged:<min>-<max>") from None
            if not 1 <= lo <= hi:
                raise ValueError("ragged devices-per-user needs "
                                 f"1 <= min <= max, got {spec!r}")
            rng = np.random.default_rng(seed)
            counts = rng.integers(lo, hi + 1, size=m).astype(np.int64)
        else:
            raise ValueError(
                f"unknown devices-per-user spec {spec!r}; one of <int> | "
                "uniform:<D> | ragged:<min>-<max> | per-user tuple")
    if np.any(counts < 1):
        raise ValueError(f"every user needs >= 1 device, got {counts}")
    return counts


def partition_fleet_data(fed: FederatedData, counts: np.ndarray,
                         d_max: int):
    """Split each user's stacked train arrays across its devices.

    Returns ``(x, y, n)`` with a nested device axis — x (m, d_max, slots,
    ...), y (m, d_max, slots), n (m, d_max) — device d of user i holding
    the strided shard ``x_i[d::counts[i]]`` of the user's TRUE samples
    (deterministic, no RNG).  Shards are padded to the fleet-wide slot
    count by cyclic repetition (the partitioners' own padding convention:
    draws are by index mod n, so padding is never over-sampled); invalid
    device slots (d >= counts[i]) hold zeros and n=0 — the edge aggregator
    gives them zero weight.

    d_max == 1 returns pure ``[:, None]`` views of the flat arrays — the
    flat-parity anchor partitions nothing."""
    if d_max == 1:
        return fed.x[:, None], fed.y[:, None], fed.n[:, None]
    m = fed.m
    x_np = np.asarray(fed.x)
    y_np = np.asarray(fed.y)
    n_np = np.asarray(fed.n)
    n_int = np.maximum(n_np.astype(np.int64), 1)
    d_idx = np.arange(d_max, dtype=np.int64)[None, :]
    # device d gets ceil((n_i - d) / c_i) of user i's n_i true samples
    n_dev = np.maximum(
        (n_int[:, None] - d_idx + counts[:, None] - 1) // counts[:, None], 0)
    n_dev = np.where(d_idx < counts[:, None], n_dev, 0)
    slots = int(max(1, n_dev.max()))
    x_out = np.zeros((m, d_max, slots) + x_np.shape[2:], x_np.dtype)
    y_out = np.zeros((m, d_max, slots) + y_np.shape[2:], y_np.dtype)
    for i in range(m):
        xi, yi = x_np[i, :n_int[i]], y_np[i, :n_int[i]]
        for d in range(int(counts[i])):
            if not n_dev[i, d]:
                continue
            xs, ys = xi[d::counts[i]], yi[d::counts[i]]
            x_out[i, d] = np.resize(xs, x_out.shape[2:])
            y_out[i, d] = np.resize(ys, y_out.shape[2:])
    return x_out, y_out, n_dev.astype(n_np.dtype)
