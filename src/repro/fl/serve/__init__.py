"""Personalized-model serving plane (DESIGN.md §3d).

`run_federated(keep_state=True)` trains one personalized model per user;
this package serves them: `DeltaStore` holds the k stream base models
plus per-user codec-compressed deltas with exact bit accounting, and
`ServeEngine` batches concurrent requests into one gather + decode +
vmapped forward per batch, on either placement.

    h = run_federated("ucfl_k2", fed, keep_state=True)
    store = DeltaStore.from_history(h, codec="qsgd:4")
    engine = ServeEngine(store, apply_fn)
    engine.submit(user=3, x=x3); engine.submit(user=0, x=x0)
    y3, y0 = engine.flush()
"""
from __future__ import annotations

from repro.fl.serve.engine import ServeEngine, check_parity
from repro.fl.serve.store import DeltaStore, StoreBits

__all__ = ["DeltaStore", "ServeEngine", "StoreBits", "check_parity"]
