"""Batched personalized-model serving engine (DESIGN.md §3d).

Request path, two jitted stages per batch size:

  1. ``params_for(users)`` — ONE batched gather of the users' base rows +
     encoded delta rows from the `DeltaStore`, decoded for just those B
     rows (`Codec.decode`: Pallas dequant kernels on `HostVmap`,
     GSPMD-friendly pure-jnp ops on `MeshShardMap`), re-added and
     unraveled to a (B, ...) stacked parameter pytree;
  2. ``forward(params, xs)`` — a single ``vmap(apply_fn)`` over the batch.

The micro-batcher (`submit`/`flush`) groups concurrent requests by the
users' stream assignment so a batch's base-row gather touches few distinct
base models, chunks to ``max_batch``, and returns outputs in submit order.

Parity anchor (`check_parity`, enforced in tests AND the `--serve`
bench): stage 2 is shared, so the served output must match a direct
forward pass through `DeltaStore.params_flat` (decode-everything-then-
gather) reconstructed params — BIT-IDENTICAL for the ``identity`` codec
on both placements.  For lossy codecs the two decode paths compute the
same dequant algebra under different XLA fusion scopes (the batched
gather fuses dequant into the base re-add, the reference path rounds
separately), so the anchor instead pins the reconstructed params to
within a few ulps between paths and the outputs to float-reassociation
tolerance; the codecs' divergence from the user's TRUE trained params is
bounded separately at store build time (`Codec.store_bound`).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.placement import resolve_placement
from repro.fl.serve.store import DeltaStore


class ServeEngine:
    """Micro-batching request engine over one `DeltaStore`.

    apply_fn(params, x) -> output for ONE user's params and ONE request
    payload; the engine vmaps it over the batch.  ``placement`` selects
    where batches land (`HostVmap` default; `MeshShardMap` shards the
    batch over its client axis) and which codec backend decodes deltas.
    """

    def __init__(self, store: DeltaStore, apply_fn: Callable, *,
                 placement=None, max_batch: int = 32):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.store = store
        self.apply_fn = apply_fn
        self.placement = resolve_placement(placement)
        self.backend = self.placement.codec_backend
        self.max_batch = int(max_batch)
        self._gather_jit: Dict[int, Callable] = {}       # B -> stage 1
        self._forward_jit: Optional[Callable] = None     # stage 2 (shared)
        self._pending: List[Tuple[int, int, Any]] = []   # (ticket, user, x)
        self._tickets = 0
        self.last_stats: Dict[str, Any] = {}

    # ---- stage 1: batched gather + decode ----------------------------------

    def _gather_fn(self, b: int) -> Callable:
        fn = self._gather_jit.get(b)
        if fn is None:
            store, backend = self.store, self.backend

            def gather(users, rows, base_flat, payload, fv, fi):
                base = jnp.take(base_flat, rows, axis=0)        # (B, D)
                enc = {k: jnp.take(v, users, axis=0)
                       for k, v in payload.items()}
                delta = store.codec.decode(enc, backend=backend, d=store.d)
                flat = store.apply_fix(base + delta,
                                       jnp.take(fv, users, axis=0),
                                       jnp.take(fi, users, axis=0))
                return store.unravel_batch(flat)

            fn = self._gather_jit[b] = jax.jit(gather)
        return fn

    def params_for(self, users: Sequence[int]) -> Any:
        """Personalized params for ``users`` as a (B, ...) stacked pytree:
        gather-THEN-decode — only the B requested delta rows are decoded."""
        users_np = np.asarray(users, np.int64).ravel()
        b = users_np.shape[0]
        users_j = jnp.asarray(users_np, jnp.int32)
        rows_j = jnp.asarray(self.store.assignment[users_np], jnp.int32)
        params = self._gather_fn(b)(users_j, rows_j, self.store.base_flat,
                                    self.store.payload,
                                    self.store.fix_values,
                                    self.store.fix_indices)
        return self.placement.place_stack(params, b)

    # ---- stage 2: one vmapped forward per batch -----------------------------

    def forward(self, params: Any, xs: Any) -> Any:
        """``vmap(apply_fn)`` over the batch — the SAME compiled function
        serves requests and the parity reference path."""
        if self._forward_jit is None:
            self._forward_jit = jax.jit(jax.vmap(self.apply_fn))
        return self._forward_jit(params, xs)

    def serve(self, users: Sequence[int], xs: Any) -> Any:
        """One batch end-to-end: params gather/decode + vmapped forward."""
        b = np.asarray(users).size
        xs = self.placement.place_stack(jnp.asarray(xs), b)
        return self.forward(self.params_for(users), xs)

    # ---- micro-batcher -------------------------------------------------------

    def submit(self, user: int, x: Any) -> int:
        """Queue one request; returns its ticket (index into `flush`'s
        output list)."""
        t = self._tickets
        self._tickets += 1
        self._pending.append((t, int(user), np.asarray(x)))
        return t

    def flush(self) -> List[np.ndarray]:
        """Serve every pending request: sort by (stream, user) so each
        batch gathers few distinct base rows, chunk to ``max_batch``, one
        gather+decode and one vmapped forward per chunk.  Returns outputs
        in submit order; per-chunk wall latencies land in `last_stats`."""
        pending, self._pending = self._pending, []
        self._tickets = 0
        if not pending:
            self.last_stats = {"requests": 0, "batches": 0, "latency_s": []}
            return []
        asn = self.store.assignment
        order = sorted(range(len(pending)),
                       key=lambda i: (asn[pending[i][1]], pending[i][1],
                                      pending[i][0]))
        outputs: List[Optional[np.ndarray]] = [None] * len(pending)
        latencies = []
        for lo in range(0, len(order), self.max_batch):
            chunk = [pending[i] for i in order[lo:lo + self.max_batch]]
            users = np.asarray([c[1] for c in chunk], np.int64)
            xs = np.stack([c[2] for c in chunk])
            t0 = time.perf_counter()
            out = jax.block_until_ready(self.serve(users, xs))
            latencies.append(time.perf_counter() - t0)
            out_np = np.asarray(out)
            for j, (ticket, _, _) in enumerate(chunk):
                outputs[ticket] = out_np[j]
        self.last_stats = {"requests": len(pending),
                           "batches": len(latencies),
                           "latency_s": latencies}
        return outputs                       # type: ignore[return-value]


# lossy codecs only: ulps of per-row param slack between the two decode
# paths (the jitted gather may fuse dequant·scale into the base re-add —
# one rounding — where the eager reference rounds twice), and the matching
# relative output tolerance for the forward through those params
_PARITY_ULPS = 8.0
_PARITY_RTOL = 1e-5


def check_parity(engine: ServeEngine, users: Sequence[int], xs: Any,
                 served: Any = None) -> float:
    """The §3d serving parity anchor: the engine's gather-then-decode
    output must equal a direct forward pass through the store's decode-
    everything reference reconstruction — BIT-IDENTICAL for the
    ``identity`` codec on every placement; for lossy codecs the two
    paths' reconstructed params must agree within `_PARITY_ULPS` ulps
    (XLA fusion reassociation, module docstring) and the outputs within
    `_PARITY_RTOL`.  Raises on divergence; returns the max |served| as a
    liveness datum."""
    users_np = np.asarray(users, np.int64).ravel()
    b = users_np.shape[0]
    xs = engine.placement.place_stack(jnp.asarray(xs), b)
    if served is None:
        served = engine.serve(users_np, xs)
    ref_flat = engine.store.params_flat(users_np, backend=engine.backend)
    ref_params = engine.placement.place_stack(
        engine.store.unravel_batch(ref_flat), b)
    direct = engine.forward(ref_params, xs)
    served_np, direct_np = np.asarray(served), np.asarray(direct)

    def fail(why: str):
        raise RuntimeError(
            "serving parity anchor violated: served output != direct "
            f"forward through reconstructed params ({why}; codec="
            f"{engine.store.codec.spec}, placement="
            f"{type(engine.placement).__name__})")

    if served_np.shape != direct_np.shape:
        fail(f"shape {served_np.shape} != {direct_np.shape}")
    exact = np.array_equal(served_np, direct_np)
    if engine.store.codec.is_identity:
        if not exact:
            bad = np.max(np.abs(served_np.astype(np.float64)
                                - direct_np.astype(np.float64)))
            fail(f"identity codec must be bit-identical, max|diff|={bad:.3e}")
    elif not exact:
        # both decode paths inside the same float-reassociation envelope?
        from repro.fl.channel import stacked_ravel
        got = np.asarray(stacked_ravel(
            jax.device_get(engine.params_for(users_np))))
        ref = np.asarray(ref_flat)
        # f32 ulps: the params are float32, so one reassociated rounding
        # moves a value by spacing(max|row|) in f32 terms
        slack = _PARITY_ULPS * np.spacing(
            np.max(np.abs(ref), axis=1).astype(np.float32)).astype(np.float64)
        perr = np.max(np.abs(got.astype(np.float64)
                             - ref.astype(np.float64)), axis=1)
        if np.any(perr > slack):
            fail(f"two-path param divergence {perr.max():.3e} > "
                 f"{_PARITY_ULPS} ulp slack")
        oerr = np.max(np.abs(served_np.astype(np.float64)
                             - direct_np.astype(np.float64)))
        scale = max(float(np.max(np.abs(direct_np))), 1e-30)
        if oerr > _PARITY_RTOL * scale:
            fail(f"output divergence {oerr:.3e} > rtol {_PARITY_RTOL} "
                 f"of {scale:.3e}")
    return float(np.max(np.abs(served_np)))
