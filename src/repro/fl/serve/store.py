"""Per-user personalized-model delta store (DESIGN.md §3d).

`run_federated(keep_state=True)` ends with an (m, ...) client-stacked
parameter pytree — one personalized model per user.  Storing m full
models is exactly the deployment cost the personalization literature
flags as the practical bottleneck; this store keeps instead

  * the k stream/cluster BASE models (one representative per stream of
    the strategy's client→stream map — `StreamPlan` assignment, CFL
    clusters, or a byte-level dedup of identical rows), flat (k, D);
  * one per-user personalization DELTA against the user's base, encoded
    at rest with a PR 4 `Codec` (``identity | qsgd:<bits> | topk:<frac>``)
    in its row-gatherable wire format (`Codec.encode`/`decode`);

so storage cost rides the same exact bit accounting as training comm
(`channel/payload.py`).  Reconstruction contract, enforced at build time:

  * ``identity`` — bit-exact.  ``fl(base + fl(x − base)) != x`` in
    general, and for magnitude-mismatched elements (|x| ≪ |base|) NO
    single f32 delta reproduces x, so reconstruction is the two-term
    error-free transform ``fl(fl(base + delta) + fix)``: the delta is
    iteratively refined, then a SPARSE per-user fixup (value, index)
    catches the few elements the one-add grid cannot reach.  The fixup's
    64 bits/entry ride the bit accounting;
  * lossy codecs — per-user max-abs error within the codec's documented
    bound (`Codec.store_bound`): the per-row quantization scale for qsgd,
    the k-th magnitude for top-k, plus 4 ulp of re-add slack.  The fixup
    is empty — the bound already covers the re-add.

`save`/`load` persist through `repro.checkpoint` (msgpack; dict/list/
array pytrees only — the template rides as a zeros pytree).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.fl.channel import get_codec, stacked_ravel, stacked_unravel
from repro.fl.channel.payload import tree_bits

_REFINE_ITERS = 8
# float re-add slack on top of the codec's own bound: reconstruct does two
# f32 rounding steps (encode-side subtract, decode-side add) per element
_ULP_SLACK = 4.0


@dataclass(frozen=True)
class StoreBits:
    """Exact at-rest size: k base models + m encoded deltas."""
    base_bits: int
    delta_bits: np.ndarray              # (m,) per-user encoded delta bits

    @property
    def total_bits(self) -> int:
        return int(self.base_bits) + int(self.delta_bits.sum())

    @property
    def total_bytes(self) -> int:
        return (self.total_bits + 7) // 8


class DeltaStore:
    """k base models + per-user codec-encoded deltas; see module docstring.

    Construct via `from_history` / `build` / `load` — the raw constructor
    takes already-validated pieces.
    """

    def __init__(self, *, base_flat, assignment, codec, payload, template,
                 recon_err, delta_bits, fix_values, fix_indices,
                 seed: int = 0, backend: str = "pallas"):
        self.base_flat = jnp.asarray(base_flat, jnp.float32)    # (k, D)
        self.assignment = np.asarray(assignment, np.int64)      # (m,)
        self.codec = get_codec(codec)
        self.payload = {k: jnp.asarray(v) for k, v in payload.items()}
        self.template = template          # single-model pytree of np zeros
        # sparse two-term fixup, (m, K) value/index pairs (K may be 0):
        # applied AFTER the base+delta add — see module docstring
        self.fix_values = jnp.asarray(fix_values, jnp.float32)
        self.fix_indices = jnp.asarray(fix_indices, jnp.int32)
        self.recon_err = np.asarray(recon_err, np.float64)      # (m,)
        self.seed = int(seed)
        self.backend = backend
        # raw codec bits kept separate so save/load doesn't double-count
        # the fixup entries
        self._delta_bits_raw = np.asarray(delta_bits, np.int64)
        fix_bits = 64 * np.count_nonzero(np.asarray(fix_values), axis=1)
        self.bits = StoreBits(
            base_bits=self.k * tree_bits(template),
            delta_bits=self._delta_bits_raw + fix_bits)
        self._asn_dev = jnp.asarray(self.assignment, jnp.int32)

    # ---- shape facts -------------------------------------------------------

    @property
    def m(self) -> int:
        return int(self.assignment.shape[0])

    @property
    def k(self) -> int:
        return int(self.base_flat.shape[0])

    @property
    def d(self) -> int:
        return int(self.base_flat.shape[1])

    def summary(self) -> Dict[str, Any]:
        return {"codec": self.codec.spec, "m": self.m, "k": self.k,
                "d": self.d, "base_bits": int(self.bits.base_bits),
                "delta_bits": int(self.bits.delta_bits.sum()),
                "total_bytes": int(self.bits.total_bytes),
                "max_recon_err": float(self.recon_err.max())}

    # ---- reconstruction ----------------------------------------------------

    def unravel_batch(self, flat: jnp.ndarray) -> Any:
        """(B, D) flat rows -> stacked parameter pytree with leading B."""
        b = flat.shape[0]
        like = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct((b,) + tuple(l.shape), l.dtype),
            self.template)
        return stacked_unravel(flat, like)

    @staticmethod
    def apply_fix(flat: jnp.ndarray, fix_values: jnp.ndarray,
                  fix_indices: jnp.ndarray) -> jnp.ndarray:
        """Second term of the error-free reconstruction: add the sparse
        per-row fixups ONTO the already-added (rows, D) flat params.
        Padding entries are (0.0, 0) — adding 0 is exact."""
        if fix_values.shape[1] == 0:
            return flat
        rows = jnp.arange(flat.shape[0], dtype=jnp.int32)[:, None]
        return flat.at[rows, fix_indices].add(fix_values)

    def params_flat(self, users: Optional[Sequence[int]] = None,
                    *, backend: Optional[str] = None) -> jnp.ndarray:
        """Decode the FULL store, then gather ``users``' rows — the
        reference path the serving engine's gather-then-decode is checked
        against (`check_parity`)."""
        backend = self.backend if backend is None else backend
        dec = self.codec.decode(self.payload, backend=backend, d=self.d)
        flat = jnp.take(self.base_flat, self._asn_dev, axis=0) + dec
        flat = self.apply_fix(flat, self.fix_values, self.fix_indices)
        if users is None:
            return flat
        return jnp.take(flat, jnp.asarray(np.asarray(users), jnp.int32),
                        axis=0)

    def params(self, users: Optional[Sequence[int]] = None) -> Any:
        """Reconstructed personalized params as a stacked pytree."""
        return self.unravel_batch(self.params_flat(users))

    # ---- construction ------------------------------------------------------

    @classmethod
    def from_history(cls, history, *, codec="identity", assignment=None,
                     link=None, seed: int = 0,
                     backend: str = "pallas") -> "DeltaStore":
        """Ingest a `run_federated(keep_state=True)` History.  Base-model
        assignment resolution: explicit ``assignment`` > the strategy's
        extras (`MixingExtras.assignment` / `ClusterExtras.clusters`) >
        byte-level dedup of identical parameter rows (stream members end
        the run with identical params, so dedup recovers the plan)."""
        if history.final_params is None:
            raise ValueError(
                "history has no final_params — run "
                "run_federated(..., keep_state=True) to serve from it")
        if assignment is None:
            ex = history.extras
            assignment = getattr(ex, "assignment", None)
            if assignment is None:
                assignment = getattr(ex, "clusters", None)
        return cls.build(history.final_params, assignment=assignment,
                         codec=codec, link=link, seed=seed, backend=backend)

    @classmethod
    def build(cls, final_params, *, assignment=None, codec="identity",
              link=None, seed: int = 0,
              backend: str = "pallas") -> "DeltaStore":
        codec = get_codec(codec)
        flat = np.asarray(stacked_ravel(final_params), np.float32)
        m, d = flat.shape
        template = jax.tree_util.tree_map(
            lambda l: np.zeros(l.shape[1:], l.dtype), final_params)
        if link is not None:
            codec = codec.bind_link(link, template)

        if assignment is None:
            # identical rows share a stream: dedup recovers the plan even
            # when the strategy recorded none (fedavg => k=1, per-user
            # personalization => k=m)
            _, assignment = np.unique(flat, axis=0, return_inverse=True)
        asn = np.asarray(assignment, np.int64).ravel()
        if asn.shape != (m,):
            raise ValueError(f"assignment must be (m,)=({m},), got "
                             f"{asn.shape}")
        _, asn = np.unique(asn, return_inverse=True)   # labels -> 0..k-1
        k = int(asn.max()) + 1
        first = np.asarray([int(np.argmax(asn == j)) for j in range(k)])
        base = flat[first]                              # (k, D)

        # iterative refinement: drive fl(base + delta) as close to flat as
        # a single f32 add can get (the plain subtract is not enough)
        delta = (flat - base[asn]).astype(np.float32)
        for _ in range(_REFINE_ITERS):
            r = (base[asn] + delta).astype(np.float32)
            if np.array_equal(r, flat):
                break
            delta = (delta + (flat - r)).astype(np.float32)

        payload = codec.encode(jnp.asarray(delta),
                               jax.random.PRNGKey(seed), backend=backend)
        dec = codec.decode(payload, backend=backend, d=d)
        recon = np.asarray(jnp.asarray(base)[jnp.asarray(asn)] + dec,
                           np.float32)

        # identity only: the sparse second term of the error-free
        # reconstruction — elements whose magnitude mismatches the base so
        # badly that no single f32 delta lands on them exactly
        fix_values = np.zeros((m, 0), np.float32)
        fix_indices = np.zeros((m, 0), np.int32)
        if codec.is_identity and not np.array_equal(recon, flat):
            lo = np.zeros_like(flat)
            for _ in range(_REFINE_ITERS):
                v = (recon + lo).astype(np.float32)
                if np.array_equal(v, flat):
                    break
                lo = (lo + (flat - v)).astype(np.float32)
            else:
                raise RuntimeError(
                    "identity fixup refinement did not converge in "
                    f"{_REFINE_ITERS} iterations — lossless reconstruction "
                    "contract cannot hold")
            nnz = int(np.max(np.count_nonzero(lo, axis=1)))
            fix_values = np.zeros((m, nnz), np.float32)
            fix_indices = np.zeros((m, nnz), np.int32)
            for i in range(m):
                idx = np.nonzero(lo[i])[0]
                fix_values[i, :idx.size] = lo[i, idx]
                fix_indices[i, :idx.size] = idx
            recon = np.asarray(DeltaStore.apply_fix(
                jnp.asarray(recon), jnp.asarray(fix_values),
                jnp.asarray(fix_indices)), np.float32)

        recon_err = np.max(np.abs(recon.astype(np.float64)
                                  - flat.astype(np.float64)), axis=1)

        bound = codec.store_bound({n: np.asarray(v)
                                   for n, v in payload.items()}, d)
        if bound is not None:
            slack = _ULP_SLACK * np.spacing(
                np.max(np.abs(flat), axis=1).astype(np.float64))
            if np.any(recon_err > bound + slack):
                worst = int(np.argmax(recon_err - bound))
                raise RuntimeError(
                    f"store reconstruction violates the {codec.spec!r} "
                    f"error bound: user {worst} err={recon_err[worst]:.3e} "
                    f"> bound={float(bound[worst]):.3e}")

        return cls(base_flat=base, assignment=asn, codec=codec,
                   payload=payload, template=template, recon_err=recon_err,
                   delta_bits=codec.per_client_bits(template, m),
                   fix_values=fix_values, fix_indices=fix_indices,
                   seed=seed, backend=backend)

    # ---- persistence (repro.checkpoint msgpack) ----------------------------

    def save(self, path: str) -> None:
        checkpoint.save(path, {
            "version": 1,
            "codec": self.codec.spec,
            "backend": self.backend,
            "seed": self.seed,
            "assignment": self.assignment,
            "base_flat": np.asarray(self.base_flat),
            "payload": {k: np.asarray(v) for k, v in self.payload.items()},
            "template": self.template,
            "recon_err": self.recon_err,
            "delta_bits": self._delta_bits_raw,
            "fix_values": np.asarray(self.fix_values),
            "fix_indices": np.asarray(self.fix_indices),
        })

    @classmethod
    def load(cls, path: str) -> "DeltaStore":
        t = checkpoint.restore(path)
        if t.get("version") != 1:
            raise ValueError(f"unknown DeltaStore version {t.get('version')}"
                             f" in {path}")
        template = jax.tree_util.tree_map(np.asarray, t["template"])
        return cls(base_flat=t["base_flat"],
                   assignment=np.asarray(t["assignment"]),
                   codec=t["codec"], payload=t["payload"],
                   template=template,
                   recon_err=np.asarray(t["recon_err"]),
                   delta_bits=np.asarray(t["delta_bits"]),
                   fix_values=np.asarray(t["fix_values"]),
                   fix_indices=np.asarray(t["fix_indices"]),
                   seed=int(t["seed"]), backend=t["backend"])
