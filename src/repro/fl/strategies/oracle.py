"""Oracle baseline: FedAvg within the ground-truth clusters."""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import groupwise_weights
from repro.fl.strategies.base import CommCost, RoundContext, Strategy
from repro.fl.strategies.registry import register


class OracleState(NamedTuple):
    weights: jnp.ndarray    # (m, m) block-diagonal group-FedAvg rule
    n_streams: int          # one broadcast per true group


@register
class Oracle(Strategy):
    name = "oracle"
    reads_prev = False      # engine may donate the pre-round buffers
    traceable = True        # pure block-diagonal W-mix

    def setup(self, ctx: RoundContext) -> OracleState:
        group = np.asarray(ctx.fed.group)
        return OracleState(weights=groupwise_weights(ctx.fed.n, group),
                           n_streams=int(group.max()) + 1)

    def aggregate(self, state: OracleState, stacked, prev, ctx):
        return ctx.mix(stacked, state.weights), state

    def traced_state(self, state: OracleState):
        return state.weights

    def aggregate_traced(self, arrays, stacked, prev, tmix):
        return tmix.mix(stacked, arrays)

    def comm(self, state: OracleState) -> CommCost:
        return CommCost(state.n_streams, 0)

    @classmethod
    def downlink_cost(cls, m, *, n_streams=1, fomo_candidates=5):
        # one broadcast per cluster; the caller passes the cluster count
        return CommCost(n_streams, 0)
