"""Pluggable server-side aggregation strategies (DESIGN.md §4–§6).

Importing this package registers the seven paper algorithms:

    fedavg | local | oracle | ucfl | ucfl_k<k> | cfl | fedfomo

New personalization rules are a new `Strategy` subclass + `@register`
entry — the round engine (`repro.fl.simulator.run_federated`) never
dispatches on algorithm names.
"""
from repro.fl.strategies.base import (ClusterExtras, CommCost, MixingExtras,
                                      RoundContext, Strategy, StrategyExtras,
                                      TracedMix, quarantine_reweight,
                                      staleness_reweight)
from repro.fl.strategies.registry import (STRATEGIES, available_strategies,
                                          get_strategy, get_strategy_class,
                                          parse_spec, register)
from repro.fl.strategies.sampling import (ClientSampler, FullParticipation,
                                          UniformFraction)
# importing the modules registers the paper's algorithms
from repro.fl.strategies.cfl import CFL
from repro.fl.strategies.fedavg import FedAvg
from repro.fl.strategies.fedfomo import FedFOMO
from repro.fl.strategies.local import Local
from repro.fl.strategies.oracle import Oracle
from repro.fl.strategies.ucfl import UCFL

__all__ = [
    "CFL", "ClientSampler", "ClusterExtras", "CommCost", "FedAvg", "FedFOMO",
    "FullParticipation", "Local", "MixingExtras", "Oracle", "RoundContext",
    "STRATEGIES", "Strategy", "StrategyExtras", "TracedMix", "UCFL",
    "UniformFraction",
    "available_strategies", "get_strategy", "get_strategy_class",
    "parse_spec", "quarantine_reweight", "register", "staleness_reweight",
]
