"""FedFOMO (Zhang et al. 2020): client-side first-order model optimization.

Each client evaluates candidate models on its own validation set and mixes
the ones that reduce its loss; the server therefore unicasts candidate
models (no broadcast sharing is possible).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import user_centric_aggregate
from repro.core.similarity import flatten_pytree
from repro.data.federated import FederatedData
from repro.fl.strategies.base import CommCost, RoundContext, Strategy
from repro.fl.strategies.registry import register


class FomoState(NamedTuple):
    val_loss_fn: Callable       # jitted (params, x_val, y_val) -> (m,) losses
    m: int
    candidates: int


def _fedfomo_round(stacked, prev, fed: FederatedData, val_loss_fn,
                   n_candidates: int, mix=None):
    # deterministic: candidates are the top-M by weight (the paper samples)
    m = fed.m
    # loss of every candidate model on every client's validation set
    losses = np.zeros((m, m), np.float32)
    flat = jax.vmap(flatten_pytree)(stacked)
    flat_prev = jax.vmap(flatten_pytree)(prev)
    for j in range(m):
        pj = jax.tree_util.tree_map(lambda l: l[j], stacked)
        losses[:, j] = np.asarray(val_loss_fn(pj, fed.x_val, fed.y_val))
    prev_losses = np.zeros((m,), np.float32)
    for i in range(m):
        pi = jax.tree_util.tree_map(lambda l: l[i], prev)
        prev_losses[i] = float(val_loss_fn(pi, fed.x_val[i:i + 1],
                                           fed.y_val[i:i + 1])[0])
    dist = np.asarray(jnp.linalg.norm(
        flat[None, :, :] - flat_prev[:, None, :], axis=-1)) + 1e-9
    wmat = np.maximum((prev_losses[:, None] - losses) / dist, 0.0)
    # keep top candidates per client (paper samples M models)
    if n_candidates < m:
        thresh = np.sort(wmat, axis=1)[:, -n_candidates][:, None]
        wmat = np.where(wmat >= thresh, wmat, 0.0)
    rows = wmat.sum(1, keepdims=True)
    wmat = np.where(rows > 0, wmat / np.maximum(rows, 1e-9), 0.0)
    wj = jnp.asarray(wmat)
    # θ_i ← θ_i^prev + Σ_j w_ij (θ_j − θ_i^prev)
    mixed = user_centric_aggregate(stacked, wj) if mix is None \
        else mix(stacked, wj)
    keep = jnp.asarray(1.0 - wmat.sum(1))
    return jax.tree_util.tree_map(
        lambda mx, pv: mx + keep.reshape((-1,) + (1,) * (pv.ndim - 1)) * pv,
        mixed, prev)


@register
class FedFOMO(Strategy):
    name = "fedfomo"
    reads_prev = True       # candidate weighting compares against prev

    def __init__(self, candidates: Optional[int] = None):
        self.candidates = candidates   # None -> FLConfig.fomo_candidates

    def setup(self, ctx: RoundContext) -> FomoState:
        loss_fn = ctx.loss_fn
        val_loss = jax.jit(jax.vmap(
            lambda p, x, y: loss_fn(p, {"x": x, "y": y})[0],
            in_axes=(None, 0, 0)))
        n_cand = (self.candidates if self.candidates is not None
                  else ctx.fl.fomo_candidates)
        return FomoState(val_loss_fn=val_loss, m=ctx.fed.m, candidates=n_cand)

    def aggregate(self, state: FomoState, stacked, prev, ctx):
        out = _fedfomo_round(stacked, prev, ctx.fed, state.val_loss_fn,
                             state.candidates, mix=ctx.mix)
        return out, state

    def comm(self, state: FomoState) -> CommCost:
        return CommCost(0, state.m * state.candidates)

    @classmethod
    def downlink_cost(cls, m, *, n_streams=1, fomo_candidates=5):
        return CommCost(0, m * fomo_candidates)
