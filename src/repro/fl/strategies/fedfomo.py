"""FedFOMO (Zhang et al. 2020): client-side first-order model optimization.

Each client evaluates candidate models on its own validation set and mixes
the ones that reduce its loss; the server therefore unicasts candidate
models (no broadcast sharing is possible).

The candidate-loss matrix is ONE batched (m, m) evaluation — a vmap over
candidate models of the vmap over client validation sets — instead of m
per-candidate device->host round trips, which is what makes FedFOMO viable
at mesh scale: on `MeshShardMap` the client-stacked candidates stay
sharded through the outer vmap rather than being pulled to host one model
at a time.  Orientation convention (pinned by a regression test):
``losses[i, j]`` is candidate j's loss on client i's OWN validation set,
and ``prev_losses[i]`` is client i's pre-round model on its own set.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import user_centric_aggregate
from repro.core.similarity import flatten_pytree
from repro.data.federated import FederatedData
from repro.fl.strategies.base import CommCost, RoundContext, Strategy
from repro.fl.strategies.registry import register


class FomoState(NamedTuple):
    cand_loss_fn: Callable      # jitted (stacked, x_val, y_val) -> (m, m):
                                # row j = candidate j on every client's val set
    self_loss_fn: Callable      # jitted diagonal: model i on client i -> (m,)
    m: int
    candidates: int


def _fedfomo_round(stacked, prev, fed: FederatedData, cand_loss_fn,
                   self_loss_fn, n_candidates: int, mix=None):
    # deterministic: candidates are the top-M by weight (the paper samples)
    m = fed.m
    flat = jax.vmap(flatten_pytree)(stacked)
    flat_prev = jax.vmap(flatten_pytree)(prev)
    # loss of every candidate model on every client's validation set, as a
    # single batched eval; the jitted result comes back (candidate j,
    # client i) and is transposed to the (i, j) convention
    losses = np.asarray(cand_loss_fn(stacked, fed.x_val, fed.y_val)).T
    # client i's own pre-round model on its own validation set
    prev_losses = np.asarray(self_loss_fn(prev, fed.x_val, fed.y_val))
    dist = np.asarray(jnp.linalg.norm(
        flat[None, :, :] - flat_prev[:, None, :], axis=-1)) + 1e-9
    wmat = np.maximum((prev_losses[:, None] - losses) / dist, 0.0)
    # keep top candidates per client (paper samples M models)
    if n_candidates < m:
        thresh = np.sort(wmat, axis=1)[:, -n_candidates][:, None]
        wmat = np.where(wmat >= thresh, wmat, 0.0)
    rows = wmat.sum(1, keepdims=True)
    wmat = np.where(rows > 0, wmat / np.maximum(rows, 1e-9), 0.0)
    wj = jnp.asarray(wmat)
    # θ_i ← θ_i^prev + Σ_j w_ij (θ_j − θ_i^prev)
    mixed = user_centric_aggregate(stacked, wj) if mix is None \
        else mix(stacked, wj)
    keep = jnp.asarray(1.0 - wmat.sum(1))
    return jax.tree_util.tree_map(
        lambda mx, pv: mx + keep.reshape((-1,) + (1,) * (pv.ndim - 1)) * pv,
        mixed, prev)


@register
class FedFOMO(Strategy):
    name = "fedfomo"
    reads_prev = True       # candidate weighting compares against prev
    traceable = False       # numpy thresholding/weighting per round: the
                            # engine falls back to the eventful loop

    def __init__(self, candidates: Optional[int] = None):
        self.candidates = candidates   # None -> FLConfig.fomo_candidates

    def setup(self, ctx: RoundContext) -> FomoState:
        loss_fn = ctx.loss_fn
        # one model on every client's validation set -> (m,)
        per_client = jax.vmap(
            lambda p, x, y: loss_fn(p, {"x": x, "y": y})[0],
            in_axes=(None, 0, 0))
        # ... and over the candidate stack -> (m candidates, m clients)
        cand_loss = jax.jit(jax.vmap(per_client, in_axes=(0, None, None)))
        # the diagonal: model i on client i's own validation set -> (m,)
        self_loss = jax.jit(jax.vmap(
            lambda p, x, y: loss_fn(p, {"x": x, "y": y})[0]))
        n_cand = (self.candidates if self.candidates is not None
                  else ctx.fl.fomo_candidates)
        return FomoState(cand_loss_fn=cand_loss, self_loss_fn=self_loss,
                         m=ctx.fed.m, candidates=n_cand)

    def aggregate(self, state: FomoState, stacked, prev, ctx):
        out = _fedfomo_round(stacked, prev, ctx.fed, state.cand_loss_fn,
                             state.self_loss_fn, state.candidates,
                             mix=ctx.mix)
        return out, state

    def comm(self, state: FomoState) -> CommCost:
        return CommCost(0, state.m * state.candidates)

    @classmethod
    def downlink_cost(cls, m, *, n_streams=1, fomo_candidates=5):
        return CommCost(0, m * fomo_candidates)
