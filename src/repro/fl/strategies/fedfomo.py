"""FedFOMO (Zhang et al. 2020): client-side first-order model optimization.

Each client evaluates candidate models on its own validation set and mixes
the ones that reduce its loss; the server therefore unicasts candidate
models (no broadcast sharing is possible).

The candidate-loss matrix is ONE batched (m, m) evaluation — a vmap over
candidate models of the vmap over client validation sets — instead of m
per-candidate device->host round trips, which is what makes FedFOMO viable
at mesh scale: on `MeshShardMap` the client-stacked candidates stay
sharded through the outer vmap rather than being pulled to host one model
at a time.  Orientation convention (pinned by a regression test):
``losses[i, j]`` is candidate j's loss on client i's OWN validation set,
and ``prev_losses[i]`` is client i's pre-round model on its own set.

The whole weighting — loss matrix, distance normalization, top-M
thresholding, row renormalization — is pure jnp (`fomo_weights`), so
FedFOMO satisfies the superstep traceability contract (DESIGN.md §3c):
the eventful path and the fused scan run the SAME math, the eventful
path merely calling it through a cached jit wrapper.  The top-M cut is
traced with the candidate count as a DYNAMIC scalar (`dynamic_slice`
into the row-sorted weights), so runs differing only in
``fomo_candidates`` share one compiled superstep.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.similarity import flatten_pytree
from repro.fl.strategies.base import CommCost, RoundContext, Strategy
from repro.fl.strategies.registry import register


class FomoState(NamedTuple):
    cand_loss_fn: Callable      # jitted (stacked, x_val, y_val) -> (m, m):
                                # row j = candidate j on every client's val set
    self_loss_fn: Callable      # jitted diagonal: model i on client i -> (m,)
    weights_fn: Callable        # jitted `fomo_weights` bound to loss_fn
    x_val: jnp.ndarray          # the per-client validation sets the
    y_val: jnp.ndarray          # weighting evaluates candidates on
    n_cand: jnp.ndarray         # top-M cut, as a TRACED scalar (int32)
    m: int
    candidates: int


def fomo_weights(loss_fn: Callable, stacked, prev, x_val, y_val, n_cand):
    """The FedFOMO weighting as one pure-jnp function: returns the
    row-normalized (m, m) mixing matrix plus the (m,) residual mass each
    client keeps on its own pre-round model.

    ``n_cand`` is a traced int32 scalar — ``n_cand >= m`` disables the
    top-M cut (every positive-weight candidate is kept), matching the
    paper's "evaluate all received models" limit."""
    per_client = jax.vmap(
        lambda p, x, y: loss_fn(p, {"x": x, "y": y})[0],
        in_axes=(None, 0, 0))
    # loss of every candidate model on every client's validation set, as a
    # single batched eval; computed (candidate j, client i) and transposed
    # to the (i, j) convention
    losses = jax.vmap(per_client, in_axes=(0, None, None))(
        stacked, x_val, y_val).T
    # client i's own pre-round model on its own validation set
    prev_losses = jax.vmap(
        lambda p, x, y: loss_fn(p, {"x": x, "y": y})[0])(prev, x_val, y_val)
    flat = jax.vmap(flatten_pytree)(stacked)
    flat_prev = jax.vmap(flatten_pytree)(prev)
    dist = jnp.linalg.norm(flat[None, :, :] - flat_prev[:, None, :],
                           axis=-1) + 1e-9
    wmat = jnp.maximum((prev_losses[:, None] - losses) / dist, 0.0)
    # keep top candidates per client (paper samples M models): threshold
    # at the n_cand-th largest weight per row, sliced dynamically so the
    # candidate count never specializes the trace
    m = wmat.shape[0]
    srt = jnp.sort(wmat, axis=1)
    pos = jnp.clip(m - n_cand, 0, m - 1).astype(jnp.int32)
    thresh = jax.lax.dynamic_slice(srt, (jnp.int32(0), pos), (m, 1))
    wmat = jnp.where((n_cand >= m) | (wmat >= thresh), wmat, 0.0)
    rows = wmat.sum(1, keepdims=True)
    wmat = jnp.where(rows > 0, wmat / jnp.maximum(rows, 1e-9), 0.0)
    return wmat, 1.0 - wmat.sum(1)


@functools.lru_cache(maxsize=8)
def _weights_fn(loss_fn: Callable) -> Callable:
    """jit wrapper for the eventful path, cached on the loss identity so
    repeated runs reuse the executable (like `cached_update`)."""
    return jax.jit(functools.partial(fomo_weights, loss_fn))


def _add_residual(mixed, prev, keep):
    # θ_i ← Σ_j w_ij θ_j + (1 − Σ_j w_ij) θ_i^prev
    return jax.tree_util.tree_map(
        lambda mx, pv: mx + keep.reshape((-1,) + (1,) * (pv.ndim - 1)) * pv,
        mixed, prev)


@register
class FedFOMO(Strategy):
    name = "fedfomo"
    reads_prev = True       # candidate weighting compares against prev
    traceable = True        # pure-jnp weighting: qualifies for the fused
                            # superstep (deterministic top-M variant)

    def __init__(self, candidates: Optional[int] = None):
        self.candidates = candidates   # None -> FLConfig.fomo_candidates
        self._loss_fn = None           # bound at setup, for the traced path

    def setup(self, ctx: RoundContext) -> FomoState:
        loss_fn = ctx.loss_fn
        # one model on every client's validation set -> (m,)
        per_client = jax.vmap(
            lambda p, x, y: loss_fn(p, {"x": x, "y": y})[0],
            in_axes=(None, 0, 0))
        # ... and over the candidate stack -> (m candidates, m clients)
        cand_loss = jax.jit(jax.vmap(per_client, in_axes=(0, None, None)))
        # the diagonal: model i on client i's own validation set -> (m,)
        self_loss = jax.jit(jax.vmap(
            lambda p, x, y: loss_fn(p, {"x": x, "y": y})[0]))
        n_cand = (self.candidates if self.candidates is not None
                  else ctx.fl.fomo_candidates)
        # the traced aggregation closes over the loss function; the
        # superstep cache key carries the same identity via the cached
        # update step, so stashing it on the instance cannot alias two
        # different compiled programs
        self._loss_fn = loss_fn
        return FomoState(cand_loss_fn=cand_loss, self_loss_fn=self_loss,
                         weights_fn=_weights_fn(loss_fn),
                         x_val=ctx.fed.x_val, y_val=ctx.fed.y_val,
                         n_cand=jnp.asarray(n_cand, jnp.int32),
                         m=ctx.fed.m, candidates=n_cand)

    def aggregate(self, state: FomoState, stacked, prev, ctx):
        wmat, keep = state.weights_fn(stacked, prev, state.x_val,
                                      state.y_val, state.n_cand)
        # ctx.mix routes through `reweight` (async staleness discounting is
        # mass-preserving per row, so `keep` stays the rows' complement)
        return _add_residual(ctx.mix(stacked, wmat), prev, keep), state

    def traced_state(self, state: FomoState):
        # structure is spec-constant: the validation sets the weighting
        # evaluates on, plus the dynamic top-M scalar
        return (state.x_val, state.y_val, state.n_cand)

    def aggregate_traced(self, arrays, stacked, prev, tmix):
        x_val, y_val, n_cand = arrays
        wmat, keep = fomo_weights(self._loss_fn, stacked, prev, x_val,
                                  y_val, n_cand)
        return _add_residual(tmix.mix(stacked, wmat), prev, keep)

    def comm(self, state: FomoState) -> CommCost:
        return CommCost(0, state.m * state.candidates)

    @classmethod
    def downlink_cost(cls, m, *, n_streams=1, fomo_candidates=5):
        return CommCost(0, m * fomo_candidates)
