"""Strategy protocol: the server-side aggregation surface (DESIGN.md §4).

A `Strategy` packages one personalization rule as three lifecycle hooks
driven by the round engine (`repro.fl.simulator.run_federated`):

    state = strategy.setup(ctx)                       # once, before round 0
    stacked, state = strategy.aggregate(state, stacked, prev, ctx)  # per round
    cost = strategy.comm(state)                       # per round, after agg

`state` is opaque to the engine — each strategy defines its own (mixing
matrices, stream plans, cluster assignments, jitted closures).  The engine
owns client updates, sampling, evaluation and the clock; strategies own
everything between "clients uploaded" and "server downlinks".

Strategies report per-round results through `CommCost` (the downlink
accounting of paper §IV-C) and through typed `StrategyExtras` subclasses
(via `extras(state)`) instead of stuffing ad-hoc keys into a dict; the
legacy `History.extra` mapping is derived from both.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Any, Callable, ClassVar, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedData


class CommCost(NamedTuple):
    """Per-round downlink accounting: broadcast streams + unicasts.

    Time unit is T_dl (see `repro.fl.comm.SystemModel`); unpacks as the
    legacy ``(n_streams, n_unicasts)`` tuple.
    """
    n_streams: int
    n_unicasts: int


def staleness_factors(staleness: jnp.ndarray, *, schedule: str = "exp",
                      discount: float = 1.0,
                      alpha: float = 0.5) -> jnp.ndarray:
    """Per-contributor staleness weights s(age) ∈ (0, 1].

    ``exp``  — FedBuff-style geometric decay ``discount ** age``;
    ``poly`` — FedAsync's polynomial schedule ``(1 + age) ** −alpha``
    (Xie et al. 2019), heavier-tailed: old-but-arriving updates keep more
    mass than under any geometric λ.  Both are exactly 1 at age 0.
    """
    age = jnp.asarray(staleness, jnp.float32)
    if schedule == "exp":
        return jnp.asarray(discount, jnp.float32) ** age
    if schedule == "poly":
        return (1.0 + age) ** jnp.asarray(-alpha, jnp.float32)
    raise ValueError(f"unknown staleness schedule {schedule!r}; "
                     "one of exp | poly")


def staleness_reweight(w: jnp.ndarray, staleness: jnp.ndarray,
                       discount: float, *, schedule: str = "exp",
                       alpha: float = 0.5) -> jnp.ndarray:
    """Discount stale contributor columns of an aggregation-rule matrix.

    ``w`` is any (r, m) weight matrix whose COLUMNS index contributing
    client models; ``staleness[j]`` is the age of model j in server
    versions (async runtime, DESIGN.md §3a).  Each column is scaled by
    `staleness_factors` (default: ``discount ** staleness[j]``) and each
    row rescaled back to its ORIGINAL total mass — row-stochastic rules
    stay row-stochastic, and FedFOMO's sub-stochastic rows keep their
    self-residual.  All-zero staleness (or ``discount == 1`` under the
    exp schedule) is an exact identity.
    """
    d = staleness_factors(staleness, schedule=schedule, discount=discount,
                          alpha=alpha)
    wd = w * d[None, :].astype(w.dtype)
    mass = jnp.sum(w, axis=1, keepdims=True)
    new_mass = jnp.sum(wd, axis=1, keepdims=True)
    return (wd * (mass / jnp.maximum(new_mass, 1e-12))).astype(w.dtype)


def quarantine_reweight(w: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Zero quarantined contributor columns of an aggregation-rule matrix
    and renormalize each row back to its ORIGINAL mass (DESIGN.md §3g).

    ``w`` is any (r, m) weight matrix whose COLUMNS index contributing
    client models; ``q[j]`` is the defense layer's survival weight of
    model j (1 kept, 0 quarantined).  The surviving columns absorb the
    quarantined mass — row-stochastic rules stay row-stochastic, UCFL's
    personalized rows keep their per-row totals.  A row whose surviving
    mass is zero falls back to its undefended weights: the screen already
    zeroed the quarantined DELTAS, so the fallback mixes the previous
    (finite) models instead of producing an all-zero parameter row.
    All-ones ``q`` is an exact identity."""
    qf = q[None, :].astype(w.dtype)
    wq = w * qf
    mass = jnp.sum(w, axis=1, keepdims=True)
    new_mass = jnp.sum(wq, axis=1, keepdims=True)
    scaled = wq * (mass / jnp.maximum(new_mass, 1e-12))
    return jnp.where(new_mass > 0, scaled, w).astype(w.dtype)


@dataclass
class RoundContext:
    """Everything a strategy may read about the run; mutated per round by
    the engine (``rnd``, ``key``, ``participation``; async runs also set
    ``staleness``)."""
    fed: FederatedData
    fl: Any                         # FLConfig (kept untyped to avoid a cycle)
    loss_fn: Callable
    acc_fn: Callable
    params0: Any                    # common initialization (pre-round stats)
    seed: int
    rnd: int = 0                    # current round index
    key: Optional[jnp.ndarray] = None       # this round's PRNG key
    participation: Optional[jnp.ndarray] = None  # (m,) bool mask or None=all
    placement: Optional[Any] = None  # Placement backend (DESIGN.md §3)
    # async runtime (DESIGN.md §3a): per-client model age in server versions
    # (None for sync rounds and for async events where every model is fresh)
    staleness: Optional[jnp.ndarray] = None
    staleness_discount: float = 1.0
    staleness_schedule: str = "exp"     # exp | poly (DESIGN.md §3a)
    staleness_alpha: float = 0.5        # poly schedule exponent
    strategy: Optional[Any] = None  # the running Strategy, for `reweight`
    # defense layer (DESIGN.md §3g): per-contributor survival weights set
    # by the engine after screening/robust aggregation (None = no defense)
    quarantine: Optional[jnp.ndarray] = None

    @property
    def m(self) -> int:
        return self.fed.m

    # Strategies apply their aggregation rules through these two hooks so
    # the SAME strategy code runs under every placement backend: HostVmap
    # dispatches to the plain stacked-pytree math, MeshShardMap to the
    # schedule-selected mixing collectives.  Under the async runtime the
    # hooks also route the weights through `Strategy.reweight`, so every
    # registered strategy picks up staleness discounting unmodified.

    def reweighted(self, w: jnp.ndarray) -> jnp.ndarray:
        """Staleness-discounted + quarantine-renormalized view of ``w``:
        the strategy's `reweight` hook first (identity for sync rounds,
        where ``staleness`` is None), then the defense layer's quarantine
        columns (DESIGN.md §3g) — engine-mandated, after any
        strategy-specific reweighting."""
        if self.strategy is not None:
            w = self.strategy.reweight(w, self)
        elif self.staleness is not None:  # engine-less driving, no strategy
            w = staleness_reweight(w, self.staleness,
                                   self.staleness_discount,
                                   schedule=self.staleness_schedule,
                                   alpha=self.staleness_alpha)
        if self.quarantine is not None:
            w = quarantine_reweight(w, self.quarantine)
        return w

    def mix(self, stacked: Any, w: jnp.ndarray) -> Any:
        """θ_i ← Σ_j w[i,j] θ_j for a full per-client matrix (m, m)."""
        w = self.reweighted(w)
        if self.placement is None:
            from repro.core import user_centric_aggregate
            return user_centric_aggregate(stacked, w)
        return self.placement.mix(stacked, w)

    def mix_plan(self, stacked: Any, plan: Any) -> Any:
        """k-stream aggregation: centroid mix + group broadcast."""
        if self.staleness is not None or self.quarantine is not None:
            plan = plan._replace(centroids=self.reweighted(plan.centroids))
        if self.placement is None:
            from repro.core import stream_aggregate
            return stream_aggregate(stacked, plan)
        return self.placement.mix_plan(stacked, plan)


class TracedMix:
    """Aggregation dispatcher handed to `Strategy.aggregate_traced` inside
    the superstep scan (DESIGN.md §3c).

    Same math as `RoundContext.mix` / `mix_plan` for a synchronous round
    (staleness reweighting is async-only and the superstep is sync-only),
    but routed through the placement's trace-safe hooks so no per-call jit
    dispatch happens inside the fused round.

    ``quarantine`` is the defense layer's per-contributor survival row
    (DESIGN.md §3g), set by the fused round right before dispatching to
    `Strategy.aggregate_traced` and cleared right after — every traced
    mixing rule picks up `quarantine_reweight` without strategy changes,
    exactly like `RoundContext.mix` on the eventful path."""

    def __init__(self, placement: Any):
        self.placement = placement
        self.quarantine: Optional[jnp.ndarray] = None

    def _reweighted(self, w: jnp.ndarray) -> jnp.ndarray:
        if self.quarantine is None:
            return w
        return quarantine_reweight(w, self.quarantine)

    def mix(self, stacked: Any, w: jnp.ndarray) -> Any:
        """θ_i ← Σ_j w[i,j] θ_j for a full per-client matrix (m, m)."""
        return self.placement.mix_traced(stacked, self._reweighted(w))

    def mix_plan(self, stacked: Any, centroids: jnp.ndarray,
                 assignment: jnp.ndarray) -> Any:
        """k-stream aggregation: centroid mix + group broadcast."""
        return self.placement.mix_plan_traced(
            stacked, self._reweighted(centroids), assignment)


@dataclass
class StrategyExtras:
    """Base for typed per-strategy results attached to `History.extras`."""


@dataclass
class MixingExtras(StrategyExtras):
    """UCFL family: the Eq. 6 collaboration matrix used all run, plus the
    client→stream assignment when the run used the k-stream reduction
    (None for full per-client unicast) — the serving plane's
    `DeltaStore.from_history` reads it to pick base models."""
    mixing_matrix: np.ndarray
    assignment: Optional[np.ndarray] = None


@dataclass
class ClusterExtras(StrategyExtras):
    """CFL: final client -> cluster assignment."""
    clusters: np.ndarray


class Strategy(abc.ABC):
    """One server-side aggregation rule; subclass + `@register` to add."""

    name: ClassVar[str]

    # Whether `aggregate` reads its `prev` argument.  When False and no
    # sampler is set, the engine donates the stacked params/opt-state
    # buffers to the local-update step (halving peak memory) and passes
    # ``prev=None`` — declare False only if `aggregate` never touches it.
    reads_prev: ClassVar[bool] = True

    # Whether this strategy's aggregation is a PURE jnp function of
    # per-round arrays (the superstep traceability contract, DESIGN.md
    # §3c): True means `traced_state`/`aggregate_traced` are implemented,
    # the per-round state never changes (so `comm(state)` is round-
    # constant), and the engine may fuse `eval_every` rounds into one
    # `lax.scan`.  Strategies with eventful host-side state transitions
    # (CFL's cluster splits, FedFOMO's numpy weighting) stay False and
    # the engine transparently falls back to the per-round loop.
    traceable: ClassVar[bool] = False

    @property
    def spec(self) -> str:
        """Registry spec string that reconstructs this instance."""
        return self.name

    def setup(self, ctx: RoundContext) -> Any:
        """Pre-round work (similarity stats, mixing matrices); returns the
        strategy state threaded through `aggregate`/`comm`/`extras`."""
        return None

    @abc.abstractmethod
    def aggregate(self, state: Any, stacked: Any, prev: Any,
                  ctx: RoundContext) -> Tuple[Any, Any]:
        """Server aggregation: (stacked', state').  `stacked` holds the
        post-local-update client models, `prev` the pre-update ones."""

    @abc.abstractmethod
    def comm(self, state: Any) -> CommCost:
        """This round's downlink cost (read after `aggregate`)."""

    def extras(self, state: Any) -> Optional[StrategyExtras]:
        """Typed end-of-run results for `History.extras`."""
        return None

    def membership(self, state: Any) -> Optional[np.ndarray]:
        """(m,) int client→stream map backing ``comm(state).n_streams``
        broadcasts, or None when the strategy doesn't know one (fedavg,
        local, fomo).  Two consumers: membership-aware downlink charging
        (`round_downlink_time`, DESIGN.md §3b) and the serving plane's
        base-model selection (`DeltaStore.from_history`, §3d)."""
        return None

    def traced_state(self, state: Any) -> Any:
        """The pytree of device arrays `aggregate_traced` consumes,
        extracted once from the `setup` state before the superstep scan
        is traced (DESIGN.md §3c).  Must be implemented when
        ``traceable=True``; its STRUCTURE must be a pure function of
        ``(type(self), self.spec)`` — the compiled superstep is cached
        across runs on that identity."""
        raise NotImplementedError(
            f"{type(self).__name__} sets traceable=True but does not "
            "implement traced_state")

    def aggregate_traced(self, arrays: Any, stacked: Any, prev: Any,
                         tmix: TracedMix) -> Any:
        """Pure-jnp server aggregation for the superstep scan: the traced
        sibling of `aggregate`.  ``arrays`` is `traced_state(state)`;
        mixing goes through ``tmix.mix`` / ``tmix.mix_plan`` (the
        trace-safe placement dispatch).  Returns only ``stacked'`` — a
        traceable strategy's state is round-constant by contract."""
        raise NotImplementedError(
            f"{type(self).__name__} sets traceable=True but does not "
            "implement aggregate_traced")

    def edge_weights(self, w: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
        """Edge-aggregation hook (hierarchy tier, DESIGN.md §3f): refine
        the `EdgeAggregator`'s normalized per-device weight matrix ``w``
        (m, d_max) given the per-device sample counts ``n`` (m, d_max).
        TRACED inside the fleet update — overrides must be pure jnp.
        Default: identity (no registered strategy reweights its users'
        fleets; the engine only threads a strategy's hook into the fleet
        update when the method is actually overridden, so the default
        costs nothing and preserves the flat-parity anchor)."""
        return w

    def reweight(self, w: jnp.ndarray, ctx: RoundContext) -> jnp.ndarray:
        """Staleness hook (DESIGN.md §3a): `ctx.mix` routes every weight
        matrix through here (`ctx.mix_plan` its centroids, when the run
        carries staleness).  Default: identity for sync rounds
        (``ctx.staleness`` is None); under the async runtime, stale
        contributor columns are discounted per ``ctx.staleness_schedule``
        (``discount ** age`` or FedAsync's ``(1+age)**-alpha``),
        mass-preserving per row.  Override for strategy-specific staleness
        handling."""
        if ctx.staleness is None:
            return w
        return staleness_reweight(w, ctx.staleness, ctx.staleness_discount,
                                  schedule=ctx.staleness_schedule,
                                  alpha=ctx.staleness_alpha)

    @classmethod
    def downlink_cost(cls, m: int, *, n_streams: int = 1,
                      fomo_candidates: int = 5) -> CommCost:
        """Family cost table entry (the legacy `downlink_cost` contract:
        the caller supplies `n_streams` for cluster/stream families)."""
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec!r})"
