"""Clustered FL (Sattler et al. 2020): per-cluster FedAvg plus a
hierarchical bipartition on the cosine similarity of client updates."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import groupwise_weights
from repro.core.similarity import flatten_pytree
from repro.fl.strategies.base import (ClusterExtras, CommCost, RoundContext,
                                      Strategy)
from repro.fl.strategies.registry import register


def _cosine_bipartition(d: np.ndarray) -> np.ndarray:
    norm = d / (np.linalg.norm(d, axis=1, keepdims=True) + 1e-9)
    sim = norm @ norm.T
    i, j = np.unravel_index(np.argmin(sim), sim.shape)
    return (sim[:, j] > sim[:, i]).astype(int)


@register
class CFL(Strategy):
    """State = the host-side (m,) cluster assignment, refined over rounds."""

    name = "cfl"
    reads_prev = True       # deltas = stacked − prev drive the bipartition

    def setup(self, ctx: RoundContext) -> np.ndarray:
        return np.zeros(ctx.fed.m, dtype=int)

    def aggregate(self, clusters: np.ndarray, stacked, prev,
                  ctx: RoundContext):
        fl = ctx.fl
        deltas = jax.vmap(lambda a, b: flatten_pytree(
            jax.tree_util.tree_map(lambda x, y: x - y, a, b)))(stacked, prev)
        deltas = np.asarray(deltas)
        norms = np.linalg.norm(deltas, axis=1)
        # non-participants were rolled back to their pre-round params, so
        # their deltas are exactly zero — they must not vote on splits
        active = (np.ones(len(clusters), bool) if ctx.participation is None
                  else np.asarray(ctx.participation))
        new_clusters = clusters.copy()
        if ctx.rnd >= fl.cfl_min_rounds:
            for c in np.unique(clusters):
                idx = np.where((clusters == c) & active)[0]
                if len(idx) < 4:
                    continue
                mean_delta = deltas[idx].mean(0)
                if (np.linalg.norm(mean_delta)
                        < fl.cfl_eps1 * norms[idx].mean()
                        and norms[idx].max() > fl.cfl_eps2 * norms[idx].mean()):
                    sub = _cosine_bipartition(deltas[idx])
                    nxt = new_clusters.max() + 1
                    new_clusters[idx[sub == 1]] = nxt
        stacked = ctx.mix(stacked,
                          groupwise_weights(ctx.fed.n, new_clusters))
        return stacked, new_clusters

    def comm(self, clusters: np.ndarray) -> CommCost:
        return CommCost(int(clusters.max()) + 1, 0)

    def membership(self, clusters: np.ndarray) -> np.ndarray:
        return np.asarray(clusters, np.int64)

    def extras(self, clusters: np.ndarray) -> ClusterExtras:
        return ClusterExtras(clusters=clusters.copy())

    @classmethod
    def downlink_cost(cls, m, *, n_streams=1, fomo_candidates=5):
        # one broadcast per current cluster; the caller passes the count
        return CommCost(n_streams, 0)
