"""Client participation hooks for the round engine (DESIGN.md §6).

A `ClientSampler` decides which clients take part in a round.  The engine
still runs the vmapped local update for every slot (the stacked layout is
static), then discards the work of non-participants: their params and
optimizer state are rolled back to the pre-round values, so they hold a
stale model that the server-side aggregation still sees (stale-model
participation semantics).  The participation mask is exposed to strategies
via `RoundContext.participation` for rules that want to reweight.
"""
from __future__ import annotations

from typing import ClassVar, Optional, Tuple

import jax
import jax.numpy as jnp


class ClientSampler:
    """Returns a (m,) bool participation mask per round; None = everyone."""

    needs_key: ClassVar[bool] = False   # engine only spends PRNG keys on
                                        # stochastic samplers, preserving the
                                        # full-participation RNG stream

    # Whether `sample_traced` is implemented (superstep traceability
    # contract, DESIGN.md §3c): mask generation must be a pure jnp
    # function of the round key so it stays inside the fused scan.
    traceable: ClassVar[bool] = False

    def sample(self, rnd: int, m: int,
               key: Optional[jnp.ndarray]) -> Optional[jnp.ndarray]:
        raise NotImplementedError

    def sample_traced(self, key: Optional[jnp.ndarray],
                      m: int) -> jnp.ndarray:
        """Traced sibling of `sample`: ALWAYS returns a (m,) bool mask
        (all-True where `sample` would return None — the engine-side
        select with an all-True mask is a bitwise identity), from the
        same key the eventful engine would spend."""
        raise NotImplementedError(
            f"{type(self).__name__} sets traceable=True but does not "
            "implement sample_traced")

    @property
    def cache_key(self) -> Tuple:
        """Hashable identity for the compiled-superstep cache: two
        samplers with equal keys must produce identical traces."""
        return (type(self).__name__,)


class FullParticipation(ClientSampler):
    """Every client, every round — identical to passing no sampler."""

    traceable = True

    def sample(self, rnd, m, key):
        return None

    def sample_traced(self, key, m):
        return jnp.ones((m,), dtype=bool)


class UniformFraction(ClientSampler):
    """Uniformly sample a per-round cohort without replacement: either
    ``round(fraction * m)`` clients (at least ``min_clients``) or an exact
    ``count`` — the latter lets async arrival tests pin cohort sizes."""

    needs_key = True
    traceable = True

    def __init__(self, fraction: Optional[float] = None,
                 min_clients: int = 1, *, count: Optional[int] = None):
        if (fraction is None) == (count is None):
            raise ValueError("pass exactly one of `fraction` or `count`")
        if fraction is not None and not 0.0 < fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {fraction}")
        if count is not None and count < 1:
            raise ValueError(f"count must be >= 1, got {count}")
        self.fraction = None if fraction is None else float(fraction)
        self.count = None if count is None else int(count)
        self.min_clients = int(min_clients)

    def cohort(self, m: int) -> int:
        """This sampler's per-round cohort size — static given m, which is
        what lets the mask generation trace (the full-cohort k >= m
        short-circuit is decided before any key is spent)."""
        if self.count is not None:
            return min(m, max(self.min_clients, self.count))
        return min(m, max(self.min_clients, int(round(self.fraction * m))))

    def sample(self, rnd, m, key):
        k = self.cohort(m)
        if k >= m:
            return None
        idx = jax.random.permutation(key, m)[:k]
        return jnp.zeros((m,), dtype=bool).at[idx].set(True)

    def sample_traced(self, key, m):
        # delegate so the eventful and fused masks CANNOT drift: `sample`
        # ignores rnd, and at full cohorts (k >= m) returns None before
        # touching the key — exactly the all-True case
        mask = self.sample(0, m, key)
        return jnp.ones((m,), dtype=bool) if mask is None else mask

    @property
    def cache_key(self):
        return (type(self).__name__, self.fraction, self.count,
                self.min_clients)
