"""Client participation hooks for the round engine (DESIGN.md §6).

A `ClientSampler` decides which clients take part in a round.  The engine
still runs the vmapped local update for every slot (the stacked layout is
static), then discards the work of non-participants: their params and
optimizer state are rolled back to the pre-round values, so they hold a
stale model that the server-side aggregation still sees (stale-model
participation semantics).  The participation mask is exposed to strategies
via `RoundContext.participation` for rules that want to reweight.
"""
from __future__ import annotations

from typing import ClassVar, Optional

import jax
import jax.numpy as jnp


class ClientSampler:
    """Returns a (m,) bool participation mask per round; None = everyone."""

    needs_key: ClassVar[bool] = False   # engine only spends PRNG keys on
                                        # stochastic samplers, preserving the
                                        # full-participation RNG stream

    def sample(self, rnd: int, m: int,
               key: Optional[jnp.ndarray]) -> Optional[jnp.ndarray]:
        raise NotImplementedError


class FullParticipation(ClientSampler):
    """Every client, every round — identical to passing no sampler."""

    def sample(self, rnd, m, key):
        return None


class UniformFraction(ClientSampler):
    """Uniformly sample a per-round cohort without replacement: either
    ``round(fraction * m)`` clients (at least ``min_clients``) or an exact
    ``count`` — the latter lets async arrival tests pin cohort sizes."""

    needs_key = True

    def __init__(self, fraction: Optional[float] = None,
                 min_clients: int = 1, *, count: Optional[int] = None):
        if (fraction is None) == (count is None):
            raise ValueError("pass exactly one of `fraction` or `count`")
        if fraction is not None and not 0.0 < fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {fraction}")
        if count is not None and count < 1:
            raise ValueError(f"count must be >= 1, got {count}")
        self.fraction = None if fraction is None else float(fraction)
        self.count = None if count is None else int(count)
        self.min_clients = int(min_clients)

    def sample(self, rnd, m, key):
        if self.count is not None:
            k = min(m, max(self.min_clients, self.count))
        else:
            k = min(m, max(self.min_clients, int(round(self.fraction * m))))
        if k >= m:
            return None
        idx = jax.random.permutation(key, m)[:k]
        return jnp.zeros((m,), dtype=bool).at[idx].set(True)
