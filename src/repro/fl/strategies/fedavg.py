"""FedAvg: one global model, size-weighted average, one broadcast stream."""
from __future__ import annotations

from repro.core import fedavg_weights
from repro.fl.strategies.base import CommCost, RoundContext, Strategy
from repro.fl.strategies.registry import register


@register
class FedAvg(Strategy):
    name = "fedavg"
    reads_prev = False      # engine may donate the pre-round buffers
    traceable = True        # pure W-mix: qualifies for the fused superstep

    def setup(self, ctx: RoundContext):
        return fedavg_weights(ctx.fed.n)          # (m, m), every row n/Σn

    def aggregate(self, state, stacked, prev, ctx):
        return ctx.mix(stacked, state), state

    def traced_state(self, state):
        return state                              # the (m, m) weight matrix

    def aggregate_traced(self, arrays, stacked, prev, tmix):
        return tmix.mix(stacked, arrays)

    def comm(self, state) -> CommCost:
        return CommCost(1, 0)

    @classmethod
    def downlink_cost(cls, m, *, n_streams=1, fomo_candidates=5):
        return CommCost(n_streams, 0)
