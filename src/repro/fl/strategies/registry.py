"""Strategy registry + spec grammar (DESIGN.md §5).

Specs are ``<family>[_k<INT>]``: a bare registered family name
(``"fedavg"``, ``"ucfl"``) or a family with a stream-count parameter
(``"ucfl_k3"`` -> ``UCFL(k=3)``).  Keyword overrides win over parsed
parameters: ``get_strategy("ucfl", k=4) == get_strategy("ucfl_k4")``.
"""
from __future__ import annotations

import inspect
import re
from typing import Dict, Tuple, Type

from repro.fl.strategies.base import Strategy

STRATEGIES: Dict[str, Type[Strategy]] = {}

_SPEC_RE = re.compile(r"^(?P<family>[a-z][a-z0-9_]*?)_k(?P<k>\d+)$")


def register(cls: Type[Strategy]) -> Type[Strategy]:
    """Class decorator: add a Strategy subclass under ``cls.name``."""
    if not issubclass(cls, Strategy):
        raise TypeError(f"{cls!r} is not a Strategy subclass")
    STRATEGIES[cls.name] = cls
    return cls


def parse_spec(spec: str) -> Tuple[str, dict]:
    """``spec -> (family, kwargs)``; raises ValueError on unknown specs."""
    if spec in STRATEGIES:
        return spec, {}
    mt = _SPEC_RE.match(spec)
    if mt and mt.group("family") in STRATEGIES:
        family = mt.group("family")
        params = inspect.signature(STRATEGIES[family].__init__).parameters
        if "k" not in params:
            raise ValueError(
                f"strategy family {family!r} takes no _k parameter "
                f"(spec {spec!r})")
        return family, {"k": int(mt.group("k"))}
    raise ValueError(
        f"unknown strategy spec {spec!r}; registered families: "
        f"{sorted(STRATEGIES)} (grammar: <family>[_k<INT>])")


def get_strategy_class(spec: str) -> Type[Strategy]:
    family, _ = parse_spec(spec)
    return STRATEGIES[family]


def get_strategy(spec: str, **kwargs) -> Strategy:
    """Instantiate a registered strategy from its spec string."""
    family, parsed = parse_spec(spec)
    parsed.update(kwargs)
    return STRATEGIES[family](**parsed)


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(STRATEGIES))
