"""Local-only baseline: no aggregation, no downlink."""
from __future__ import annotations

from repro.fl.strategies.base import CommCost, Strategy
from repro.fl.strategies.registry import register


@register
class Local(Strategy):
    name = "local"
    reads_prev = False      # engine may donate the pre-round buffers
    traceable = True        # identity aggregation: trivially fusible

    def aggregate(self, state, stacked, prev, ctx):
        return stacked, state

    def traced_state(self, state):
        return ()

    def aggregate_traced(self, arrays, stacked, prev, tmix):
        return stacked

    def comm(self, state) -> CommCost:
        return CommCost(0, 0)

    @classmethod
    def downlink_cost(cls, m, *, n_streams=1, fomo_candidates=5):
        return CommCost(0, 0)
