"""User-centric FL (the paper's method).

`UCFL()` is full personalization: one similarity round at the common
initialization builds the Eq. 6 mixing matrix W, and every round each
client receives its own W-row mixture (m unicast streams).

`UCFL(k=...)` (spec ``ucfl_k<k>``) is the §III-B stream reduction: k-means
over the rows of W yields k centroid aggregation rules served by group
broadcast.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kmeans, mixing_matrix
from repro.core.similarity import delta_matrix
from repro.core.streams import StreamPlan
from repro.fl.stats import full_client_gradients, sigma2_estimates
from repro.fl.strategies.base import (CommCost, MixingExtras, RoundContext,
                                      Strategy)
from repro.fl.strategies.registry import register


class UCFLState(NamedTuple):
    w: jnp.ndarray                  # (m, m) Eq. 6 mixing matrix
    plan: Optional[StreamPlan]      # k-means stream plan (None = unicast)
    n_streams: int


@register
class UCFL(Strategy):
    name = "ucfl"
    reads_prev = False      # engine may donate the pre-round buffers
    traceable = True        # pure W / StreamPlan mix, round-constant state

    def __init__(self, k: Optional[int] = None):
        if k is not None and k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        self.k = k

    @property
    def spec(self) -> str:
        return self.name if self.k is None else f"{self.name}_k{self.k}"

    def setup(self, ctx: RoundContext) -> UCFLState:
        grads = full_client_gradients(ctx.loss_fn, ctx.params0, ctx.fed)
        delta = delta_matrix(grads)
        sigma2 = sigma2_estimates(ctx.loss_fn, ctx.params0, ctx.fed,
                                  ctx.fl.sigma_batches)
        w = mixing_matrix(delta, sigma2, ctx.fed.n)
        if self.k is None:
            return UCFLState(w=w, plan=None, n_streams=ctx.fed.m)
        plan = kmeans(w, self.k, key=jax.random.PRNGKey(ctx.seed + 1))
        # kmeans clamps k to m: report the streams actually transmitted
        return UCFLState(w=w, plan=plan,
                         n_streams=int(plan.centroids.shape[0]))

    def aggregate(self, state: UCFLState, stacked, prev, ctx):
        if state.plan is None:
            return ctx.mix(stacked, state.w), state
        return ctx.mix_plan(stacked, state.plan), state

    def traced_state(self, state: UCFLState):
        # structure depends only on the spec: unicast (k=None) mixes the
        # full W, stream reduction mixes the k-means plan
        if state.plan is None:
            return (state.w,)
        return (state.plan.centroids, state.plan.assignment)

    def aggregate_traced(self, arrays, stacked, prev, tmix):
        if len(arrays) == 1:
            return tmix.mix(stacked, arrays[0])
        return tmix.mix_plan(stacked, arrays[0], arrays[1])

    def comm(self, state: UCFLState) -> CommCost:
        return CommCost(state.n_streams, 0)

    def membership(self, state: UCFLState) -> np.ndarray:
        if state.plan is None:          # full personalization: own stream
            return np.arange(state.w.shape[0], dtype=np.int64)
        return np.asarray(state.plan.assignment, np.int64)

    def extras(self, state: UCFLState) -> MixingExtras:
        return MixingExtras(mixing_matrix=np.asarray(state.w),
                            assignment=self.membership(state))

    @classmethod
    def downlink_cost(cls, m, *, n_streams=1, fomo_candidates=5):
        return CommCost(n_streams, 0)
