"""Communication/straggler time model (paper §IV-C).

Time unit = T_dl (one model broadcast on the downlink).
  * uplink per round: ρ = T_ul/T_dl ∈ [1, 4]   (clients upload in parallel)
  * downlink per round: one T_dl per distinct model stream (group broadcast);
    client-side personalization (FedFOMO) needs unicasts — one per
    (client, candidate model) pair.
  * compute: shifted exponential per client; the round waits for the slowest:
    E[max] = T_min + H_m/μ (H_m the m-th harmonic number).
"""
from __future__ import annotations

import math
from dataclasses import dataclass


def harmonic(m: int) -> float:
    return sum(1.0 / i for i in range(1, m + 1))


@dataclass(frozen=True)
class SystemModel:
    rho: float = 4.0            # T_ul / T_dl
    t_min: float = 1.0          # min compute time, units of T_dl
    inv_mu: float = 1.0         # 1/μ: mean extra straggler delay (0 = reliable)
    name: str = "wireless-slow-ul"

    def compute_time(self, m: int) -> float:
        return self.t_min + self.inv_mu * harmonic(m) if self.inv_mu else self.t_min

    def round_time(self, m: int, *, n_streams: int = 1,
                   n_unicasts: int = 0) -> float:
        return self.compute_time(m) + self.rho + n_streams + n_unicasts


# the three systems of Fig. 3
WIRELESS_SLOW_UL = SystemModel(rho=4.0, t_min=1.0, inv_mu=1.0,
                               name="wireless rho=4, unreliable")
WIRELESS_FAST_UL = SystemModel(rho=2.0, t_min=1.0, inv_mu=0.0,
                               name="wireless rho=2, reliable")
WIRED = SystemModel(rho=1.0, t_min=1.0, inv_mu=0.0, name="wired rho=1")

SYSTEMS = {"wireless_slow": WIRELESS_SLOW_UL,
           "wireless_fast": WIRELESS_FAST_UL,
           "wired": WIRED}


def downlink_cost(algorithm: str, m: int, n_streams: int = 1,
                  fomo_candidates: int = 5):
    """(n_streams, n_unicasts) per round for each algorithm family."""
    if algorithm in ("fedavg", "cfl", "oracle"):
        # cfl/oracle: one broadcast per cluster; caller passes n_streams
        return n_streams, 0
    if algorithm == "local":
        return 0, 0
    if algorithm.startswith("ucfl"):
        return n_streams, 0
    if algorithm == "fedfomo":
        return 0, m * fomo_candidates
    raise ValueError(algorithm)
