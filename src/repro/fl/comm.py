"""Communication/straggler time model (paper §IV-C).

Time unit = T_dl (one model broadcast on the downlink).
  * uplink per round: ρ = T_ul/T_dl ∈ [1, 4]   (clients upload in parallel)
  * downlink per round: one T_dl per distinct model stream (group broadcast);
    client-side personalization (FedFOMO) needs unicasts — one per
    (client, candidate model) pair.
  * compute: shifted exponential per client; the round waits for the slowest:
    E[max] = T_min + H_m/μ (H_m the m-th harmonic number).

The per-algorithm downlink table lives on each Strategy class
(repro.fl.strategies); `downlink_cost` here is the legacy string entry
point and simply resolves the spec through the registry.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

_EULER_GAMMA = 0.5772156649015329
_HARMONIC_EXACT_MAX = 64


def harmonic(m: int) -> float:
    """H_m = Σ_{i<=m} 1/i.  Exact sum up to ``_HARMONIC_EXACT_MAX``; above
    it the asymptotic expansion ln(m) + γ + 1/(2m) − 1/(12m²), whose error
    is O(1/m⁴) < 1e-9 at the crossover — keeps `SystemModel.round_time`
    O(1) at million-user scale."""
    m = int(m)
    if m <= 0:
        return 0.0
    if m <= _HARMONIC_EXACT_MAX:
        return sum(1.0 / i for i in range(1, m + 1))
    return math.log(m) + _EULER_GAMMA + 1.0 / (2 * m) - 1.0 / (12 * m * m)


@dataclass(frozen=True)
class SystemModel:
    rho: float = 4.0            # T_ul / T_dl
    t_min: float = 1.0          # min compute time, units of T_dl
    inv_mu: float = 1.0         # 1/μ: mean extra straggler delay (0 = reliable)
    name: str = "wireless-slow-ul"

    def compute_time(self, m: int) -> float:
        return self.t_min + self.inv_mu * harmonic(m) if self.inv_mu else self.t_min

    def round_time(self, m: int, *, n_streams: int = 1,
                   n_unicasts: int = 0) -> float:
        """Analytic synchronous round: E[max of m stragglers] + UL + DL.
        ``m`` is the PARTICIPANT count — a round only waits for the clients
        that actually compute (H_|S|, not H_m, under partial sampling)."""
        return self.compute_time(m) + self.rho + n_streams + n_unicasts

    def sample_compute_time(self, rng) -> float:
        """One client's compute draw for the async runtime (DESIGN.md
        §3a): the shifted-exponential law whose order statistics give the
        analytic ``E[max] = t_min + H_m/μ``.  ``inv_mu=0`` degenerates to
        the deterministic ``t_min`` (lockstep arrivals).  Exactly one RNG
        draw when ``inv_mu > 0``, none otherwise."""
        extra = float(rng.exponential(self.inv_mu)) if self.inv_mu else 0.0
        return self.t_min + extra

    def sample_client_time(self, rng) -> float:
        """Compute draw plus the homogeneous ρ uplink — the full
        download-to-upload round trip under this system's own channel
        (a `LinkProfile` replaces the ρ term per client, DESIGN.md §3b)."""
        return self.sample_compute_time(rng) + self.rho


# the three systems of Fig. 3
WIRELESS_SLOW_UL = SystemModel(rho=4.0, t_min=1.0, inv_mu=1.0,
                               name="wireless rho=4, unreliable")
WIRELESS_FAST_UL = SystemModel(rho=2.0, t_min=1.0, inv_mu=0.0,
                               name="wireless rho=2, reliable")
WIRED = SystemModel(rho=1.0, t_min=1.0, inv_mu=0.0, name="wired rho=1")

SYSTEMS = {"wireless_slow": WIRELESS_SLOW_UL,
           "wireless_fast": WIRELESS_FAST_UL,
           "wired": WIRED}


def downlink_cost(algorithm: str, m: int, n_streams: int = 1,
                  fomo_candidates: int = 5):
    """(n_streams, n_unicasts) per round — legacy shim over the registry:
    each Strategy class owns its entry via ``Strategy.downlink_cost``."""
    from repro.fl.strategies import get_strategy_class
    cls = get_strategy_class(algorithm)
    return tuple(cls.downlink_cost(m, n_streams=n_streams,
                                   fomo_candidates=fomo_candidates))
