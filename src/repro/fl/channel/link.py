"""Per-client wireless link profiles (DESIGN.md §3b).

The legacy clock (`repro.fl.comm.SystemModel`) charges every client the
same ρ = T_ul/T_dl and every broadcast one T_dl — a homogeneous channel.
A `LinkProfile` makes the links per-client and bit-denominated, the
follow-on the ROADMAP names from the authors' sequel (arXiv:2304.12930):

  * ``dl_rate[i]`` — client i's downlink rate in bits per T_dl;
  * ``ul_ratio[i]`` — client i's uplink slowdown ρ_i (uplink moves bits
    ``ρ_i×`` slower than its downlink).

Client time is payload/rate: ``downlink_time(i, b) = b / dl_rate[i]`` and
``uplink_time(i, b) = b · ρ_i / dl_rate[i]``.  A broadcast must reach its
slowest subscriber, so a group stream is charged at ``min dl_rate`` over
the receiving cohort — an UPPER BOUND when several streams serve disjoint
subsets.  When the strategy exposes its client→stream map
(`Strategy.membership`), `round_downlink_time` charges each stream at its
OWN slowest subscriber instead — strictly tighter on heterogeneous
profiles, bit-identical on uniform ones.  Unicasts each reach one
receiver and are charged the cohort-mean per-client time.

`from_system(system, ref_bits, m)` is the exactness anchor: a uniform
profile with ``dl_rate = ref_bits`` and ``ul_ratio = ρ`` charges the
uncompressed model exactly 1.0 T_dl down and exactly ρ up — IEEE-754
guarantees ``(bits·ρ)/bits == ρ`` here — so `codec=identity` reproduces
the legacy clock bit-for-bit on both engines.

Spec grammar (CLI ``--link-profile``):

  uniform                  from_system (homogeneous; parity anchor)
  tiered:<factor>          odd-indexed clients run ``factor×`` slower
  lognormal:<sigma>        per-client rates scaled by LogNormal(0, σ²)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.fl.comm import SystemModel


@dataclass(frozen=True)
class LinkProfile:
    """Per-client uplink/downlink link budget; see module docstring."""

    dl_rate: np.ndarray                 # (m,) bits per T_dl
    ul_ratio: np.ndarray                # (m,) ρ_i = uplink slowdown
    name: str = "custom"

    def __post_init__(self):
        object.__setattr__(self, "dl_rate",
                           np.asarray(self.dl_rate, np.float64))
        object.__setattr__(self, "ul_ratio",
                           np.asarray(self.ul_ratio, np.float64))
        if self.dl_rate.shape != self.ul_ratio.shape:
            raise ValueError("dl_rate and ul_ratio must have equal shape, "
                             f"got {self.dl_rate.shape} vs "
                             f"{self.ul_ratio.shape}")
        if np.any(self.dl_rate <= 0) or np.any(self.ul_ratio <= 0):
            raise ValueError("link rates/ratios must be positive")

    @property
    def m(self) -> int:
        return int(self.dl_rate.shape[0])

    def _rates(self, clients: Optional[Sequence[int]]) -> np.ndarray:
        """dl rates of a cohort; an EMPTY cohort (a sampler round with zero
        participants) falls back to the full profile — a broadcast still
        goes out to whoever listens."""
        if clients is None:
            return self.dl_rate
        idx = np.asarray(clients, np.int64)
        return self.dl_rate if idx.size == 0 else self.dl_rate[idx]

    def downlink_time(self, bits: float,
                      clients: Optional[Sequence[int]] = None) -> float:
        """One broadcast of ``bits`` to ``clients`` (None = everyone):
        charged at the slowest subscriber's rate."""
        return float(bits / np.min(self._rates(clients)))

    def uplink_time(self, client: int, bits: float) -> float:
        return float((bits * self.ul_ratio[client]) / self.dl_rate[client])

    def max_uplink_time(self, bits,
                        clients: Optional[Sequence[int]] = None) -> float:
        """Slowest participant's upload (the sync round waits for it);
        0.0 for an empty cohort — nobody uploads, nothing to wait for.
        ``bits`` may be a scalar or an (m,) per-client payload vector
        (rate-adaptive codecs); the scalar path is the vector path with a
        constant, so the two agree bit-for-bit on fixed codecs."""
        idx = (slice(None) if clients is None
               else np.asarray(clients, np.int64))
        if clients is not None and idx.size == 0:
            return 0.0
        b = bits[idx] if isinstance(bits, np.ndarray) and bits.ndim else bits
        return float(np.max((b * self.ul_ratio[idx]) / self.dl_rate[idx]))

    def mean_unicast_time(self, bits: float,
                          clients: Optional[Sequence[int]] = None) -> float:
        """Average per-unicast downlink over ``clients``: a unicast reaches
        ONE receiver at that receiver's own rate, so a batch of unicasts
        spread over the cohort is charged the cohort-mean time, not the
        slowest subscriber's (that penalty is broadcast-only)."""
        return float(np.mean(bits / self._rates(clients)))

    # ---- constructors -----------------------------------------------------

    @classmethod
    def from_system(cls, system: SystemModel, ref_bits: int,
                    m: int) -> "LinkProfile":
        """Uniform profile reproducing ``system``'s clock on a payload of
        ``ref_bits`` (the uncompressed model): 1 T_dl down, ρ up — exact."""
        return cls(dl_rate=np.full(m, float(ref_bits)),
                   ul_ratio=np.full(m, float(system.rho)),
                   name="uniform")

    @classmethod
    def tiered(cls, system: SystemModel, ref_bits: int, m: int, *,
               factor: float = 4.0) -> "LinkProfile":
        """Every other client on a ``factor×`` slower link (cell-edge
        users): deterministic, no RNG spent."""
        if factor < 1.0:
            raise ValueError(f"tiered factor must be >= 1, got {factor}")
        dl = np.full(m, float(ref_bits))
        dl[1::2] /= factor
        return cls(dl_rate=dl, ul_ratio=np.full(m, float(system.rho)),
                   name=f"tiered:{factor:g}")

    @classmethod
    def lognormal(cls, system: SystemModel, ref_bits: int, m: int, *,
                  sigma: float = 0.5, seed: int = 0) -> "LinkProfile":
        """Rates scaled by LogNormal(0, σ²) draws (shadow fading),
        median-normalized so σ spreads without shifting the typical link."""
        if sigma < 0:
            raise ValueError(f"lognormal sigma must be >= 0, got {sigma}")
        rng = np.random.default_rng(seed)
        scale = np.exp(rng.normal(0.0, sigma, size=m))
        return cls(dl_rate=float(ref_bits) * scale,
                   ul_ratio=np.full(m, float(system.rho)),
                   name=f"lognormal:{sigma:g}")


# the one list `Channel.__post_init__` validates against and
# `get_link_profile` dispatches over — extend both via this tuple
LINK_FAMILIES = ("uniform", "tiered", "lognormal")


def get_link_profile(spec, system: SystemModel, ref_bits: int,
                     m: int) -> LinkProfile:
    """``"uniform" | "tiered:<factor>" | "lognormal:<sigma>"`` ->
    LinkProfile (instances pass through)."""
    if isinstance(spec, LinkProfile):
        return spec
    family, _, param = str(spec).partition(":")
    try:
        if family == "uniform" and not param:
            return LinkProfile.from_system(system, ref_bits, m)
        if family == "tiered":
            return LinkProfile.tiered(system, ref_bits, m,
                                      **({"factor": float(param)}
                                         if param else {}))
        if family == "lognormal":
            return LinkProfile.lognormal(system, ref_bits, m,
                                         **({"sigma": float(param)}
                                            if param else {}))
    except ValueError as e:
        if "could not convert" in str(e):
            raise ValueError(f"bad link-profile parameter in {spec!r}") \
                from None
        raise
    raise ValueError(f"unknown link profile {spec!r}; families: "
                     f"{list(LINK_FAMILIES)}")


def round_downlink_time(link: LinkProfile, cost, payload_bits: int,
                        participants: Optional[Sequence[int]] = None,
                        assignment: Optional[np.ndarray] = None) -> float:
    """Total serialized downlink of one round/event — BOTH engines charge
    through here (the sync analytic clock directly, the async engine as
    its event's `serve` duration): ``n_streams`` group broadcasts plus
    ``n_unicasts`` unicasts, each moving one compressed model.
    Broadcasts are charged at the slowest participating rate (a group
    stream must reach its slowest subscriber); unicasts each reach ONE
    receiver, so they are charged the cohort-mean per-client time.  With
    a uniform `from_system` profile and the identity codec every term is
    exactly 1.0, recovering the legacy ``n_streams + n_unicasts``.

    ``assignment`` — optional (m,) client→stream map from
    `Strategy.membership` (the `StreamPlan` assignment / CFL clusters).
    When given, each broadcast is charged at ITS OWN stream's slowest
    subscriber instead of the cohort-wide minimum — strictly tighter on
    heterogeneous profiles.  The refinement only engages when some
    stream's rate actually beats the cohort minimum: whenever every
    stream bottoms out at the same rate (uniform profiles in particular)
    the legacy ``n_streams × t`` multiply is kept verbatim, so the
    identity-codec parity anchors stay bit-exact (``n·t`` and ``t`` summed
    n times differ in floating point)."""
    if assignment is not None and cost.n_streams:
        asn = np.asarray(assignment, np.int64)
        if asn.shape != (link.m,):
            raise ValueError(f"assignment must be (m,)=({link.m},), got "
                             f"{asn.shape}")
        part = (np.arange(link.m, dtype=np.int64) if participants is None
                else np.asarray(participants, np.int64))
        cohort = part if part.size else np.arange(link.m, dtype=np.int64)
        slowest = float(np.min(link.dl_rate[cohort]))
        rates = []                     # per-stream slowest subscriber rate
        for s in np.unique(asn[cohort]):
            rates.append(float(np.min(link.dl_rate[cohort[
                asn[cohort] == s]])))
        # idle streams (no subscriber in the cohort) are still charged at
        # the cohort floor: the server transmits them regardless
        rates += [slowest] * (cost.n_streams - len(rates))
        # a clamped CommCost (async buffering) can charge FEWER streams
        # than the cohort spans — membership no longer maps 1:1, keep the
        # legacy upper bound
        if len(rates) <= cost.n_streams and any(r > slowest for r in rates):
            t = float(sum(payload_bits / r for r in rates))
            if cost.n_unicasts:
                t += cost.n_unicasts * link.mean_unicast_time(
                    payload_bits, participants)
            return t
    t = cost.n_streams * link.downlink_time(payload_bits, participants)
    if cost.n_unicasts:
        t += cost.n_unicasts * link.mean_unicast_time(payload_bits,
                                                      participants)
    return t
