"""Bit-level payload accounting (DESIGN.md §3b).

The paper's communication axis is the abstract broadcast unit T_dl; this
module makes it physical: exact bit counts for any model/update pytree,
derived from the leaves' dtypes — nothing is assumed about architecture
or layout.  `ChannelCost` is the bits-based sibling of the legacy
`CommCost(n_streams, n_unicasts)` record: the engines append one per
round/event to `History.comm_bits` whenever a `Channel` is attached.

Codecs (repro.fl.channel.codecs) operate on the (m, D) client-flat view;
`stacked_ravel`/`stacked_unravel` are the loss-free bridges between the
client-stacked pytree and that view.
"""
from __future__ import annotations

from typing import Any, List, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ChannelCost(NamedTuple):
    """Per-round bit accounting: total downlink and uplink payload bits."""
    dl_bits: int
    ul_bits: int


def dtype_bits(dtype) -> int:
    """Bits per element on the wire for ``dtype`` (8 · itemsize; bools ride
    as bytes, matching their in-memory representation)."""
    return int(np.dtype(dtype).itemsize) * 8


def leaf_bits(leaf) -> int:
    return int(np.prod(np.shape(leaf)) or 1) * dtype_bits(
        getattr(leaf, "dtype", np.float32))


def tree_bits(tree: Any) -> int:
    """Exact payload bits of one pytree (e.g. a single client's model)."""
    return sum(leaf_bits(l) for l in jax.tree_util.tree_leaves(tree))


def tree_size(tree: Any) -> int:
    """Total element count across all leaves (codec payload arithmetic)."""
    return sum(int(np.prod(np.shape(l)) or 1)
               for l in jax.tree_util.tree_leaves(tree))


def stacked_ravel(stacked: Any) -> jnp.ndarray:
    """Client-stacked pytree (every leaf (m, ...)) -> (m, D) f32 flat view."""
    leaves = jax.tree_util.tree_leaves(stacked)
    m = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)


def stacked_unravel(flat: jnp.ndarray, like: Any) -> Any:
    """Inverse of `stacked_ravel`: split (m, D) back into ``like``'s
    structure/shapes/dtypes."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    m = leaves[0].shape[0]
    sizes = [int(np.prod(l.shape[1:]) or 1) for l in leaves]
    offsets = np.cumsum([0] + sizes)
    out: List[jnp.ndarray] = []
    for l, lo, hi in zip(leaves, offsets[:-1], offsets[1:]):
        out.append(flat[:, lo:hi].reshape(l.shape).astype(l.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
