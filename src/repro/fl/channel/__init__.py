"""Wireless channel subsystem (DESIGN.md §3b).

Makes the paper's communication axis physical: exact bit-level payload
accounting (`payload`), uplink compression codecs with error feedback
(`codecs`), and per-client link profiles driving both clocks (`link`).

    run_federated("ucfl_k2", fed,
                  channel=Channel(codec="qsgd:8"), system=SYSTEMS["wired"])

With a `Channel` attached the engines (sync and async) additionally record
`History.comm_bits` (downlink/uplink bits per round) and, when a `system`
is present, drive the clock from the link profile instead of the
homogeneous ρ/T_dl constants.  ``Channel()`` — identity codec, uniform
link — reproduces the channel-less engines bit-for-bit (the §3b anchor).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union

from repro.fl.channel.codecs import (BACKENDS, CODECS, Adaptive,
                                     AdaptiveTopK, BoundAdaptive,
                                     BoundAdaptiveTopK, Codec, Identity,
                                     QSGD, TopK, apply_uplink, get_codec,
                                     register_codec, uplink_roundtrip,
                                     zeros_like_stack)
from repro.fl.channel.link import (LINK_FAMILIES, LinkProfile,
                                   get_link_profile, round_downlink_time)
from repro.fl.channel.payload import (ChannelCost, dtype_bits, leaf_bits,
                                      stacked_ravel, stacked_unravel,
                                      tree_bits, tree_size)


@dataclass(frozen=True)
class Channel:
    """The engine-facing channel configuration.

    codec:           a `Codec` instance or spec string (``identity``,
                     ``qsgd:<bits>``, ``topk:<frac>``).
    link:            a `LinkProfile`, a profile spec string (``uniform``,
                     ``tiered:<f>``, ``lognormal:<s>``), or None — None and
                     ``uniform`` both resolve to the `from_system` profile
                     that reproduces the legacy clock exactly.
    error_feedback:  carry per-client EF residuals across rounds (the
                     standard companion of biased codecs like top-k; exact
                     no-op under ``identity``).
    """
    codec: Union[str, Codec] = "identity"
    link: Union[str, LinkProfile, None] = None
    error_feedback: bool = True

    def __post_init__(self):
        object.__setattr__(self, "codec", get_codec(self.codec))
        if isinstance(self.link, str):
            # validate the family early; the profile itself needs (system,
            # ref_bits, m) and is resolved by the engine
            family = self.link.partition(":")[0]
            if family not in LINK_FAMILIES:
                raise ValueError(f"unknown link profile {self.link!r}; "
                                 f"families: {list(LINK_FAMILIES)}")

    def resolve_link(self, system, ref_bits: int, m: int) -> LinkProfile:
        spec = "uniform" if self.link is None else self.link
        return get_link_profile(spec, system, ref_bits, m)


def resolve_channel(channel: Union[str, "Channel", None]
                    ) -> Optional["Channel"]:
    """None -> None (legacy engines, zero new code paths); a codec spec
    string -> Channel(codec=spec)."""
    if channel is None or isinstance(channel, Channel):
        return channel
    return Channel(codec=channel)


__all__ = [
    "Adaptive", "AdaptiveTopK", "BACKENDS", "BoundAdaptive",
    "BoundAdaptiveTopK", "CODECS", "Channel",
    "ChannelCost", "Codec", "Identity",
    "LINK_FAMILIES", "LinkProfile", "QSGD", "TopK", "apply_uplink",
    "dtype_bits", "get_codec",
    "get_link_profile", "leaf_bits", "register_codec", "resolve_channel",
    "stacked_ravel", "stacked_unravel", "round_downlink_time",
    "tree_bits", "tree_size", "uplink_roundtrip", "zeros_like_stack",
]
