"""Uplink compression codecs with error feedback (DESIGN.md §3b).

A `Codec` is one lossy (or identity) channel code for the client->server
update payload.  The simulation never materializes packed bitstreams: a
codec exposes

  * ``roundtrip(flat, key)``   — decode(encode(·)) on the (m, D) client-
    flat view: the values the SERVER sees.  Rows are independent clients.
  * ``payload_bits(tree)``     — exact wire bits for one client's payload
    of ``tree``'s size (per-element code bits + per-client side info).

Registered codecs (spec grammar ``<family>[:<param>]``, mirroring the
strategy registry §5):

  identity        lossless float passthrough (bit-parity anchor)
  qsgd:<bits>     signed stochastic uniform quantization, b ∈ [2, 8]
                  (QSGD, Alistarh et al. 2017): d·b bits + one 32-bit
                  per-client scale
  topk:<frac>     magnitude top-k sparsification, k = ⌈frac·d⌉:
                  k · (32-bit value + 32-bit index)

Error feedback (Seide et al. 2014 / EF-SGD): the engines keep a per-client
residual stack e_i; each round the codec transmits v = Δ + e and the new
residual is e' = v − decode(v), so *everything the channel drops is
retransmitted later* — `apply_uplink` below owns that algebra, jitted and
cached per (codec, backend, masking).  ``backend="pallas"`` executes the
`repro.kernels` quantize/top-k-threshold kernels (HostVmap); ``"jnp"`` is
the bit-identical-for-qsgd pure-jnp path the mesh placement shards under
GSPMD.
"""
from __future__ import annotations

import abc
import functools
import math
from typing import Any, ClassVar, Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.channel.payload import (stacked_ravel, stacked_unravel,
                                      tree_bits, tree_size)

BACKENDS = ("pallas", "jnp")


class Codec(abc.ABC):
    """One uplink channel code; subclass + `@register_codec` to add."""

    name: ClassVar[str]
    is_identity: ClassVar[bool] = False

    @property
    def spec(self) -> str:
        """Registry spec string that reconstructs this instance."""
        return self.name

    @abc.abstractmethod
    def payload_bits(self, tree: Any) -> int:
        """Exact uplink bits for ONE client's payload of ``tree``'s size."""

    @abc.abstractmethod
    def roundtrip(self, flat: jnp.ndarray, key: jnp.ndarray, *,
                  backend: str = "pallas") -> jnp.ndarray:
        """decode(encode(flat)) per row; (m, D) f32 -> (m, D) f32."""

    # ---- at-rest wire format (serving plane, DESIGN.md §3d) ---------------
    # `encode` materializes the codec's payload as a dict of row-aligned
    # arrays (every value has leading dim m) so a store can GATHER a request
    # batch's rows and decode only those; `decode(encode(x)) ==
    # roundtrip(x)` bit-for-bit per backend.  The default keeps the decoded
    # dense values (identity and any codec without a compact residency).

    def encode(self, flat: jnp.ndarray, key: jnp.ndarray, *,
               backend: str = "pallas") -> Dict[str, jnp.ndarray]:
        """(m, D) f32 -> payload dict of (m, ...) arrays."""
        return {"dense": self.roundtrip(flat, key, backend=backend)}

    def decode(self, payload: Dict[str, jnp.ndarray], *,
               backend: str = "pallas", d: Optional[int] = None
               ) -> jnp.ndarray:
        """Payload dict (rows possibly gathered) -> (m, D) f32 values.
        ``d`` is the dense width — required only by sparse payloads."""
        return payload["dense"]

    def store_bound(self, payload: Dict[str, np.ndarray],
                    d: int) -> Optional[np.ndarray]:
        """(m,) per-row max-abs reconstruction error bound of
        ``decode(encode(x)) - x``, computable from the HOST-side payload
        alone — the serving store enforces it at build time.  None when
        the codec documents no bound (the store then skips the check)."""
        return None

    # ---- link adaptation (rate-adaptive codecs, DESIGN.md §3b) ------------

    def bind_link(self, link: Any, tree: Any) -> "Codec":
        """Specialize this codec to a resolved `LinkProfile` (the engines
        call it from `init_channel`).  Fixed codecs return themselves;
        `Adaptive` returns a bound instance with per-client parameters."""
        return self

    def per_client_bits(self, tree: Any, m: int) -> np.ndarray:
        """(m,) exact uplink bits per client (vector sibling of
        `payload_bits`; non-uniform only for link-bound adaptive codecs)."""
        return np.full(m, self.payload_bits(tree), dtype=np.int64)

    # codecs are value objects: spec identity drives the jit caches
    def __eq__(self, other) -> bool:
        return isinstance(other, Codec) and self.spec == other.spec

    def __hash__(self) -> int:
        return hash(self.spec)

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec!r})"


CODECS: Dict[str, Type[Codec]] = {}


def register_codec(cls: Type[Codec]) -> Type[Codec]:
    CODECS[cls.name] = cls
    return cls


@register_codec
class Identity(Codec):
    """Lossless passthrough: raw dtype bits, engines skip the value path
    entirely (the bit-parity anchor of DESIGN.md §3b)."""

    name = "identity"
    is_identity = True

    def payload_bits(self, tree: Any) -> int:
        return tree_bits(tree)

    def roundtrip(self, flat, key, *, backend="pallas"):
        return flat

    def store_bound(self, payload, d):
        return np.zeros(payload["dense"].shape[0])  # lossless: exact


@register_codec
class QSGD(Codec):
    """Stochastic uniform quantization onto ``{-s..s}·scale`` per client,
    s = 2^(b−1) − 1, scale = max|x|/s.  Unbiased given the scale:
    E[roundtrip(x)] = x (stochastic rounding ``floor(y + u)``)."""

    name = "qsgd"

    def __init__(self, bits: int = 8):
        if not 2 <= int(bits) <= 8:
            raise ValueError(f"qsgd bits must be in [2, 8], got {bits}")
        self.bits = int(bits)

    @property
    def spec(self) -> str:
        return f"{self.name}:{self.bits}"

    def payload_bits(self, tree: Any) -> int:
        return tree_size(tree) * self.bits + 32     # + per-client scale

    def roundtrip(self, flat, key, *, backend="pallas"):
        noise = jax.random.uniform(key, flat.shape, jnp.float32)
        if backend == "pallas":
            from repro.kernels import ops
            return ops.qsgd_roundtrip(flat, noise, bits=self.bits)
        from repro.kernels import ref
        return ref.qsgd_roundtrip_ref(flat, noise, self.bits)

    def encode(self, flat, key, *, backend="pallas"):
        """Resident payload: int32 levels (m, D) + per-row absmax (m, 1) —
        the accounted b bits/element + 32-bit scale of `payload_bits`."""
        noise = jax.random.uniform(key, flat.shape, jnp.float32)
        if backend == "pallas":
            from repro.kernels import ops
            q, amax = ops.qsgd_quantize(flat, noise, bits=self.bits)
            return {"levels": q, "absmax": amax}
        # pure-jnp split of ref.qsgd_roundtrip_ref — same op sequence, so
        # decode(encode(x)) stays bit-identical to roundtrip(x)
        s = float(2 ** (self.bits - 1) - 1)
        amax = jnp.max(jnp.abs(flat), axis=1, keepdims=True)
        scale = amax * (1.0 / s)
        inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
        q = jnp.clip(jnp.floor(flat * inv + noise), -s, s).astype(jnp.int32)
        return {"levels": q, "absmax": amax}

    def decode(self, payload, *, backend="pallas", d=None):
        q, amax = payload["levels"], payload["absmax"]
        if backend == "pallas":
            from repro.kernels import ops
            return ops.qsgd_dequantize(q, amax, bits=self.bits)
        s = float(2 ** (self.bits - 1) - 1)
        return q.astype(jnp.float32) * (amax * (1.0 / s))

    def store_bound(self, payload, d):
        # stochastic rounding moves each element at most one level:
        # |x - decode| <= scale_i = absmax_i / s
        s = float(2 ** (self.bits - 1) - 1)
        return np.asarray(payload["absmax"])[:, 0].astype(np.float64) / s


@register_codec
class TopK(Codec):
    """Magnitude top-k sparsification: keep each client's k = ⌈frac·d⌉
    largest-|x| coordinates exactly, zero the rest.  Biased — error
    feedback is what makes it converge (the residual carries the tail)."""

    name = "topk"

    def __init__(self, frac: float = 0.1):
        if not 0.0 < float(frac) <= 1.0:
            raise ValueError(f"topk frac must be in (0, 1], got {frac}")
        self.frac = float(frac)

    @property
    def spec(self) -> str:
        return f"{self.name}:{self.frac:g}"

    def k(self, d: int) -> int:
        return max(1, min(d, int(math.ceil(self.frac * d))))

    def payload_bits(self, tree: Any) -> int:
        return self.k(tree_size(tree)) * (32 + 32)  # (value, index) pairs

    def roundtrip(self, flat, key, *, backend="pallas"):
        k = self.k(flat.shape[1])
        if backend == "pallas":
            from repro.kernels import ops
            thresh = ops.topk_threshold(jnp.abs(flat), k=k)
            return jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
        from repro.kernels import ref
        return jnp.where(ref.topk_mask_ref(flat, k), flat, 0.0)

    def encode(self, flat, key, *, backend="pallas"):
        """Resident payload: the k largest-|x| (value, index) pairs per row.
        Ties at the k-th magnitude resolve to the FIRST index (top_k order);
        `roundtrip` keeps every tied coordinate — both drop nothing larger
        than the k-th magnitude, so the documented error bound is shared."""
        k = self.k(flat.shape[1])
        idx = jax.lax.top_k(jnp.abs(flat), k)[1].astype(jnp.int32)
        vals = jnp.take_along_axis(flat, idx, axis=1)
        return {"values": vals, "indices": idx}

    def decode(self, payload, *, backend="pallas", d=None):
        vals, idx = payload["values"], payload["indices"]
        if d is None:
            raise ValueError("topk decode needs the dense width d")
        m = vals.shape[0]
        rows = jnp.arange(m, dtype=jnp.int32)[:, None]
        # scatter-add: every stored index appears once per row, so add ==
        # set on real entries and is a GSPMD-friendly single scatter
        return jnp.zeros((m, d), jnp.float32).at[rows, idx].add(vals)

    def store_bound(self, payload, d):
        # every dropped coordinate is <= the k-th kept magnitude
        vals = np.abs(np.asarray(payload["values"], np.float64))
        if vals.shape[1] >= d:
            return np.zeros(vals.shape[0])      # k == d keeps everything
        return np.min(vals, axis=1)


@register_codec
class Adaptive(Codec):
    """Rate-adaptive uplink code (DESIGN.md §3b): each client's qsgd bit
    width is picked from its `LinkProfile` so that EVERY upload fits the
    time budget of the slowest client sending the minimum spec — faster
    links spend their headroom on fidelity instead of idling at the
    round barrier.

    Spec grammar: ``adaptive`` (qsgd, bits ∈ [2, 8]) or
    ``adaptive:<min_bits>`` to raise the floor.  The instance the engines
    run is produced by `bind_link` (init_channel calls it once the link is
    resolved); using an UNBOUND adaptive codec's value path is an error.
    On a uniform profile every client lands exactly on ``min_bits``, so
    the charge equals ``qsgd:<min_bits>`` bit-for-bit.
    """

    name = "adaptive"

    def __init__(self, min_bits: int = 2, max_bits: int = 8):
        if not 2 <= int(min_bits) <= int(max_bits) <= 8:
            raise ValueError("adaptive bits must satisfy 2 <= min <= max "
                             f"<= 8, got [{min_bits}, {max_bits}]")
        self.min_bits = int(min_bits)
        self.max_bits = int(max_bits)

    @property
    def spec(self) -> str:
        if self.max_bits != 8:
            return f"{self.name}:{self.min_bits}:{self.max_bits}"
        if self.min_bits != 2:
            return f"{self.name}:{self.min_bits}"
        return self.name

    def payload_bits(self, tree: Any) -> int:
        raise RuntimeError(
            "adaptive codec is link-dependent: the engines bind it via "
            "Channel(link_profile=...) -> init_channel; call "
            "bind_link(link, tree) first")

    def roundtrip(self, flat, key, *, backend="pallas"):
        raise RuntimeError(
            "adaptive codec is link-dependent; bind_link(link, tree) first")

    def bind_link(self, link: Any, tree: Any) -> "Codec":
        d = tree_size(tree)
        # uplink bits per T_dl of client i; the budget is the slowest
        # client transmitting the minimum spec — nobody is ever charged
        # more than the fixed qsgd:<min_bits> round would charge
        rate = np.asarray(link.dl_rate, np.float64) / np.asarray(
            link.ul_ratio, np.float64)
        budget = (d * self.min_bits + 32) / rate.min()
        bits = np.floor((budget * rate - 32.0) / d)
        bits = np.clip(bits, self.min_bits, self.max_bits).astype(np.int64)
        return BoundAdaptive(self.spec, bits)


class BoundAdaptive(Codec):
    """`Adaptive` specialized to one resolved link: a per-client qsgd bit
    vector.  NOT registered — only `Adaptive.bind_link` constructs it.
    Equality/hash fold in the bit vector: two runs over different link
    profiles must never share a compiled superstep or uplink jit."""

    name = "adaptive"

    def __init__(self, spec: str, bits: np.ndarray):
        self._spec = str(spec)
        self.bits = np.asarray(bits, np.int64)

    @property
    def spec(self) -> str:
        return self._spec

    def bind_link(self, link: Any, tree: Any) -> "Codec":
        return self                       # already bound — idempotent

    def payload_bits(self, tree: Any) -> int:
        """Scalar (downlink/broadcast) payload: the broadcast carries the
        server model re-encoded for the best subscriber, so charge the
        LARGEST assigned width — the per-client uplink truth lives in
        `per_client_bits`."""
        return tree_size(tree) * int(self.bits.max()) + 32

    def per_client_bits(self, tree: Any, m: int) -> np.ndarray:
        if m != self.bits.shape[0]:
            raise ValueError(f"bound for m={self.bits.shape[0]} clients, "
                             f"asked for {m}")
        return tree_size(tree) * self.bits + 32

    def roundtrip(self, flat, key, *, backend="pallas"):
        """ref.qsgd_roundtrip_ref with the scalar level count replaced by a
        per-row (m, 1) column — rows whose width equals b are bit-identical
        to ``qsgd:<b>`` on the jnp backend (same op sequence elementwise).
        Pure jnp on BOTH backends: the Pallas quantize kernel bakes a
        scalar level count into its body."""
        noise = jax.random.uniform(key, flat.shape, jnp.float32)
        s = jnp.asarray(2.0 ** (self.bits - 1) - 1.0,
                        jnp.float32)[:, None]               # (m, 1)
        amax = jnp.max(jnp.abs(flat), axis=1, keepdims=True)
        scale = amax * (1.0 / s)
        inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
        q = jnp.clip(jnp.floor(flat * inv + noise), -s, s)
        return q * scale

    def __eq__(self, other) -> bool:
        return (isinstance(other, BoundAdaptive)
                and self._spec == other._spec
                and self.bits.shape == other.bits.shape
                and bool(np.all(self.bits == other.bits)))

    def __hash__(self) -> int:
        return hash((self._spec, self.bits.tobytes()))

    def __repr__(self) -> str:
        return (f"BoundAdaptive({self._spec!r}, "
                f"bits=[{self.bits.min()}..{self.bits.max()}])")


@register_codec
class AdaptiveTopK(Codec):
    """Rate-adaptive top-k sparsification (DESIGN.md §3b): each client's
    kept-coordinate count is picked from its `LinkProfile` so that every
    upload fits the time budget of the slowest client sending the minimum
    fraction — the sparsity sibling of `Adaptive`'s bit-width headroom
    rule.  Biased like `topk`; run it with error feedback.

    Spec grammar: ``adaptive_topk`` (frac ∈ [0.05, 1]),
    ``adaptive_topk:<min_frac>`` to raise the floor, or
    ``adaptive_topk:<min_frac>:<max_frac>`` to also cap the ceiling.
    The instance the engines run is produced by `bind_link`; using an
    UNBOUND adaptive codec's value path is an error.  On a uniform
    profile every client lands exactly on the floor k, so the charge
    (and, on the threshold backend, the values) equal
    ``topk:<min_frac>``.
    """

    name = "adaptive_topk"

    def __init__(self, min_frac: float = 0.05, max_frac: float = 1.0):
        if not 0.0 < float(min_frac) <= float(max_frac) <= 1.0:
            raise ValueError("adaptive_topk fracs must satisfy 0 < min <= "
                             f"max <= 1, got [{min_frac}, {max_frac}]")
        self.min_frac = float(min_frac)
        self.max_frac = float(max_frac)

    @property
    def spec(self) -> str:
        if self.max_frac != 1.0:
            return f"{self.name}:{self.min_frac:g}:{self.max_frac:g}"
        if self.min_frac != 0.05:
            return f"{self.name}:{self.min_frac:g}"
        return self.name

    def payload_bits(self, tree: Any) -> int:
        raise RuntimeError(
            "adaptive_topk codec is link-dependent: the engines bind it "
            "via Channel(link_profile=...) -> init_channel; call "
            "bind_link(link, tree) first")

    def roundtrip(self, flat, key, *, backend="pallas"):
        raise RuntimeError("adaptive_topk codec is link-dependent; "
                           "bind_link(link, tree) first")

    def bind_link(self, link: Any, tree: Any) -> "Codec":
        d = tree_size(tree)
        k_of = lambda frac: max(1, min(d, int(math.ceil(frac * d))))
        k_min, k_max = k_of(self.min_frac), k_of(self.max_frac)
        # uplink bits per T_dl of client i; the budget is the slowest
        # client transmitting the minimum fraction — nobody is ever
        # charged more than the fixed topk:<min_frac> round would charge
        rate = np.asarray(link.dl_rate, np.float64) / np.asarray(
            link.ul_ratio, np.float64)
        budget = (k_min * 64) / rate.min()
        ks = np.floor(budget * rate / 64.0)
        ks = np.clip(ks, k_min, k_max).astype(np.int64)
        return BoundAdaptiveTopK(self.spec, ks)


class BoundAdaptiveTopK(Codec):
    """`AdaptiveTopK` specialized to one resolved link: a per-client
    kept-coordinate vector.  NOT registered — only
    `AdaptiveTopK.bind_link` constructs it.  Equality/hash fold in the k
    vector: runs over different link profiles never share a compiled
    superstep or uplink jit."""

    name = "adaptive_topk"

    def __init__(self, spec: str, ks: np.ndarray):
        self._spec = str(spec)
        self.ks = np.asarray(ks, np.int64)

    @property
    def spec(self) -> str:
        return self._spec

    def bind_link(self, link: Any, tree: Any) -> "Codec":
        return self                       # already bound — idempotent

    def payload_bits(self, tree: Any) -> int:
        """Scalar (downlink/broadcast) payload: charge the LARGEST
        assigned k — the per-client uplink truth is `per_client_bits`."""
        return int(self.ks.max()) * (32 + 32)

    def per_client_bits(self, tree: Any, m: int) -> np.ndarray:
        if m != self.ks.shape[0]:
            raise ValueError(f"bound for m={self.ks.shape[0]} clients, "
                             f"asked for {m}")
        return self.ks * (32 + 32)

    def roundtrip(self, flat, key, *, backend="pallas"):
        """Per-row k-th-magnitude threshold — `TopK`'s pallas-path
        semantics (ties at the threshold all kept) with the scalar k
        replaced by a per-row column.  Pure jnp on BOTH backends: the
        threshold kernel bakes a scalar k into its grid, and a sort is
        what a per-row k needs anyway.  Rows whose k equals ``topk``'s
        are value-identical to the threshold backend."""
        a = jnp.abs(flat)
        srt = jnp.sort(a, axis=1)[:, ::-1]            # descending
        rows = jnp.arange(flat.shape[0])
        thr = srt[rows, jnp.asarray(self.ks - 1)][:, None]
        return jnp.where(a >= thr, flat, 0.0)

    def __eq__(self, other) -> bool:
        return (isinstance(other, BoundAdaptiveTopK)
                and self._spec == other._spec
                and self.ks.shape == other.ks.shape
                and bool(np.all(self.ks == other.ks)))

    def __hash__(self) -> int:
        return hash((self._spec, self.ks.tobytes()))

    def __repr__(self) -> str:
        return (f"BoundAdaptiveTopK({self._spec!r}, "
                f"ks=[{self.ks.min()}..{self.ks.max()}])")


def get_codec(spec) -> Codec:
    """``"identity" | "qsgd:<bits>" | "topk:<frac>" | "adaptive[:<min>
    [:<max>]]" | "adaptive_topk[:<min>[:<max>]]"`` -> Codec instance
    (instances pass through).  Multi-parameter specs split on ``:``."""
    if isinstance(spec, Codec):
        return spec
    family, _, param = str(spec).partition(":")
    cls = CODECS.get(family)
    if cls is None:
        raise ValueError(f"unknown codec {spec!r}; families: "
                         f"{sorted(CODECS)}")
    if not param:
        return cls()
    conv = int if family in ("qsgd", "adaptive") else float
    try:
        args = [conv(p) for p in param.split(":")]
    except ValueError:
        raise ValueError(f"bad codec parameter in {spec!r}") from None
    try:
        return cls(*args)
    except TypeError:
        raise ValueError(f"too many parameters in {spec!r}") from None


# ---------------------------------------------------------------------------
# error-feedback uplink application (engine entry point)


def uplink_roundtrip(codec: Codec, stacked: Any, prev: Any, ef: Any,
                     key: jnp.ndarray, mask: Optional[jnp.ndarray], *,
                     backend: str = "pallas") -> Tuple[Any, Any]:
    """The EF uplink algebra as a PURE traced function: transmit v = Δ + e,
    return ``(prev + decode(v), v − decode(v))`` with non-participant rows
    untouched.  Used directly inside the superstep scan (DESIGN.md §3c);
    `apply_uplink` wraps it in the cached per-round jit for the eventful
    engines."""
    delta = jax.tree_util.tree_map(jnp.subtract, stacked, prev)
    v = jax.tree_util.tree_map(jnp.add, delta, ef)
    flat = stacked_ravel(v)
    dec_flat = codec.roundtrip(flat, key, backend=backend)
    dec = stacked_unravel(dec_flat, v)
    new_ef = jax.tree_util.tree_map(jnp.subtract, v, dec)
    # residuals ride in f32; the model stack keeps its own dtype
    new_stacked = jax.tree_util.tree_map(
        lambda p, d: (p + d).astype(p.dtype), prev, dec)
    if mask is not None:
        # non-participants transmitted nothing: model and residual
        # rows stay exactly as they were
        sel = lambda a, b: jax.tree_util.tree_map(
            lambda x, y: jnp.where(
                mask.reshape((-1,) + (1,) * (x.ndim - 1)), x, y), a, b)
        new_stacked = sel(new_stacked, stacked)
        new_ef = sel(new_ef, ef)
    return new_stacked, new_ef


@functools.lru_cache(maxsize=32)
def _uplink_fn(codec: Codec, backend: str, masked: bool):
    """jit(uplink_roundtrip) cached per (codec, backend, masked) — sweeps
    re-entering the engines with the same channel reuse the compiled step."""
    if masked:
        return jax.jit(lambda s, p, e, k, m: uplink_roundtrip(
            codec, s, p, e, k, m, backend=backend))
    return jax.jit(lambda s, p, e, k: uplink_roundtrip(
        codec, s, p, e, k, None, backend=backend))


def apply_uplink(codec: Codec, stacked: Any, prev: Any, ef: Any,
                 key: jnp.ndarray, mask: Optional[jnp.ndarray] = None, *,
                 backend: str = "pallas") -> Tuple[Any, Any]:
    """One uplink crossing with error feedback.

    ``stacked``/``prev`` are the post-/pre-update client stacks, ``ef`` the
    residual stack.  Transmits v = (stacked − prev) + ef per participating
    client, returns ``(prev + decode(v), v − decode(v))`` — the server-side
    models and the carried-forward residuals.  Rows where ``mask`` is False
    (non-participants / in-flight clients) are untouched.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown codec backend {backend!r}; one of "
                         f"{BACKENDS}")
    if codec.is_identity:
        return stacked, ef
    if mask is None:
        return _uplink_fn(codec, backend, False)(stacked, prev, ef, key)
    return _uplink_fn(codec, backend, True)(stacked, prev, ef, key, mask)


def zeros_like_stack(stacked: Any) -> Any:
    """Fresh all-zero error-feedback residual stack shaped like ``stacked``
    (f32 — residuals accumulate in full precision regardless of the model
    dtype)."""
    return jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, jnp.float32), stacked)
