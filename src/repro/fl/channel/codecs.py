"""Uplink compression codecs with error feedback (DESIGN.md §3b).

A `Codec` is one lossy (or identity) channel code for the client->server
update payload.  The simulation never materializes packed bitstreams: a
codec exposes

  * ``roundtrip(flat, key)``   — decode(encode(·)) on the (m, D) client-
    flat view: the values the SERVER sees.  Rows are independent clients.
  * ``payload_bits(tree)``     — exact wire bits for one client's payload
    of ``tree``'s size (per-element code bits + per-client side info).

Registered codecs (spec grammar ``<family>[:<param>]``, mirroring the
strategy registry §5):

  identity        lossless float passthrough (bit-parity anchor)
  qsgd:<bits>     signed stochastic uniform quantization, b ∈ [2, 8]
                  (QSGD, Alistarh et al. 2017): d·b bits + one 32-bit
                  per-client scale
  topk:<frac>     magnitude top-k sparsification, k = ⌈frac·d⌉:
                  k · (32-bit value + 32-bit index)

Error feedback (Seide et al. 2014 / EF-SGD): the engines keep a per-client
residual stack e_i; each round the codec transmits v = Δ + e and the new
residual is e' = v − decode(v), so *everything the channel drops is
retransmitted later* — `apply_uplink` below owns that algebra, jitted and
cached per (codec, backend, masking).  ``backend="pallas"`` executes the
`repro.kernels` quantize/top-k-threshold kernels (HostVmap); ``"jnp"`` is
the bit-identical-for-qsgd pure-jnp path the mesh placement shards under
GSPMD.
"""
from __future__ import annotations

import abc
import functools
import math
from typing import Any, ClassVar, Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp

from repro.fl.channel.payload import (stacked_ravel, stacked_unravel,
                                      tree_bits, tree_size)

BACKENDS = ("pallas", "jnp")


class Codec(abc.ABC):
    """One uplink channel code; subclass + `@register_codec` to add."""

    name: ClassVar[str]
    is_identity: ClassVar[bool] = False

    @property
    def spec(self) -> str:
        """Registry spec string that reconstructs this instance."""
        return self.name

    @abc.abstractmethod
    def payload_bits(self, tree: Any) -> int:
        """Exact uplink bits for ONE client's payload of ``tree``'s size."""

    @abc.abstractmethod
    def roundtrip(self, flat: jnp.ndarray, key: jnp.ndarray, *,
                  backend: str = "pallas") -> jnp.ndarray:
        """decode(encode(flat)) per row; (m, D) f32 -> (m, D) f32."""

    # codecs are value objects: spec identity drives the jit caches
    def __eq__(self, other) -> bool:
        return isinstance(other, Codec) and self.spec == other.spec

    def __hash__(self) -> int:
        return hash(self.spec)

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec!r})"


CODECS: Dict[str, Type[Codec]] = {}


def register_codec(cls: Type[Codec]) -> Type[Codec]:
    CODECS[cls.name] = cls
    return cls


@register_codec
class Identity(Codec):
    """Lossless passthrough: raw dtype bits, engines skip the value path
    entirely (the bit-parity anchor of DESIGN.md §3b)."""

    name = "identity"
    is_identity = True

    def payload_bits(self, tree: Any) -> int:
        return tree_bits(tree)

    def roundtrip(self, flat, key, *, backend="pallas"):
        return flat


@register_codec
class QSGD(Codec):
    """Stochastic uniform quantization onto ``{-s..s}·scale`` per client,
    s = 2^(b−1) − 1, scale = max|x|/s.  Unbiased given the scale:
    E[roundtrip(x)] = x (stochastic rounding ``floor(y + u)``)."""

    name = "qsgd"

    def __init__(self, bits: int = 8):
        if not 2 <= int(bits) <= 8:
            raise ValueError(f"qsgd bits must be in [2, 8], got {bits}")
        self.bits = int(bits)

    @property
    def spec(self) -> str:
        return f"{self.name}:{self.bits}"

    def payload_bits(self, tree: Any) -> int:
        return tree_size(tree) * self.bits + 32     # + per-client scale

    def roundtrip(self, flat, key, *, backend="pallas"):
        noise = jax.random.uniform(key, flat.shape, jnp.float32)
        if backend == "pallas":
            from repro.kernels import ops
            return ops.qsgd_roundtrip(flat, noise, bits=self.bits)
        from repro.kernels import ref
        return ref.qsgd_roundtrip_ref(flat, noise, self.bits)


@register_codec
class TopK(Codec):
    """Magnitude top-k sparsification: keep each client's k = ⌈frac·d⌉
    largest-|x| coordinates exactly, zero the rest.  Biased — error
    feedback is what makes it converge (the residual carries the tail)."""

    name = "topk"

    def __init__(self, frac: float = 0.1):
        if not 0.0 < float(frac) <= 1.0:
            raise ValueError(f"topk frac must be in (0, 1], got {frac}")
        self.frac = float(frac)

    @property
    def spec(self) -> str:
        return f"{self.name}:{self.frac:g}"

    def k(self, d: int) -> int:
        return max(1, min(d, int(math.ceil(self.frac * d))))

    def payload_bits(self, tree: Any) -> int:
        return self.k(tree_size(tree)) * (32 + 32)  # (value, index) pairs

    def roundtrip(self, flat, key, *, backend="pallas"):
        k = self.k(flat.shape[1])
        if backend == "pallas":
            from repro.kernels import ops
            thresh = ops.topk_threshold(jnp.abs(flat), k=k)
            return jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
        from repro.kernels import ref
        return jnp.where(ref.topk_mask_ref(flat, k), flat, 0.0)


def get_codec(spec) -> Codec:
    """``"identity" | "qsgd:<bits>" | "topk:<frac>"`` -> Codec instance
    (instances pass through)."""
    if isinstance(spec, Codec):
        return spec
    family, _, param = str(spec).partition(":")
    cls = CODECS.get(family)
    if cls is None:
        raise ValueError(f"unknown codec {spec!r}; families: "
                         f"{sorted(CODECS)}")
    if not param:
        return cls()
    try:
        arg = int(param) if family == "qsgd" else float(param)
    except ValueError:
        raise ValueError(f"bad codec parameter in {spec!r}") from None
    return cls(arg)


# ---------------------------------------------------------------------------
# error-feedback uplink application (engine entry point)


def uplink_roundtrip(codec: Codec, stacked: Any, prev: Any, ef: Any,
                     key: jnp.ndarray, mask: Optional[jnp.ndarray], *,
                     backend: str = "pallas") -> Tuple[Any, Any]:
    """The EF uplink algebra as a PURE traced function: transmit v = Δ + e,
    return ``(prev + decode(v), v − decode(v))`` with non-participant rows
    untouched.  Used directly inside the superstep scan (DESIGN.md §3c);
    `apply_uplink` wraps it in the cached per-round jit for the eventful
    engines."""
    delta = jax.tree_util.tree_map(jnp.subtract, stacked, prev)
    v = jax.tree_util.tree_map(jnp.add, delta, ef)
    flat = stacked_ravel(v)
    dec_flat = codec.roundtrip(flat, key, backend=backend)
    dec = stacked_unravel(dec_flat, v)
    new_ef = jax.tree_util.tree_map(jnp.subtract, v, dec)
    # residuals ride in f32; the model stack keeps its own dtype
    new_stacked = jax.tree_util.tree_map(
        lambda p, d: (p + d).astype(p.dtype), prev, dec)
    if mask is not None:
        # non-participants transmitted nothing: model and residual
        # rows stay exactly as they were
        sel = lambda a, b: jax.tree_util.tree_map(
            lambda x, y: jnp.where(
                mask.reshape((-1,) + (1,) * (x.ndim - 1)), x, y), a, b)
        new_stacked = sel(new_stacked, stacked)
        new_ef = sel(new_ef, ef)
    return new_stacked, new_ef


@functools.lru_cache(maxsize=32)
def _uplink_fn(codec: Codec, backend: str, masked: bool):
    """jit(uplink_roundtrip) cached per (codec, backend, masked) — sweeps
    re-entering the engines with the same channel reuse the compiled step."""
    if masked:
        return jax.jit(lambda s, p, e, k, m: uplink_roundtrip(
            codec, s, p, e, k, m, backend=backend))
    return jax.jit(lambda s, p, e, k: uplink_roundtrip(
        codec, s, p, e, k, None, backend=backend))


def apply_uplink(codec: Codec, stacked: Any, prev: Any, ef: Any,
                 key: jnp.ndarray, mask: Optional[jnp.ndarray] = None, *,
                 backend: str = "pallas") -> Tuple[Any, Any]:
    """One uplink crossing with error feedback.

    ``stacked``/``prev`` are the post-/pre-update client stacks, ``ef`` the
    residual stack.  Transmits v = (stacked − prev) + ef per participating
    client, returns ``(prev + decode(v), v − decode(v))`` — the server-side
    models and the carried-forward residuals.  Rows where ``mask`` is False
    (non-participants / in-flight clients) are untouched.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown codec backend {backend!r}; one of "
                         f"{BACKENDS}")
    if codec.is_identity:
        return stacked, ef
    if mask is None:
        return _uplink_fn(codec, backend, False)(stacked, prev, ef, key)
    return _uplink_fn(codec, backend, True)(stacked, prev, ef, key, mask)


def zeros_like_stack(stacked: Any) -> Any:
    """Fresh all-zero error-feedback residual stack shaped like ``stacked``
    (f32 — residuals accumulate in full precision regardless of the model
    dtype)."""
    return jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, jnp.float32), stacked)
