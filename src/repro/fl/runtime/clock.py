"""Virtual clock for the async runtime (DESIGN.md §3a).

Event-driven simulated wall-clock over per-client upload arrivals.  Each
`schedule(client, start)` draws one client round-trip from the
`SystemModel`'s shifted-exponential compute law (`t_min + Exp(1/μ) + ρ`,
units of T_dl — the law whose max-order-statistic gives the synchronous
engine's analytic `E[max] = t_min + H_m/μ`) and pushes the arrival onto a
heap; `pop()` returns the earliest pending arrival and advances `now`.

The parameter-server downlink is a serialized resource, mirroring the
synchronous model where every round pays its broadcast streams in full:
`serve(duration)` occupies the downlink and returns the completion time,
queueing behind any broadcast still in flight.

Determinism: draws come from a private `numpy` Generator (the engine's JAX
key stream is never touched, preserving sync↔async bit-equivalence), and
heap ties break on client index — with `inv_mu=0` every draw is exactly
`t_min + ρ`, so arrivals pop in lockstep client order.
"""
from __future__ import annotations

import heapq
from typing import Tuple

import numpy as np

from repro.fl.comm import SystemModel


class VirtualClock:
    """Per-client arrival heap + serialized server downlink."""

    def __init__(self, system: SystemModel, seed: int = 0):
        self.system = system
        self._rng = np.random.default_rng(seed)
        self._heap = []
        self.now = 0.0              # time of the latest popped arrival
        self._busy_until = 0.0      # downlink occupied through this time

    def schedule(self, client: int, start: float) -> float:
        """Client downloads at ``start``; returns its sampled arrival time."""
        t = start + self.system.sample_client_time(self._rng)
        heapq.heappush(self._heap, (t, int(client)))
        return t

    def pop(self) -> Tuple[float, int]:
        """(arrival_time, client) of the earliest pending upload."""
        t, c = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        return t, c

    def serve(self, duration: float) -> float:
        """Occupy the server downlink for ``duration`` starting no earlier
        than ``now``; returns the broadcast completion time."""
        done = max(self.now, self._busy_until) + duration
        self._busy_until = done
        return done

    def __len__(self) -> int:
        return len(self._heap)
