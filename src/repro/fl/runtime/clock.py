"""Virtual clock for the async runtime (DESIGN.md §3a, §3b).

Event-driven simulated wall-clock over per-client upload arrivals.  Each
`schedule(client, start)` draws one client round-trip from the
`SystemModel`'s shifted-exponential compute law (`t_min + Exp(1/μ)`, units
of T_dl — the law whose max-order-statistic gives the synchronous
engine's analytic `E[max] = t_min + H_m/μ`) plus the client's uplink, and
pushes the arrival onto a heap; `pop()` returns the earliest pending
arrival and advances `now`.

The uplink term is ρ by default (the homogeneous paper model).  With a
channel attached (`link=` a `LinkProfile` and ``ul_bits`` per schedule
call) it becomes the client's own ``payload_bits / uplink_rate`` — the
per-client heterogeneous profile of DESIGN.md §3b.  A uniform
`LinkProfile.from_system` profile carrying the uncompressed model
reproduces ρ exactly, so the channel-less clock is a special case
bit-for-bit.

The parameter-server downlink is a serialized resource, mirroring the
synchronous model where every round pays its broadcast streams in full:
`serve(duration)` occupies the downlink and returns the completion time,
queueing behind any broadcast still in flight.  ``overlap=True`` is the
async-aware charging fix (ROADMAP follow-on): an event's streams start at
the event time on their own carriers and run CONCURRENTLY with any
broadcast still in flight from an earlier event — completion is
``now + duration``, not ``busy + duration``.  In lockstep operation every
client re-downloads before the next event, the downlink is always idle,
and the fix is exactly a no-op (the sync-equivalence anchor is preserved;
regression-tested).

Determinism: draws come from a private `numpy` Generator (the engine's JAX
key stream is never touched, preserving sync↔async bit-equivalence), one
exponential per `schedule` call regardless of the channel configuration —
attaching a link profile never shifts the draw sequence.  Heap ties break
on client index: with `inv_mu=0` every draw is exactly `t_min + ρ`, so
arrivals pop in lockstep client order.
"""
from __future__ import annotations

import heapq
from typing import Optional, Tuple

import numpy as np

from repro.fl.comm import SystemModel


class VirtualClock:
    """Per-client arrival heap + serialized server downlink."""

    def __init__(self, system: SystemModel, seed: int = 0, *, link=None):
        self.system = system
        self.link = link                # Optional[LinkProfile] (§3b)
        self._rng = np.random.default_rng(seed)
        self._heap = []
        self.now = 0.0              # time of the latest popped arrival
        self._busy_until = 0.0      # downlink occupied through this time

    def schedule(self, client: int, start: float,
                 ul_bits: Optional[float] = None,
                 extra: float = 0.0) -> float:
        """Client downloads at ``start``; returns its sampled arrival time.

        ``ul_bits`` (with a ``link`` profile) charges the client's own
        uplink ``bits·ρ_i/rate_i`` instead of the homogeneous ρ.
        ``extra`` adds a deterministic per-client term BEFORE the compute
        draw — the hierarchy tier's edge sub-round time (DESIGN.md §3f);
        the default 0.0 is bit-exact (``start + 0.0 == start``), so the
        flat clock is unchanged and the draw sequence never shifts."""
        compute = self.system.sample_compute_time(self._rng)
        if self.link is not None and ul_bits is not None:
            uplink = self.link.uplink_time(client, ul_bits)
        else:
            uplink = self.system.rho
        t = start + extra + compute + uplink
        heapq.heappush(self._heap, (t, int(client)))
        return t

    def requeue(self, client: int, at: float) -> float:
        """Re-push an already-drawn arrival at ``at`` — NO new compute
        draw.  The async retry path (DESIGN.md §3g): a crashed arrival is
        rescheduled with deterministic backoff without shifting the
        clock's draw sequence, so faults-off runs and the engines' JAX key
        schedule stay bit-identical."""
        heapq.heappush(self._heap, (float(at), int(client)))
        return float(at)

    def pop(self) -> Tuple[float, int]:
        """(arrival_time, client) of the earliest pending upload."""
        t, c = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        return t, c

    def serve(self, duration: float, *, overlap: bool = False) -> float:
        """Occupy the server downlink for ``duration`` starting no earlier
        than ``now``; returns the broadcast completion time.  With
        ``overlap=True`` a transmission still in flight from an earlier
        event does NOT delay this one (concurrent carriers; see module
        docstring) — a no-op whenever the downlink is idle."""
        if overlap:
            done = self.now + duration
            self._busy_until = max(self._busy_until, done)
            return done
        done = max(self.now, self._busy_until) + duration
        self._busy_until = done
        return done

    def __len__(self) -> int:
        return len(self._heap)
