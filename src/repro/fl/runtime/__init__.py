"""Event-driven async federated runtime (DESIGN.md §3a).

`run_async` runs buffered staleness-aware aggregation events over a
`VirtualClock` instead of bulk-synchronous rounds; `AsyncConfig` holds the
buffer/staleness knobs.  `run_federated(..., async_cfg=AsyncConfig(...))`
delegates here, so the sync and async engines share one call surface.
"""
from repro.fl.runtime.clock import VirtualClock
from repro.fl.runtime.engine import AsyncConfig, run_async

__all__ = ["AsyncConfig", "VirtualClock", "run_async"]
