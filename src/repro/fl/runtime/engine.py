"""Buffered-asynchronous federated round engine (DESIGN.md §3a).

The synchronous engine (`repro.fl.simulator.run_federated`) makes every
round wait for the slowest of m shifted-exponential stragglers.  This
runtime replaces that barrier with an event-driven loop over a
`VirtualClock`: every client trains continuously and uploads when its
sampled compute finishes; the server buffers arrivals and fires one
aggregation EVENT whenever `AsyncConfig.buffer_k` updates are queued
(FedBuff-style).  At each event

  * buffered updates older than ``max_staleness`` server versions are
    dropped (their clients still re-download and restart);
  * the strategy's aggregation runs unmodified — ``ctx.participation``
    masks the fresh cohort and ``ctx.staleness`` carries every
    contributor's model age, which `ctx.mix`/`ctx.mix_plan` route through
    `Strategy.reweight` (default: mass-preserving ``λ**age`` column
    discount);
  * only the buffered clients download the new mix — in-flight clients
    keep training on the model they last pulled — so the event is charged
    (and `History.comm` records) only the cohort's downlink: at most K
    broadcast streams plus the cohort's share of per-client unicasts;
  * `History.time` records the event-driven virtual clock (arrival of the
    K-th update + serialized downlink), replacing the analytic max.

Equivalence anchor (tested): with ``inv_mu=0``, ``buffer_k=m`` and
unbounded staleness every event is a lockstep full-participation round —
the same key schedule, update step and aggregation path as the sync
engine, bit-for-bit on `HostVmap`.

Both placements work: `HostVmap` masks cohorts via `placement.select`;
`MeshShardMap` reuses the schedule-selected `mix_schedule` collectives.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedData
from repro.fl.channel import (Channel, ChannelCost, resolve_channel,
                              round_downlink_time)
from repro.fl.comm import SYSTEMS, SystemModel
from repro.fl.faults import (FaultMeter, get_robust_aggregator,
                             inject_values, pop_with_retries,
                             screen_and_defend)
from repro.fl.placement import Placement, resolve_placement
from repro.fl.runtime.clock import VirtualClock
from repro.fl.simulator import (FLConfig, History, channel_extra,
                                channel_uplink, finalize_history,
                                init_channel, init_run,
                                per_client_uplink_bits, record_eval,
                                resolve_strategy)
from repro.fl.strategies import CommCost, Strategy
from repro.models import lenet


@dataclass(frozen=True)
class AsyncConfig:
    """Knobs of the buffered-asynchronous server (DESIGN.md §3a).

    buffer_k:           aggregation fires when this many client uploads are
                        buffered (clamped to m; K=m with a reliable system
                        degenerates to the synchronous engine).
    max_staleness:      drop buffered updates whose base model is older than
                        this many server versions (None = keep everything).
    staleness_schedule: contributor-discount law routed through
                        `Strategy.reweight`: ``"exp"`` (FedBuff-style
                        ``λ**age``) or ``"poly"`` (FedAsync's
                        ``(1+age)**-α``, Xie et al. 2019).
    staleness_discount: λ of the ``exp`` schedule (1.0 = no discounting).
    staleness_alpha:    α of the ``poly`` schedule.
    max_retries:        with a crash fault model (DESIGN.md §3g): a client
                        whose upload crashes this many CONSECUTIVE times
                        is dead for the run (0 = first crash kills).
    retry_backoff:      base of the crashed-arrival reschedule delay,
                        ``backoff · 2**attempt`` (deterministic
                        exponential backoff; no new compute draw).
    """
    buffer_k: int = 2
    max_staleness: Optional[float] = None
    staleness_schedule: str = "exp"
    staleness_discount: float = 0.9
    staleness_alpha: float = 0.5
    max_retries: int = 3
    retry_backoff: float = 1.0

    def __post_init__(self):
        if self.buffer_k < 1:
            raise ValueError(f"buffer_k must be >= 1, got {self.buffer_k}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got "
                             f"{self.max_retries}")
        if self.retry_backoff <= 0.0:
            raise ValueError(f"retry_backoff must be > 0, got "
                             f"{self.retry_backoff}")
        if self.staleness_schedule not in ("exp", "poly"):
            raise ValueError("staleness_schedule must be 'exp' or 'poly', "
                             f"got {self.staleness_schedule!r}")
        if not 0.0 < self.staleness_discount <= 1.0:
            raise ValueError("staleness_discount must be in (0, 1], got "
                             f"{self.staleness_discount}")
        if self.staleness_alpha < 0.0:
            raise ValueError("staleness_alpha must be >= 0, got "
                             f"{self.staleness_alpha}")
        if self.max_staleness is not None and self.max_staleness < 0:
            raise ValueError("max_staleness must be >= 0 or None, got "
                             f"{self.max_staleness}")


def run_async(algorithm: Union[str, Strategy, None] = None,
              fed: Optional[FederatedData] = None, *,
              strategy: Optional[Strategy] = None,
              async_cfg: Optional[AsyncConfig] = None,
              fl: Optional[FLConfig] = None,
              model_init: Optional[Callable] = None,
              loss_fn: Callable = lenet.loss_fn,
              acc_fn: Callable = lenet.accuracy,
              system: Optional[SystemModel] = None,
              placement: Optional[Placement] = None,
              channel: Union[str, Channel, None] = None,
              keep_state: bool = False,
              paging: Optional[Any] = None,
              hierarchy: Optional[Any] = None,
              faults: Optional[Any] = None,
              robust_agg: Optional[str] = None,
              min_quorum: Optional[int] = None,
              seed: int = 0) -> History:
    """Run `fl.rounds` buffered-async aggregation events; returns History.

    Same surface as `run_federated` (which delegates here when passed
    ``async_cfg=``), minus ``sampler`` — the arrival buffer IS the per-event
    cohort.  ``system`` drives the virtual clock (default: the reliable
    ``wired`` model, i.e. deterministic lockstep arrivals); ``channel``
    (DESIGN.md §3b) adds uplink compression, bit accounting and per-client
    link timing on top of it.  ``paging`` (a `PagingConfig`) switches to
    the store-backed event loop (DESIGN.md §3e): only each event's
    arrival buffer is device-resident.  ``hierarchy`` (DESIGN.md §3f)
    nests an edge sub-round inside every client upload: device uploads
    buffer at the user's edge, the user's pseudo-update is what arrives
    at the server, and each arrival's clock draw carries the user's edge
    sub-round time as a deterministic ``extra`` term.
    """
    if paging is not None:
        if hierarchy is not None:
            raise TypeError("the hierarchy tier does not compose with the "
                            "cohort paging engine yet (the store pages "
                            "flat client rows, not device fleets)")
        from repro.fl.population import run_async_paged
        return run_async_paged(algorithm, fed, paging=paging,
                               strategy=strategy, async_cfg=async_cfg,
                               fl=fl, model_init=model_init,
                               loss_fn=loss_fn, acc_fn=acc_fn,
                               system=system, placement=placement,
                               channel=channel, keep_state=keep_state,
                               faults=faults, robust_agg=robust_agg,
                               min_quorum=min_quorum, seed=seed)
    strategy = resolve_strategy(algorithm, strategy)
    if fed is None:
        raise TypeError("`fed` is required")
    cfg = AsyncConfig() if async_cfg is None else async_cfg
    fl = FLConfig() if fl is None else fl
    system = SYSTEMS["wired"] if system is None else system
    placement = resolve_placement(placement)
    channel = resolve_channel(channel)
    codec = channel.codec if channel is not None else None
    lossy = codec is not None and not codec.is_identity

    m = fed.m
    k_buf = min(cfg.buffer_k, m)
    tau = np.inf if cfg.max_staleness is None else float(cfg.max_staleness)

    if hierarchy is not None:
        from repro.fl.hierarchy import resolve_hierarchy
        hierarchy = resolve_hierarchy(hierarchy)

    # identical init path to the sync engine (bit-equivalence anchor); no
    # donation — every event rolls in-flight clients back against `prev`
    key, vmapped_update, stacked, opt_state, (x, y, n), ctx, state = \
        init_run(strategy, fed, fl, model_init, loss_fn, acc_fn,
                 placement, seed, hierarchy=hierarchy, system=system,
                 faults=faults)
    plan = ctx.fault_plan
    defense = get_robust_aggregator(robust_agg)
    robust_spec = "none" if defense is None else str(robust_agg)
    byz_row = None if plan is None else jnp.asarray(plan.byz_row())
    fmeter = None
    if plan is not None or defense is not None or min_quorum is not None:
        fmeter = FaultMeter(plan, robust_spec, min_quorum)
    attempts: dict = {}         # per-client consecutive-crash counter
    meter = None
    if hierarchy is not None:
        from repro.fl.hierarchy import EdgeMeter
        meter = EdgeMeter(ctx.hierarchy_plan)
    ctx.staleness_discount = cfg.staleness_discount
    ctx.staleness_schedule = cfg.staleness_schedule
    ctx.staleness_alpha = cfg.staleness_alpha

    payload, link, model_bits, ef, channel = init_channel(
        channel, ctx, stacked, system, m)
    ul_bits_pc = per_client_uplink_bits(channel, ctx, payload, m)

    def _ul_bits(c: int):
        return payload if ul_bits_pc is None else int(ul_bits_pc[c])

    # clock draws come from a private numpy stream — the JAX key schedule
    # below stays exactly the sync engine's; the link profile (if any)
    # swaps the homogeneous ρ uplink for each client's own payload/rate
    clock = VirtualClock(system, seed=seed, link=link)

    def _edge_time(c: int) -> float:
        # the device fleet's sub-round runs before the user's own compute
        # begins — a deterministic add to the arrival draw (§3f); 0.0
        # without a hierarchy, which is bit-exact in the clock
        return meter.time_of(c) if meter is not None else 0.0

    for i in range(m):
        clock.schedule(i, 0.0, ul_bits=_ul_bits(i), extra=_edge_time(i))
    # server version at each client's last model download; a model/update's
    # age at event e is  e - version[i]
    version = np.zeros(m, dtype=np.int64)

    history = History()
    t_done = 0.0

    for event in range(fl.rounds):
        # with a crash fault model, arrivals survive a crash coin: crashed
        # ones requeue with exponential backoff (no new compute draw —
        # the clock stream never shifts), capped retries kill the client
        buffered = []
        while len(buffered) < k_buf:
            nxt = pop_with_retries(clock, plan, cfg.max_retries,
                                   cfg.retry_backoff, attempts, fmeter)
            if nxt is None:
                break
            buffered.append(nxt[1])
        if not buffered:
            warnings.warn(
                f"async run ended early at event {event}/{fl.rounds}: "
                "every remaining client exhausted its crash retries "
                f"(dead: {sorted(fmeter.dead) if fmeter else []})",
                RuntimeWarning, stacklevel=2)
            break
        age = event - version                       # (m,) contributor ages
        fresh_np = np.zeros(m, dtype=bool)
        fresh_np[[c for c in buffered if age[c] <= tau]] = True
        all_fresh = bool(fresh_np.all())

        key, kround = jax.random.split(key)
        ckeys = placement.place_keys(jax.random.split(kround, m))
        prev, prev_opt = stacked, opt_state
        if all_fresh:
            # lockstep event (K=m, nothing stale): the sync engine's step
            mask = None
            stacked, opt_state = vmapped_update(stacked, opt_state,
                                                x, y, n, ckeys)
        else:
            # only the fresh cohort's local work lands; in-flight clients
            # and stale-dropped updates stay at their server-known models
            mask = jnp.asarray(fresh_np)
            if meter is not None and not meter.plan.row_local:
                # the fleet step bakes a static per-USER straggler mask
                # (§3f): row gathers would misalign it, so partial events
                # take the base full-width path (run-every-row + select)
                stacked, opt_state = Placement.update_cohort(
                    placement, vmapped_update, jnp.asarray(buffered),
                    jnp.asarray(fresh_np[buffered]), stacked, opt_state,
                    x, y, n, ckeys)
            else:
                stacked, opt_state = placement.update_cohort(
                    vmapped_update, jnp.asarray(buffered),
                    jnp.asarray(fresh_np[buffered]), stacked, opt_state,
                    x, y, n, ckeys)

        if plan is not None and plan.value_faults:
            # fault injection (DESIGN.md §3g): the fresh cohort's
            # TRANSMITTED updates are corrupted (arrival crashes were
            # already decided at the clock, via `pop_with_retries`)
            stacked = inject_values(plan, byz_row, stacked, prev,
                                    jax.random.fold_in(kround, 3),
                                    rows=mask)

        if lossy:
            # uplink channel crossing (DESIGN.md §3b): the fresh cohort's
            # updates reach the server through the codec; in-flight /
            # stale-dropped rows (mask False) transmit nothing and keep
            # their error-feedback residuals
            stacked, ef = channel_uplink(placement, channel, stacked, prev,
                                         ef, kround, mask)

        q = None
        if defense is not None:
            # screening + robust aggregation (DESIGN.md §3g) before mixing
            stacked, q = screen_and_defend(defense, stacked, prev)

        n_fresh = int(fresh_np.sum())
        quorum_ok = min_quorum is None or n_fresh >= min_quorum
        if quorum_ok:
            ctx.rnd, ctx.key, ctx.participation = \
                event, jax.random.fold_in(kround, 1), mask
            ctx.staleness = (jnp.asarray(age, jnp.float32)
                             if age.any() else None)
            ctx.quarantine = q
            mixed, state = strategy.aggregate(state, stacked, prev, ctx)
            ctx.quarantine = None

            # the buffered clients (fresh AND stale-dropped) pull the new
            # mix and restart; everyone else is mid-flight, keeps its model
            down_np = np.zeros(m, dtype=bool)
            down_np[buffered] = True
            if down_np.all():
                stacked = mixed
            else:
                stacked = placement.select(jnp.asarray(down_np), mixed,
                                           stacked)
        else:
            # below quorum: the event is undone — no mix, no downlink, no
            # version bump; the buffered clients restart from their last
            # downloaded models and their uploads are wasted (the EF
            # residuals keep the uplink they actually transmitted)
            stacked, opt_state = prev, prev_opt

        # event-level downlink: only the buffered cohort downloads, so the
        # server transmits at most k_buf distinct broadcast streams and the
        # cohort's share of any per-client unicasts (the strategy reports
        # full-cohort costs; K=m recovers them exactly — lockstep anchor)
        ul_total = (sum(_ul_bits(c) for c in buffered)
                    if channel is not None else 0)
        if quorum_ok:
            cost = strategy.comm(state)
            cost = CommCost(min(cost.n_streams, len(buffered)),
                            int(round(cost.n_unicasts * len(buffered) / m)))
        else:
            cost = CommCost(0, 0)       # no mix moved: no downlink at all
        history.comm.append(cost)
        if channel is not None:
            # every buffered client uploaded one payload (stale-dropped
            # uploads still crossed the channel); the cohort downloads the
            # codec-compressed model per stream (§3b)
            history.comm_bits.append(ChannelCost(
                dl_bits=(cost.n_streams + cost.n_unicasts) * payload,
                ul_bits=ul_total))
        if meter is not None:
            # the device→user hop's bits for this event's arrivals (their
            # edge TIME is already inside each arrival's clock draw)
            meter.charge_event(buffered)
        if quorum_ok:
            if link is not None:
                # same charging rule as the sync clock (slowest buffered
                # subscriber per broadcast, receiver-mean per unicast;
                # membership-aware when the strategy exposes its stream map)
                duration = round_downlink_time(link, cost, payload, buffered,
                                               strategy.membership(state))
            else:
                duration = cost.n_streams + cost.n_unicasts
            # overlap=True: this event's streams run concurrently with any
            # broadcast still in flight from an earlier event (the
            # async-aware downlink charging fix) — an exact no-op in
            # lockstep, where the downlink is always idle by the next event
            done = clock.serve(duration, overlap=True)
        else:
            done = clock.now            # nothing served; time still passed
        # the reported clock stays monotone even if a later event's shorter
        # broadcast completes before an earlier long one
        t_done = max(t_done, done)
        for c in buffered:
            clock.schedule(c, done, ul_bits=_ul_bits(c),
                           extra=_edge_time(c))
            if quorum_ok:
                version[c] = event + 1
        if fmeter is not None:
            qrow = None if q is None else np.asarray(q)
            qbits = 0
            if channel is not None and qrow is not None and quorum_ok:
                qbits = int(np.sum(qrow <= 0)) * payload
            fmeter.charge(None, qrow, quorum_ok,
                          ul_total if channel is not None else 0, qbits)

        if event % fl.eval_every == 0 or event == fl.rounds - 1:
            mean_acc, worst_acc = placement.evaluate(acc_fn, stacked, fed)
            record_eval(history, event, mean_acc, worst_acc, t_done)

    history = finalize_history(history, strategy, state, keep_state,
                               stacked, opt_state)
    history.extra["async"] = {"buffer_k": k_buf,
                              "max_staleness": cfg.max_staleness,
                              "staleness_schedule": cfg.staleness_schedule,
                              "staleness_discount": cfg.staleness_discount,
                              "staleness_alpha": cfg.staleness_alpha,
                              "max_retries": cfg.max_retries,
                              "retry_backoff": cfg.retry_backoff,
                              "events": fl.rounds}
    if meter is not None:
        history.extra["hierarchy"] = meter.extra()
    if fmeter is not None:
        history.extra["faults"] = fmeter.extra()
    if channel is not None:
        channel_extra(history, channel, link, model_bits, payload)
    return history
