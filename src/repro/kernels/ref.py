"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def mixing_aggregate_ref(w: jnp.ndarray, theta: jnp.ndarray) -> jnp.ndarray:
    """PS-side user-centric aggregation: (k,m) x (m,D) -> (k,D), fp32 accum."""
    out = jnp.dot(w.astype(jnp.float32), theta.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return out.astype(theta.dtype)


def pairwise_sqdist_ref(g: jnp.ndarray) -> jnp.ndarray:
    """Δ_ij = ||g_i − g_j||², (m,D) -> (m,m) float32."""
    gf = g.astype(jnp.float32)
    sq = jnp.sum(gf * gf, axis=1)
    gram = gf @ gf.T
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)


def qsgd_roundtrip_ref(x: jnp.ndarray, noise: jnp.ndarray,
                       bits: int) -> jnp.ndarray:
    """QSGD quantize→dequantize on (m, D) rows: per-row scale max|x|/s with
    s = 2^(b-1) − 1, stochastic rounding ``floor(y + u)`` (unbiased given
    ``noise ~ U[0,1)``).  The mesh placement's GSPMD-friendly codec path
    (DESIGN.md §3b) runs exactly this math."""
    levels = float(2 ** (bits - 1) - 1)
    # reciprocal multiply, matching the kernel's formulation bit-for-bit
    # (XLA lowers in-kernel division by a constant to exactly this)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) * (1.0 / levels)
    inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.floor(x * inv + noise), -levels, levels)
    return q * scale


def topk_mask_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Exact per-row top-k-|x| survivor mask via `jax.lax.top_k`: (m, D)
    bool, ties resolved by first-index (may keep slightly fewer than the
    threshold kernel, which keeps all tied coordinates)."""
    k = min(int(k), x.shape[1])
    absx = jnp.abs(x)
    kth = jax.lax.top_k(absx, k)[0][:, -1:]
    return absx >= kth


def topk_threshold_ref(absx: jnp.ndarray, k: int) -> jnp.ndarray:
    """Exact k-th largest magnitude per row: (m, 1) (0 when k >= D)."""
    if k >= absx.shape[1]:
        return jnp.zeros((absx.shape[0], 1), absx.dtype)
    return jax.lax.top_k(absx, int(k))[0][:, -1:]


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None) -> jnp.ndarray:
    """Reference SDPA.  q: (B,H,Sq,hd); k,v: (B,Kh,Sk,hd); GQA G=H/Kh."""
    B, H, Sq, hd = q.shape
    Kh, Sk = k.shape[1], k.shape[2]
    G = H // Kh
    qg = q.reshape(B, Kh, G, Sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("bkgqh,bksh->bkgqs", qg, kf) / math.sqrt(hd)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(Sq)[:, None] + (Sk - Sq)   # aligned to sequence end
    k_pos = jnp.arange(Sk)[None, :]
    valid = jnp.ones((Sq, Sk), bool)
    if causal:
        valid &= k_pos <= q_pos
    if window is not None:
        valid &= k_pos > q_pos - window
    logits = jnp.where(valid[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, hd).astype(q.dtype)
