"""Pallas TPU flash attention (causal / windowed / softcapped, GQA).

Online-softmax formulation: grid (B, H, nQ, nK) with the KV dimension as
the innermost (sequential) grid axis; running max / denominator live in
VMEM scratch and the output block is revisited across KV steps.  Block
shapes are MXU-aligned: (QBLK, head_dim) x (head_dim, KBLK) contractions
with QBLK = KBLK = 128 by default.  GQA is expressed through the K/V
BlockSpec index map (query head h reads kv head h // group_size), so no
materialized K/V broadcast.

Used for the prefill/training hot spot; gemma2's logit softcap and
local-attention layers map to `softcap` / `window`.  Validated against
ref.flash_attention_ref in interpret mode (tests/test_kernels.py sweeps
shapes, dtypes, GQA ratios, windows and caps).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], seq_q: int, seq_k: int,
            qblk: int, kblk: int):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (qblk, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (kblk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    # absolute positions (q aligned to the END of the kv sequence)
    q_pos = iq * qblk + jax.lax.broadcasted_iota(jnp.int32, (qblk, kblk), 0) \
        + (seq_k - seq_q)
    k_pos = ik * kblk + jax.lax.broadcasted_iota(jnp.int32, (qblk, kblk), 1)
    valid = k_pos < seq_k                                  # exclude k padding
    if causal:
        valid &= k_pos <= q_pos
    if window is not None:
        valid &= k_pos > q_pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                                    # (qblk, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (keep m sane)
    m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)
    p = jnp.exp(s - m_safe)
    p = jnp.where(valid, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - m_safe))

    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "qblk", "kblk",
                     "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, qblk: int = 128,
                    kblk: int = 128, interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, Sq, hd); k, v: (B, Kh, Sk, hd); H % Kh == 0 -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    Kh, Sk = k.shape[1], k.shape[2]
    group = H // Kh
    scale = 1.0 / math.sqrt(hd)

    qblk = min(qblk, Sq)
    kblk = min(kblk, Sk)
    pad_q = (-Sq) % qblk
    pad_k = (-Sk) % kblk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    # padded k slots sit at positions >= Sk: with causal masking they are
    # excluded only if q positions stay < Sk — enforce via explicit seq args.
    nq = q.shape[2] // qblk
    nk = k.shape[2] // kblk

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        seq_q=Sq, seq_k=Sk, qblk=qblk, kblk=kblk)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, qblk, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kblk, hd),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, kblk, hd),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qblk, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qblk, 1), jnp.float32),   # running max
            pltpu.VMEM((qblk, 1), jnp.float32),   # running denominator
            pltpu.VMEM((qblk, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq] if pad_q else out
