"""Pallas TPU kernel: user-centric PS aggregation  Y = W Θ.

W: (k, m) mixing rules (resident in VMEM — tiny), Θ: (m, D) client-stacked
flat params with D up to billions.  The kernel streams Θ through VMEM in
(m, DBLK) tiles and emits (k, DBLK) tiles — a skinny matmul with O(k)
arithmetic intensity, i.e. deliberately HBM-bandwidth-bound (DESIGN.md §5):
one pass over HBM is the roofline, and this tiling achieves it.

DBLK is MXU/VREG aligned (multiple of 128 lanes); m and k are padded to the
8-sublane boundary by the ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_DBLK = 2048


def _kernel(w_ref, theta_ref, out_ref):
    w = w_ref[...].astype(jnp.float32)          # (k, m)
    t = theta_ref[...].astype(jnp.float32)      # (m, DBLK)
    out_ref[...] = jnp.dot(
        w, t, preferred_element_type=jnp.float32).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("dblk", "interpret"))
def mixing_aggregate(w: jnp.ndarray, theta: jnp.ndarray, *,
                     dblk: int = DEFAULT_DBLK,
                     interpret: bool = False) -> jnp.ndarray:
    """Y = W @ Θ.  w: (k, m); theta: (m, D) -> (k, D) in theta.dtype."""
    k, m = w.shape
    m2, d = theta.shape
    assert m == m2, (w.shape, theta.shape)
    pad_d = (-d) % dblk
    if pad_d:
        theta = jnp.pad(theta, ((0, 0), (0, pad_d)))
    grid = (theta.shape[1] // dblk,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, m), lambda i: (0, 0)),        # W resident
            pl.BlockSpec((m, dblk), lambda i: (0, i)),     # Θ tile
        ],
        out_specs=pl.BlockSpec((k, dblk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k, theta.shape[1]), theta.dtype),
        interpret=interpret,
    )(w, theta)
    return out[:, :d] if pad_d else out
