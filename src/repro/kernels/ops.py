"""jit'd public wrappers around the Pallas kernels.

On this CPU container kernels run with interpret=True (the TPU lowering is
the target; interpret executes the same kernel body).  `INTERPRET` flips
automatically off when a TPU backend is present.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mixing_aggregate import mixing_aggregate as _mix
from repro.kernels.pairwise_sqdist import gram_matrix as _gram
from repro.kernels.pairwise_sqdist import pairwise_sqdist as _sqdist

INTERPRET = jax.default_backend() != "tpu"


def _pad_rows(a: jnp.ndarray, mult: int = 8):
    pad = (-a.shape[0]) % mult
    return (jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1)), pad)


def mixing_aggregate(w: jnp.ndarray, theta: jnp.ndarray, *,
                     dblk: int = 2048) -> jnp.ndarray:
    """Y = W Θ; k/m padded to the TPU sublane boundary, result cropped."""
    k, m = w.shape
    pk, pm = (-k) % 8, (-m) % 8
    w2 = jnp.pad(w, ((0, pk), (0, pm)))
    theta2 = jnp.pad(theta, ((0, pm), (0, 0)))
    out = _mix(w2, theta2, dblk=dblk, interpret=INTERPRET)
    return out[:k]


def pairwise_sqdist(g: jnp.ndarray, *, dblk: int = 2048) -> jnp.ndarray:
    m = g.shape[0]
    g2, _ = _pad_rows(g)
    return _sqdist(g2, dblk=dblk, interpret=INTERPRET)[:m, :m]


def gram_matrix(g: jnp.ndarray, *, dblk: int = 2048) -> jnp.ndarray:
    m = g.shape[0]
    g2, _ = _pad_rows(g)
    return _gram(g2, dblk=dblk, interpret=INTERPRET)[:m, :m]


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    qblk: int = 128, kblk: int = 128) -> jnp.ndarray:
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  qblk=qblk, kblk=kblk, interpret=INTERPRET)


__all__ = ["mixing_aggregate", "pairwise_sqdist", "gram_matrix",
           "flash_attention", "ref", "INTERPRET"]
