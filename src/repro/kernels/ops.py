"""jit'd public wrappers around the Pallas kernels.

On this CPU container kernels run with interpret=True (the TPU lowering is
the target; interpret executes the same kernel body).  `INTERPRET` flips
automatically off when a TPU backend is present.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mixing_aggregate import mixing_aggregate as _mix
from repro.kernels.pairwise_sqdist import gram_matrix as _gram
from repro.kernels.pairwise_sqdist import pairwise_sqdist as _sqdist
from repro.kernels.quantize import (qsgd_dequantize as _qsgd_deq,
                                    qsgd_quantize as _qsgd_q,
                                    rowwise_absmax as _absmax)
from repro.kernels.topk_threshold import topk_threshold as _topk

INTERPRET = jax.default_backend() != "tpu"


def _pad_rows(a: jnp.ndarray, mult: int = 8):
    pad = (-a.shape[0]) % mult
    return (jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1)), pad)


def mixing_aggregate(w: jnp.ndarray, theta: jnp.ndarray, *,
                     dblk: int = 2048) -> jnp.ndarray:
    """Y = W Θ; k/m padded to the TPU sublane boundary, result cropped."""
    k, m = w.shape
    pk, pm = (-k) % 8, (-m) % 8
    w2 = jnp.pad(w, ((0, pk), (0, pm)))
    theta2 = jnp.pad(theta, ((0, pm), (0, 0)))
    out = _mix(w2, theta2, dblk=dblk, interpret=INTERPRET)
    return out[:k]


def pairwise_sqdist(g: jnp.ndarray, *, dblk: int = 2048) -> jnp.ndarray:
    m = g.shape[0]
    g2, _ = _pad_rows(g)
    return _sqdist(g2, dblk=dblk, interpret=INTERPRET)[:m, :m]


def gram_matrix(g: jnp.ndarray, *, dblk: int = 2048) -> jnp.ndarray:
    m = g.shape[0]
    g2, _ = _pad_rows(g)
    return _gram(g2, dblk=dblk, interpret=INTERPRET)[:m, :m]


def qsgd_quantize(x: jnp.ndarray, noise: jnp.ndarray, *, bits: int,
                  dblk: int = 2048):
    """(levels int32, absmax (m,1)) of the QSGD channel codec; rows padded
    to the sublane boundary and cropped."""
    m = x.shape[0]
    x2, _ = _pad_rows(x)
    noise2, _ = _pad_rows(noise)
    amax = _absmax(x2, dblk=dblk, interpret=INTERPRET)
    q = _qsgd_q(x2, noise2, amax, bits=bits, dblk=dblk, interpret=INTERPRET)
    return q[:m], amax[:m]


def qsgd_dequantize(q: jnp.ndarray, absmax: jnp.ndarray, *, bits: int,
                    dblk: int = 2048) -> jnp.ndarray:
    m = q.shape[0]
    q2, _ = _pad_rows(q)
    amax2, _ = _pad_rows(absmax)
    return _qsgd_deq(q2, amax2, bits=bits, dblk=dblk,
                     interpret=INTERPRET)[:m]


def qsgd_roundtrip(x: jnp.ndarray, noise: jnp.ndarray, *, bits: int,
                   dblk: int = 2048) -> jnp.ndarray:
    """Fused channel view: dequantize(quantize(x)) — what the server sees."""
    q, amax = qsgd_quantize(x, noise, bits=bits, dblk=dblk)
    return qsgd_dequantize(q, amax, bits=bits, dblk=dblk)


def topk_threshold(absx: jnp.ndarray, *, k: int, rblk: int = 8
                   ) -> jnp.ndarray:
    """Per-row top-k magnitude cutoff (m, 1); rows padded to rblk."""
    m = absx.shape[0]
    absx2, _ = _pad_rows(absx, mult=rblk)
    return _topk(absx2, k=k, rblk=rblk, interpret=INTERPRET)[:m]


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    qblk: int = 128, kblk: int = 128) -> jnp.ndarray:
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  qblk=qblk, kblk=kblk, interpret=INTERPRET)


__all__ = ["mixing_aggregate", "pairwise_sqdist", "gram_matrix",
           "flash_attention", "qsgd_quantize", "qsgd_dequantize",
           "qsgd_roundtrip", "topk_threshold", "ref", "INTERPRET"]
