"""Pallas TPU kernel: pairwise squared distances between client gradients.

Computes the Gram matrix G Gᵀ of (m, D) stacked gradients by streaming D
through VMEM in (m, DBLK) tiles and accumulating the (m, m) product across
grid steps (output block is revisited every step — the canonical Pallas
accumulation pattern).  Δ is then assembled from the Gram diagonal:
Δ_ij = G_ii + G_jj − 2 G_ij.  One HBM pass instead of the naive O(m²)
re-reads of each g_i (DESIGN.md §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_DBLK = 2048


def _gram_kernel(g_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...].astype(jnp.float32)          # (m, DBLK)
    out_ref[...] += jnp.dot(g, g.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("dblk", "interpret"))
def gram_matrix(g: jnp.ndarray, *, dblk: int = DEFAULT_DBLK,
                interpret: bool = False) -> jnp.ndarray:
    """(m, D) -> (m, m) float32 Gram matrix, D-tiled single HBM pass."""
    m, d = g.shape
    pad_d = (-d) % dblk
    if pad_d:
        g = jnp.pad(g, ((0, 0), (0, pad_d)))
    grid = (g.shape[1] // dblk,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, dblk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=interpret,
    )(g)


def pairwise_sqdist(g: jnp.ndarray, *, dblk: int = DEFAULT_DBLK,
                    interpret: bool = False) -> jnp.ndarray:
    """Δ_ij = ||g_i − g_j||² via the Gram kernel."""
    gram = gram_matrix(g, dblk=dblk, interpret=interpret)
    sq = jnp.diag(gram)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)
