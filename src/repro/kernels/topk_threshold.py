"""Pallas TPU kernel: per-row top-k magnitude threshold (channel uplink).

Top-k sparsification keeps each client's k largest-|x| update coordinates.
A full sort of the (m, D) client stack is the naive route; the channel
only needs the per-row CUTOFF, so this kernel bisects it instead: each
row block stays resident in VMEM and ``N_ITER`` halvings of ``[0, max|x|]``
converge ``lo`` onto the k-th largest magnitude from below, maintaining
the invariant ``count(|x| >= lo) >= k`` (so thresholding at ``lo`` never
drops below k survivors).  After 30 iterations the interval is
``max|x| · 2⁻³⁰`` wide — below the spacing of float32 order statistics at
any realistic D, i.e. exactly the k-th value in practice (ties keep both,
which only ever errs toward transmitting more).

One grid step owns an (RBLK, D) row block — for paper-scale updates
(D ≲ 10⁵) that is well under VMEM; bigger payloads lower RBLK.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_RBLK = 8
N_ITER = 30


def _threshold_kernel(a_ref, out_ref, *, k: int):
    a = a_ref[...]                                       # (rblk, D) = |x|
    hi = jnp.max(a, axis=1, keepdims=True)               # (rblk, 1)
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((a >= mid).astype(jnp.int32), axis=1, keepdims=True)
        ge = cnt >= k
        return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid)

    lo, _ = jax.lax.fori_loop(0, N_ITER, body, (lo, hi))
    out_ref[...] = jnp.broadcast_to(lo, out_ref.shape)


@functools.partial(jax.jit, static_argnames=("k", "rblk", "interpret"))
def topk_threshold(absx: jnp.ndarray, *, k: int, rblk: int = DEFAULT_RBLK,
                   interpret: bool = False) -> jnp.ndarray:
    """absx: (m, D) non-negative magnitudes -> (m, 1) thresholds t_i with
    ``count(absx[i] >= t_i) >= k`` (t_i = 0 when k >= D: keep everything)."""
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    m, d = absx.shape
    pad_d = (-d) % 128
    if pad_d:
        # zero padding never lifts the threshold: mid > 0 throughout the
        # bisection, so padded zeros are never counted as survivors
        absx = jnp.pad(absx, ((0, 0), (0, pad_d)))
    grid = (m // rblk,)
    out = pl.pallas_call(
        functools.partial(_threshold_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((rblk, absx.shape[1]), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rblk, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 128), jnp.float32),
        interpret=interpret,
    )(absx)
    return out[:, :1]
