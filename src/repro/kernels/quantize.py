"""Pallas TPU kernels: QSGD stochastic uniform quantization (channel uplink).

The wireless channel subsystem (DESIGN.md §3b) compresses each client's
update vector before it crosses the uplink.  The payload is the (m, D)
client-stacked flat update; QSGD with b bits quantizes each row onto the
signed grid ``{-s..s} · scale_i`` with ``s = 2^(b-1) − 1`` and a per-row
scale ``max|x_i|/s``, using stochastic rounding so the quantizer is
unbiased: ``E[q] = x/scale`` exactly (``floor(y + u)`` with ``u ~ U[0,1)``).

Three kernels, all streaming D through VMEM in (m, DBLK) tiles:

  * `rowwise_absmax`  — per-row max|x|, accumulated across the D grid.
  * `qsgd_quantize`   — int32 levels from (x, absmax, uniform noise).  The
    noise rides in as an input (the host engines draw it from the run's
    JAX key) — deterministic given a key, and the kernel body is identical
    under interpret mode, where the TPU-resident PRNG is unavailable.
  * `qsgd_dequantize` — levels × per-row scale back to f32.

Levels are carried as int32 (8-sublane tiling like the f32 tiles; the
*accounted* payload is b bits/element + one 32-bit scale per row —
`repro.fl.channel.payload` owns that arithmetic, the simulation never
materializes the packed bitstream).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_DBLK = 2048


def _absmax_kernel(x_ref, out_ref):
    i = pl.program_id(0)
    tile = jnp.max(jnp.abs(x_ref[...]), axis=1, keepdims=True)   # (m, 1)
    tile = jnp.broadcast_to(tile, out_ref.shape)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = tile

    @pl.when(i > 0)
    def _acc():
        out_ref[...] = jnp.maximum(out_ref[...], tile)


@functools.partial(jax.jit, static_argnames=("dblk", "interpret"))
def rowwise_absmax(x: jnp.ndarray, *, dblk: int = DEFAULT_DBLK,
                   interpret: bool = False) -> jnp.ndarray:
    """(m, D) f32 -> (m, 1) per-row max|x| (0 for all-zero rows)."""
    m, d = x.shape
    pad_d = (-d) % dblk
    if pad_d:
        x = jnp.pad(x, ((0, 0), (0, pad_d)))
    grid = (x.shape[1] // dblk,)
    out = pl.pallas_call(
        _absmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, dblk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 128), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:, :1]


# The per-row scale is ``absmax · (1/levels)`` — an explicit reciprocal
# multiply, NOT ``absmax / levels``: XLA rewrites division by a constant
# into the reciprocal multiply anyway inside the kernel, so spelling it
# out keeps the kernel bit-identical to the pure-jnp oracle
# (`ref.qsgd_roundtrip_ref`), which the mesh codec path executes.


def _quantize_kernel(x_ref, noise_ref, absmax_ref, q_ref, *, levels: float):
    scale = absmax_ref[...][:, :1] * (1.0 / levels)             # (m, 1)
    inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
    y = x_ref[...] * inv
    q = jnp.floor(y + noise_ref[...])                           # unbiased
    q_ref[...] = jnp.clip(q, -levels, levels).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "dblk", "interpret"))
def qsgd_quantize(x: jnp.ndarray, noise: jnp.ndarray, absmax: jnp.ndarray, *,
                  bits: int, dblk: int = DEFAULT_DBLK,
                  interpret: bool = False) -> jnp.ndarray:
    """Stochastic-rounding quantization to signed b-bit levels.

    x, noise: (m, D); absmax: (m, 1) from `rowwise_absmax`; noise ~ U[0,1).
    Returns int32 levels in [-s, s], s = 2^(b-1) − 1.
    """
    if not 2 <= bits <= 8:
        raise ValueError(f"qsgd bits must be in [2, 8], got {bits}")
    m, d = x.shape
    levels = float(2 ** (bits - 1) - 1)
    pad_d = (-d) % dblk
    if pad_d:
        x = jnp.pad(x, ((0, 0), (0, pad_d)))
        noise = jnp.pad(noise, ((0, 0), (0, pad_d)))
    absmax = jnp.broadcast_to(absmax, (m, 128))
    grid = (x.shape[1] // dblk,)
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, dblk), lambda i: (0, i)),
            pl.BlockSpec((m, dblk), lambda i: (0, i)),
            pl.BlockSpec((m, 128), lambda i: (0, 0)),   # absmax resident
        ],
        out_specs=pl.BlockSpec((m, dblk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, x.shape[1]), jnp.int32),
        interpret=interpret,
    )(x, noise, absmax)
    return out[:, :d] if pad_d else out


def _dequantize_kernel(q_ref, absmax_ref, out_ref, *, levels: float):
    scale = absmax_ref[...][:, :1] * (1.0 / levels)
    out_ref[...] = q_ref[...].astype(jnp.float32) * scale


@functools.partial(jax.jit, static_argnames=("bits", "dblk", "interpret"))
def qsgd_dequantize(q: jnp.ndarray, absmax: jnp.ndarray, *, bits: int,
                    dblk: int = DEFAULT_DBLK,
                    interpret: bool = False) -> jnp.ndarray:
    """int32 levels (m, D) × per-row scale -> f32 values."""
    m, d = q.shape
    levels = float(2 ** (bits - 1) - 1)
    pad_d = (-d) % dblk
    if pad_d:
        q = jnp.pad(q, ((0, 0), (0, pad_d)))
    absmax = jnp.broadcast_to(absmax, (m, 128))
    grid = (q.shape[1] // dblk,)
    out = pl.pallas_call(
        functools.partial(_dequantize_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, dblk), lambda i: (0, i)),
            pl.BlockSpec((m, 128), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, dblk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, q.shape[1]), jnp.float32),
        interpret=interpret,
    )(q, absmax)
    return out[:, :d] if pad_d else out
