"""Roofline: three terms per (arch × shape × mesh) from dry-run artifacts.

    compute    = HLO_FLOPs  / (chips × PEAK_FLOPS_BF16)
    memory     = HLO_bytes  / (chips × HBM_BW)
    collective = coll_bytes / (chips × ICI_BW)

cost_analysis() on a GSPMD-partitioned executable reports the PER-DEVICE
program, so terms divide by per-chip rates directly; `chips` normalization
is kept explicit in the artifact for the global view.  Collective bytes are
not in cost_analysis — they are parsed out of the optimized HLO: the sum of
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict, Optional

from repro.configs.base import ModelConfig, active_param_count, param_count
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[2,1024,512]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9\[\],\s{}:#]+?)\s*(?:\))?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind OUTPUT bytes of every collective op (per device).

    '-start' variants counted once ('-done' carries no new transfer).
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "-done(" in s:
            continue
        hit = None
        for kind in _COLLECTIVES:
            if f" {kind}(" in s or f" {kind}-start(" in s:
                hit = kind
                break
        if hit is None:
            continue
        eq = s.find("=")
        if eq < 0:
            continue
        lhs_rhs = s[eq + 1:]
        op_idx = lhs_rhs.find(hit)
        out[hit] += _shape_bytes(lhs_rhs[:op_idx])
    return out


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops_global: float
    useful_flops_ratio: float
    peak_memory_per_device: Optional[float] = None

    def as_dict(self):
        return asdict(self)


def model_flops(cfg: ModelConfig, shape_kind: str, seq_len: int,
                global_batch: int) -> float:
    """MODEL_FLOPS = 6·N_active·tokens (train), 2·N_active·tokens (serve)."""
    n = active_param_count(cfg)
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n * tokens
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n * tokens
    return 2.0 * n * global_batch          # decode: one token per sequence


def roofline(arch: str, shape: str, mesh_name: str, chips: int,
             flops_dev: float, bytes_dev: float, coll_dev: float,
             mflops: float, peak_mem: Optional[float] = None) -> RooflineTerms:
    t_c = flops_dev / PEAK_FLOPS_BF16
    t_m = bytes_dev / HBM_BW
    t_x = coll_dev / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    total_flops = flops_dev * chips
    ratio = mflops / total_flops if total_flops else 0.0
    return RooflineTerms(arch, shape, mesh_name, chips, flops_dev, bytes_dev,
                         coll_dev, t_c, t_m, t_x, bottleneck, mflops, ratio,
                         peak_mem)
