from repro.roofline.analysis import (RooflineTerms, model_flops,
                                     parse_collective_bytes, roofline)

__all__ = ["RooflineTerms", "model_flops", "parse_collective_bytes",
           "roofline"]
