"""Federated partitioners: the paper's three heterogeneity protocols.

All partitioners return a `FederatedData` with *stacked* client arrays
(m, n_max, ...) plus per-client sizes, so client updates vmap/jit cleanly.
Invalid tail slots repeat valid samples (sampling is by index mod n_i, so
padding is never drawn with higher probability).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import synthetic_cifar, synthetic_emnist


class FederatedData(NamedTuple):
    x: jnp.ndarray          # (m, n_max, H, W, C)
    y: jnp.ndarray          # (m, n_max)
    n: jnp.ndarray          # (m,) true client dataset sizes
    x_val: jnp.ndarray      # (m, n_val, H, W, C)
    y_val: jnp.ndarray      # (m, n_val)
    group: jnp.ndarray      # (m,) ground-truth cluster id (oracle baseline)

    @property
    def m(self) -> int:
        return self.x.shape[0]


def _dirichlet_partition(rng: np.random.Generator, labels: np.ndarray,
                         m: int, alpha: float, n_classes: int):
    """Class-wise proportional split: client weights ~ Dir(alpha) per class."""
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    client_idx = [[] for _ in range(m)]
    for idxs in idx_by_class:
        rng.shuffle(idxs)
        w = rng.dirichlet([alpha] * m)
        cuts = (np.cumsum(w) * len(idxs)).astype(int)[:-1]
        for i, part in enumerate(np.split(idxs, cuts)):
            client_idx[i].extend(part.tolist())
    for ci in client_idx:
        rng.shuffle(ci)
    return client_idx


def _stack_clients(x: np.ndarray, y: np.ndarray, client_idx, val_frac: float):
    m = len(client_idx)
    # guarantee a minimum of 8 train + 4 val samples per client
    sizes = [max(len(ci), 12) for ci in client_idx]
    n_val = max(4, int(min(sizes) * val_frac))
    n_train = [max(s - n_val, 8) for s in sizes]
    n_max = max(n_train)
    xs, ys, xv, yv, ns = [], [], [], [], []
    for ci, nt in zip(client_idx, n_train):
        ci = np.asarray(ci if len(ci) >= 12 else
                        np.resize(np.asarray(ci, int), 12), int)
        tr, va = ci[:nt], ci[nt:nt + n_val]
        if len(va) < n_val:
            va = np.resize(ci, n_val)
        pad = np.resize(tr, n_max)              # repeat to n_max
        xs.append(x[pad]); ys.append(y[pad])
        xv.append(x[va]); yv.append(y[va])
        ns.append(len(tr))
    return (jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys)),
            jnp.asarray(np.stack(xv)), jnp.asarray(np.stack(yv)),
            jnp.asarray(np.array(ns), jnp.float32))


def rotate_images(x: jnp.ndarray, quarter_turns: int) -> jnp.ndarray:
    return jnp.rot90(x, k=quarter_turns, axes=(-3, -2))


# ---------------------------------------------------------------------------
# the paper's three scenarios


def scenario_label_shift(key, *, n: int = 10000, m: int = 20,
                         alpha: float = 0.4, n_classes: int = 47,
                         val_frac: float = 0.15, seed: int = 0) -> FederatedData:
    """EMNIST-like, Dirichlet(0.4) label shift across 20 users (paper §IV-A.1)."""
    data = synthetic_emnist(key, n, n_classes)
    rng = np.random.default_rng(seed)
    y_np = np.asarray(data["y"])
    client_idx = _dirichlet_partition(rng, y_np, m, alpha, n_classes)
    xs, ys, xv, yv, ns = _stack_clients(np.asarray(data["x"]), y_np,
                                        client_idx, val_frac)
    return FederatedData(xs, ys, ns, xv, yv, jnp.zeros((m,), jnp.int32))


def scenario_covariate_shift(key, *, n: int = 20000, m: int = 40,
                             alpha: float = 0.4, n_classes: int = 47,
                             n_groups: int = 4, val_frac: float = 0.15,
                             seed: int = 1) -> FederatedData:
    """EMNIST-like label shift + per-group rotations {0,90,180,270}°
    (paper §IV-A.2; paper uses n=100k, m=100 — scaled for CPU, same protocol)."""
    base = scenario_label_shift(key, n=n, m=m, alpha=alpha,
                                n_classes=n_classes, val_frac=val_frac,
                                seed=seed)
    group = jnp.asarray(np.arange(m) % n_groups, jnp.int32)
    x = jnp.stack([rotate_images(base.x[i], int(group[i])) for i in range(m)])
    xv = jnp.stack([rotate_images(base.x_val[i], int(group[i]))
                    for i in range(m)])
    return base._replace(x=x, x_val=xv, group=group)


def scenario_concept_shift(key, *, n: int = 10000, m: int = 20,
                           n_classes: int = 10, n_groups: int = 4,
                           val_frac: float = 0.15, seed: int = 2
                           ) -> FederatedData:
    """CIFAR-like, per-group random label permutation (paper §IV-A.3)."""
    data = synthetic_cifar(key, n, n_classes)
    rng = np.random.default_rng(seed)
    # IID split (concept shift only): round-robin
    order = rng.permutation(n)
    client_idx = [order[i::m].tolist() for i in range(m)]
    xs, ys, xv, yv, ns = _stack_clients(np.asarray(data["x"]),
                                        np.asarray(data["y"]),
                                        client_idx, val_frac)
    group = jnp.asarray(np.arange(m) % n_groups, jnp.int32)
    perms = np.stack([rng.permutation(n_classes) for _ in range(n_groups)])
    perms_j = jnp.asarray(perms, jnp.int32)
    ys = jax.vmap(lambda g, yy: perms_j[g][yy])(group, ys)
    yv = jax.vmap(lambda g, yy: perms_j[g][yy])(group, yv)
    return FederatedData(xs, ys, ns, xv, yv, group)


SCENARIOS = {
    "emnist_label_shift": scenario_label_shift,
    "emnist_covariate_shift": scenario_covariate_shift,
    "cifar_concept_shift": scenario_concept_shift,
}
