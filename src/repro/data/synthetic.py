"""Deterministic synthetic stand-ins for EMNIST / CIFAR-10 (repro band 2:
datasets are a hardware/data gate we simulate — DESIGN.md §1).

Each class c has a smooth latent prototype image; samples are
prototype + structured deformation + pixel noise, so (a) the task is
learnable by LeNet-5, (b) rotations create genuine covariate shift,
(c) label permutations create genuine concept shift — the three protocols
of the paper apply unchanged on top.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def _smooth_noise(key, n, size, channels, cutoff: int = 6):
    """Low-frequency random images via truncated 2D Fourier basis."""
    kr, ki = jax.random.split(key)
    coef = (jax.random.normal(kr, (n, channels, cutoff, cutoff)) +
            1j * jax.random.normal(ki, (n, channels, cutoff, cutoff)))
    full = jnp.zeros((n, channels, size, size), jnp.complex64)
    full = full.at[:, :, :cutoff, :cutoff].set(coef)
    img = jnp.fft.ifft2(full).real
    img = img / (jnp.std(img, axis=(-2, -1), keepdims=True) + 1e-6)
    return jnp.transpose(img, (0, 2, 3, 1))      # NHWC


def make_class_prototypes(key, n_classes: int, size: int, channels: int, *,
                          separation: float = 1.0,
                          orientation_scale: float = 1.5) -> jnp.ndarray:
    """Correlated prototypes: shared base + `separation`-scaled class parts.
    Lower separation ⇒ closer classes ⇒ harder task.

    orientation_scale adds a class-independent horizontal ramp — an
    orientation marker.  Real digits are strongly orientation-sensitive;
    smooth Fourier blobs are not, which made the paper's rotation protocol
    produce almost no gradient-level covariate shift (Δ same-group ≈
    Δ cross-group, measured 7.68 vs 7.77 — EXPERIMENTS.md §Paper).  The
    ramp restores the property the protocol relies on without adding any
    class information.
    """
    kb, kc = jax.random.split(key)
    base = _smooth_noise(kb, 1, size, channels)
    uniq = _smooth_noise(kc, n_classes, size, channels)
    ramp = jnp.broadcast_to(jnp.linspace(-1.0, 1.0, size)[None, :, None],
                            (size, size, channels))
    return base + separation * uniq + orientation_scale * ramp[None]


def sample_dataset(key, prototypes: jnp.ndarray, labels: jnp.ndarray, *,
                   deform_scale: float = 1.1, noise_scale: float = 0.8
                   ) -> jnp.ndarray:
    """x_i = prototype[y_i] + deform (smooth, per-sample) + white noise."""
    n = labels.shape[0]
    size, channels = prototypes.shape[1], prototypes.shape[3]
    kd, kn = jax.random.split(key)
    deform = _smooth_noise(kd, n, size, channels) * deform_scale
    noise = jax.random.normal(kn, (n, size, size, channels)) * noise_scale
    return prototypes[labels] + deform + noise


def synthetic_emnist(key, n: int, n_classes: int = 47) -> Dict[str, jnp.ndarray]:
    """EMNIST-like: 28x28x1, 47 balanced classes.

    Class signal (separation 1.2) deliberately dominates the per-sample
    deform/noise so that, like real digits, the class structure — and
    therefore its rotation — is what gradients see (the covariate-shift
    protocol is vacuous otherwise; see make_class_prototypes)."""
    kp, kl, ks = jax.random.split(key, 3)
    protos = make_class_prototypes(kp, n_classes, 28, 1, separation=1.2)
    labels = jax.random.randint(kl, (n,), 0, n_classes)
    x = sample_dataset(ks, protos, labels, deform_scale=0.5, noise_scale=0.4)
    return {"x": x, "y": labels}


def synthetic_cifar(key, n: int, n_classes: int = 10) -> Dict[str, jnp.ndarray]:
    """CIFAR-like: 32x32x3, 10 balanced classes."""
    kp, kl, ks = jax.random.split(key, 3)
    protos = make_class_prototypes(kp, n_classes, 32, 3, separation=0.5)
    labels = jax.random.randint(kl, (n,), 0, n_classes)
    x = sample_dataset(ks, protos, labels)
    return {"x": x, "y": labels}


def synthetic_lm_tokens(key, batch: int, seq_len: int, vocab: int,
                        *, order: int = 2) -> jnp.ndarray:
    """Markov-ish synthetic token stream for LM training examples: tokens are
    a noisy deterministic function of the previous `order` tokens, so a
    language model has actual structure to learn."""
    k0, kf, kn = jax.random.split(key, 3)
    a = jax.random.randint(kf, (order,), 1, vocab - 1)
    start = jax.random.randint(k0, (batch, order), 0, vocab)
    noise = jax.random.bernoulli(kn, 0.1, (batch, seq_len))
    rand = jax.random.randint(kn, (batch, seq_len), 0, vocab)

    def step(carry, t):
        nxt = (jnp.sum(carry * a[None, :], axis=1) + 17) % vocab
        nxt = jnp.where(noise[:, t], rand[:, t], nxt)
        carry = jnp.concatenate([carry[:, 1:], nxt[:, None]], axis=1)
        return carry, nxt

    _, toks = jax.lax.scan(step, start, jnp.arange(seq_len))
    return jnp.transpose(toks, (1, 0)).astype(jnp.int32)
