"""User-centric aggregation (paper Eq. 5) over parameter pytrees.

Stacked-client params: every leaf carries a leading client dim m.  The
aggregation is a weighted mix along that dim:

    θ_i^t = Σ_j W[i,j] θ_j^{t-1/2}        (unicast / full personalization)
    θ̂_c  = Σ_j Ŵ[c,j] θ_j ; θ_i = θ̂_{a(i)}  (m_t streams, group broadcast)

Under pjit with the client dim sharded over a mesh axis, the einsum lowers
to the corresponding collective (all-gather+mix or k weighted all-reduces);
`repro.core.distributed` provides explicit shard_map schedules for the same
math, and `repro.kernels.mixing_aggregate` the Pallas PS-side kernel.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.streams import StreamPlan


def _mix_leaf(w: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """(k,m) x (m, ...) -> (k, ...) in the leaf's dtype.

    Inputs stay in the leaf dtype (so any collective the mix lowers to moves
    bf16, not fp32); the contraction accumulates in fp32."""
    out = jax.lax.dot_general(
        w.astype(leaf.dtype), leaf, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out.astype(leaf.dtype)


def mix_pytree(stacked_params: Any, w: jnp.ndarray) -> Any:
    """Apply an aggregation-rule matrix w (k, m) to all leaves (m, ...)."""
    return jax.tree_util.tree_map(lambda l: _mix_leaf(w, l), stacked_params)


def user_centric_aggregate(stacked_params: Any, w: jnp.ndarray) -> Any:
    """Full personalization: every client gets its own mixed model (m -> m)."""
    return mix_pytree(stacked_params, w)


def fedavg_aggregate(stacked_params: Any, n: jnp.ndarray) -> Any:
    """FedAvg: one weighted mean, broadcast back to all m clients."""
    m = n.shape[0]
    w = jnp.broadcast_to((n / jnp.sum(n))[None, :], (m, m))
    return mix_pytree(stacked_params, w)


def stream_aggregate(stacked_params: Any, plan: StreamPlan) -> Any:
    """m_t-stream aggregation: mix to centroids then group-broadcast."""
    mixed = mix_pytree(stacked_params, plan.centroids)          # (k, ...)
    return jax.tree_util.tree_map(
        lambda l: jnp.take(l, plan.assignment, axis=0), mixed)  # (m, ...)


def downlink_models(w_or_plan) -> int:
    """Number of distinct models the PS must transmit (comm-model input)."""
    if isinstance(w_or_plan, StreamPlan):
        return int(w_or_plan.centroids.shape[0])
    return int(w_or_plan.shape[0])
