"""User-centric mixing coefficients (paper Eq. 6).

    w_{i,j} ∝ (n_j / n_i) · exp( −Δ_{i,j} / (2 σ_i σ_j) ),   normalized over j.

Properties the paper leans on (and our tests assert):
  * homogeneous clients (Δ→0, equal n) ⇒ W → uniform ⇒ UCFL ≡ FedAvg;
  * n_i → ∞ relative to others ⇒ row i → e_i (local learning);
  * W is row-stochastic (each row is a personalized aggregation rule).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def mixing_matrix(delta: jnp.ndarray, sigma2: jnp.ndarray,
                  n: jnp.ndarray) -> jnp.ndarray:
    """W (m, m), row-stochastic, from Δ (m,m), σ² (m,), dataset sizes n (m,)."""
    sigma = jnp.sqrt(jnp.maximum(sigma2.astype(jnp.float32), 1e-12))
    denom = 2.0 * sigma[:, None] * sigma[None, :]
    # log-space for stability: log w_ij = log n_j - Δ_ij / (2 σ_i σ_j) + const_i
    logits = jnp.log(n.astype(jnp.float32))[None, :] - \
        delta.astype(jnp.float32) / denom
    logits = logits - jnp.max(logits, axis=1, keepdims=True)
    w = jnp.exp(logits)
    return w / jnp.sum(w, axis=1, keepdims=True)


def fedavg_weights(n: jnp.ndarray) -> jnp.ndarray:
    """The FedAvg special case: every row is n / Σn."""
    w = n.astype(jnp.float32) / jnp.sum(n)
    return jnp.broadcast_to(w[None, :], (n.shape[0], n.shape[0]))


def groupwise_weights(n: jnp.ndarray, group: np.ndarray) -> jnp.ndarray:
    """Block-diagonal FedAvg rule: row i averages over i's group, weighted
    by dataset size (the oracle baseline and CFL's per-cluster FedAvg)."""
    group = np.asarray(group)
    m = len(group)
    wmat = np.zeros((m, m), np.float32)
    nn = np.asarray(n)
    for g in np.unique(group):
        idx = np.where(group == g)[0]
        wg = nn[idx] / nn[idx].sum()
        for i in idx:
            wmat[i, idx] = wg
    return jnp.asarray(wmat)


def effective_samples(w: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """1 / Σ_j w_ij²/n_j — the variance-reduction term of Theorem 1 per user."""
    return 1.0 / jnp.sum(w ** 2 / jnp.maximum(n[None, :], 1.0), axis=1)
