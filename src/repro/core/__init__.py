"""The paper's primary contribution: user-centric aggregation for FL.

similarity  — pre-training round statistics (Δ, σ², n)
mixing      — Eq. 6 collaboration coefficients
streams     — k-means stream reduction + silhouette guidance
aggregation — Eq. 5 pytree mixing (unicast / streams / fedavg)
distributed — explicit shard_map collective schedules for the mesh
theory      — Theorem 1 bound + bound-minimizing weights (beyond paper)
"""
from repro.core.aggregation import (downlink_models, fedavg_aggregate,
                                    mix_pytree, stream_aggregate,
                                    user_centric_aggregate)
from repro.core.mixing import (effective_samples, fedavg_weights,
                               groupwise_weights, mixing_matrix)
from repro.core.similarity import (client_gradients, delta_matrix,
                                   flatten_pytree, full_gradient,
                                   sigma_estimates, similarity_round)
from repro.core.streams import (StreamPlan, kmeans, select_num_streams,
                                silhouette_score)
from repro.core.theory import bound_minimizing_weights, theorem1_bound

__all__ = [
    "downlink_models", "fedavg_aggregate", "mix_pytree", "stream_aggregate",
    "user_centric_aggregate", "effective_samples", "fedavg_weights",
    "groupwise_weights", "mixing_matrix", "client_gradients", "delta_matrix",
    "flatten_pytree",
    "full_gradient", "sigma_estimates", "similarity_round", "StreamPlan",
    "kmeans", "select_num_streams", "silhouette_score",
    "bound_minimizing_weights", "theorem1_bound",
]
