"""Theorem 1 machinery: the excess-risk bound and a bound-minimizing
weight rule (a beyond-paper alternative to the Eq. 6 heuristic).

    gap(i) <= B·sqrt(Σ_j w_ij²/n_j)·( sqrt(2d/N·log(eN/d)) + sqrt(log(2/δ)) )
              + 2·Σ_j w_ij·d_F(P_i,P_j) + 2λ

The discrepancy d_F is unobservable under FL constraints; the paper's
heuristic substitutes the gradient score.  `bound_minimizing_weights`
instead *optimizes* the bound directly over the simplex, using any supplied
discrepancy proxy — projected mirror descent, fully jit-able.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def estimation_term(w: jnp.ndarray, n: jnp.ndarray, *, B: float = 1.0,
                    d_vc: float = 100.0, delta: float = 0.05) -> jnp.ndarray:
    """First bound term, per user (vectorized over rows of w)."""
    N = jnp.sum(n)
    cplx = jnp.sqrt(2 * d_vc / N * jnp.log(math.e * N / d_vc)) + \
        jnp.sqrt(jnp.log(2.0 / delta))
    return B * jnp.sqrt(jnp.sum(w ** 2 / jnp.maximum(n[None, :], 1.0), axis=1)) * cplx


def bias_term(w: jnp.ndarray, disc: jnp.ndarray) -> jnp.ndarray:
    """2 Σ_j w_ij d_F(P_i, P_j) per user; disc: (m, m) discrepancy proxy."""
    return 2.0 * jnp.sum(w * disc, axis=1)


def theorem1_bound(w: jnp.ndarray, n: jnp.ndarray, disc: jnp.ndarray, *,
                   B: float = 1.0, d_vc: float = 100.0, delta: float = 0.05,
                   lam: float = 0.0) -> jnp.ndarray:
    """Per-user upper bound on the excess risk of the personalized model."""
    return estimation_term(w, n, B=B, d_vc=d_vc, delta=delta) + \
        bias_term(w, disc) + 2.0 * lam


def bound_minimizing_weights(n: jnp.ndarray, disc: jnp.ndarray, *,
                             B: float = 1.0, d_vc: float = 100.0,
                             delta: float = 0.05, steps: int = 500,
                             lr: float = 0.5) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Minimize Theorem 1's bound over row-stochastic W (mirror descent).

    Returns (W*, per-user bound at W*).  Beyond-paper weight rule: instead of
    the Eq. 6 softmax heuristic, directly descend the bound with the gradient
    score as the discrepancy proxy.
    """
    m = n.shape[0]
    logits0 = jnp.zeros((m, m), jnp.float32)

    def obj(logits):
        w = jax.nn.softmax(logits, axis=1)
        return jnp.sum(theorem1_bound(w, n, disc, B=B, d_vc=d_vc, delta=delta))

    grad_fn = jax.grad(obj)

    def step(logits, _):
        return logits - lr * grad_fn(logits), None

    logits, _ = jax.lax.scan(step, logits0, None, length=steps)
    w = jax.nn.softmax(logits, axis=1)
    return w, theorem1_bound(w, n, disc, B=B, d_vc=d_vc, delta=delta)
