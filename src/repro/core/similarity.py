"""Distribution-similarity statistics (paper §III-A).

The special pre-training round: the PS broadcasts a probe model θ̂; every
client i computes (a) the full-dataset gradient ĝ_i = (1/n_i) Σ ∇ℓ and
(b) the gradient-variance estimate σ_i² over K local mini-batch resamples
(Eq. 7).  The PS then forms the pairwise score
Δ_{i,j} = ||ĝ_i − ĝ_j||²  (an estimate of the squared mean-gradient
discrepancy between P_i and P_j).

On the TPU mesh Δ is a Gram-matrix computation over m gradient vectors of
dimension D — `repro.kernels.pairwise_sqdist` is the Pallas kernel for it;
`delta_matrix` below is the pure-jnp implementation (also its oracle).
"""
from __future__ import annotations

from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp


def flatten_pytree(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])


def full_gradient(loss_fn: Callable, params, data) -> jnp.ndarray:
    """ĝ_i: flat full-dataset gradient of `loss_fn(params, data)`."""
    g = jax.grad(lambda p: loss_fn(p, data))(params)
    return flatten_pytree(g)


def client_gradients(loss_fn: Callable, params, datasets: Sequence) -> jnp.ndarray:
    """Stack ĝ_i for every client: (m, D)."""
    return jnp.stack([full_gradient(loss_fn, params, d) for d in datasets])


def delta_matrix(grads: jnp.ndarray) -> jnp.ndarray:
    """Δ_{i,j} = ||g_i - g_j||² from stacked gradients (m, D).

    Computed via the Gram matrix (one pass over D): ||g_i||² + ||g_j||² − 2⟨g_i,g_j⟩.
    """
    g = grads.astype(jnp.float32)
    sq = jnp.sum(g * g, axis=-1)
    gram = g @ g.T
    d = sq[:, None] + sq[None, :] - 2.0 * gram
    return jnp.maximum(d, 0.0)


def sigma_estimates(loss_fn: Callable, params, datasets: Sequence, *,
                    n_batches: int = 5, key=None) -> jnp.ndarray:
    """σ_i² (Eq. 7): mean squared deviation of K mini-batch gradients from ĝ_i.

    Each dataset is a dict of arrays with a leading sample dim; batches are
    contiguous K-way splits (a fixed partition, as in the paper).
    """
    sigmas = []
    for data in datasets:
        n = jax.tree_util.tree_leaves(data)[0].shape[0]
        g_full = full_gradient(loss_fn, params, data)
        K = max(2, min(n_batches, n))
        bounds = [round(k * n / K) for k in range(K + 1)]
        devs = []
        for k in range(K):
            sl = jax.tree_util.tree_map(lambda a: a[bounds[k]:bounds[k + 1]], data)
            g_k = full_gradient(loss_fn, params, sl)
            devs.append(jnp.sum((g_k - g_full) ** 2))
        sigmas.append(jnp.mean(jnp.stack(devs)))
    return jnp.stack(sigmas)


def similarity_round(loss_fn: Callable, probe_params, datasets: Sequence, *,
                     n_batches: int = 5) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The full pre-training round.  Returns (Δ (m,m), σ² (m,), n (m,))."""
    grads = client_gradients(loss_fn, probe_params, datasets)
    delta = delta_matrix(grads)
    sigma2 = sigma_estimates(loss_fn, probe_params, datasets,
                             n_batches=n_batches)
    n = jnp.array([jax.tree_util.tree_leaves(d)[0].shape[0] for d in datasets],
                  jnp.float32)
    return delta, sigma2, n
