"""Personalized-stream reduction (paper §III-B).

k-means over the rows of the mixing matrix W; the m_t centroids become the
personalized streams and each client is served its cluster's centroid rule
(group broadcast instead of unicast).  The silhouette score over the rows
guides the choice of m_t, per the paper.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class StreamPlan(NamedTuple):
    centroids: jnp.ndarray     # (k, m) — the Ŵ aggregation rules
    assignment: jnp.ndarray    # (m,) int32 — client -> stream
    inertia: jnp.ndarray       # scalar, final k-means objective


def _pairwise_sq(a, b):
    return (jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None, :]
            - 2.0 * a @ b.T)


def kmeans(rows: jnp.ndarray, k: int, *, n_iter: int = 50,
           key=None, drop_diag: bool = True) -> StreamPlan:
    """Lloyd's algorithm with greedy k-means++ style seeding (deterministic
    given `key`).  rows: (m, m) mixing-weight vectors.

    drop_diag: cluster on the OFF-DIAGONAL collaboration profile.  Each raw
    row is dominated by its own diagonal (self-weight at a different
    coordinate per client), so raw rows of same-group clients are mutually
    *distant* in L2 and Lloyd's degenerates to one blob + singletons at
    small m.  Zeroing the diagonal (and renormalizing) clusters clients by
    who they collaborate with — the quantity the paper's protocol actually
    groups by.  Centroids are then re-fit as the mean of the ORIGINAL rows
    per cluster, which spreads each member's self-weight over its cluster
    (the group-broadcast semantics).
    """
    m = rows.shape[0]
    k = int(min(k, m))
    key = jax.random.PRNGKey(0) if key is None else key
    raw = rows.astype(jnp.float32)
    if drop_diag and m > 1 and rows.shape[0] == rows.shape[1]:
        x = raw * (1.0 - jnp.eye(m, dtype=jnp.float32))
        x = x / jnp.maximum(jnp.sum(x, axis=1, keepdims=True), 1e-9)
    else:
        x = raw

    # k-means++ seeding
    first = jax.random.randint(key, (), 0, m)
    centers = [x[first]]
    for _ in range(1, k):
        d = jnp.min(_pairwise_sq(x, jnp.stack(centers)), axis=1)
        centers.append(x[jnp.argmax(d)])          # farthest-point (deterministic)
    cents = jnp.stack(centers)

    def step(cents, _):
        d = _pairwise_sq(x, cents)                # (m, k)
        assign = jnp.argmin(d, axis=1)
        oh = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # (m, k)
        counts = jnp.maximum(jnp.sum(oh, axis=0), 1.0)
        new = (oh.T @ x) / counts[:, None]
        # keep empty clusters where they were
        new = jnp.where((jnp.sum(oh, axis=0) > 0)[:, None], new, cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=n_iter)
    d = _pairwise_sq(x, cents)
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    inertia = jnp.sum(jnp.min(d, axis=1))
    # re-fit centroids on the ORIGINAL rows of each cluster and renormalize
    # to remain aggregation rules (row-stochastic)
    oh = jax.nn.one_hot(assign, k, dtype=jnp.float32)
    counts = jnp.maximum(jnp.sum(oh, axis=0), 1.0)
    cents = (oh.T @ raw) / counts[:, None]
    cents = cents / jnp.maximum(jnp.sum(cents, axis=1, keepdims=True), 1e-9)
    return StreamPlan(cents, assign, inertia)


def silhouette_score(rows: jnp.ndarray, assignment: jnp.ndarray,
                     k: int) -> jnp.ndarray:
    """Mean silhouette over samples (euclidean).  Degenerate clusters -> 0."""
    x = rows.astype(jnp.float32)
    m = x.shape[0]
    d = jnp.sqrt(jnp.maximum(_pairwise_sq(x, x), 0.0))        # (m, m)
    oh = jax.nn.one_hot(assignment, k, dtype=jnp.float32)     # (m, k)
    counts = jnp.sum(oh, axis=0)                              # (k,)
    sums = d @ oh                                             # (m, k)
    own = counts[assignment]
    a = jnp.where(own > 1,
                  jnp.take_along_axis(sums, assignment[:, None], 1)[:, 0]
                  / jnp.maximum(own - 1, 1), 0.0)
    other = jnp.where(oh > 0, jnp.inf, sums / jnp.maximum(counts[None, :], 1))
    b = jnp.min(other, axis=1)
    s = jnp.where((own > 1) & jnp.isfinite(b),
                  (b - a) / jnp.maximum(jnp.maximum(a, b), 1e-9), 0.0)
    return jnp.mean(s)


def select_num_streams(rows: jnp.ndarray, candidates=None, *,
                       key=None) -> Tuple[int, dict]:
    """Silhouette-guided m_t selection (paper: silhouette over the w_i's)."""
    m = rows.shape[0]
    if candidates is None:
        candidates = [k for k in (2, 3, 4, 6, 8) if k < m]
    scores = {}
    for k in candidates:
        plan = kmeans(rows, k, key=key)
        scores[k] = float(silhouette_score(rows, plan.assignment, k))
    best = max(scores, key=scores.get)
    return best, scores
