"""Explicit shard_map collective schedules for user-centric aggregation.

The pjit einsum in `aggregation.py` lets GSPMD choose collectives (the
baseline we roofline).  These schedules pin the communication pattern:

  * `mix_unicast_shard_map`  — all-gather the client-stacked params over the
    client axis, mix locally with the full W.  Receive volume ≈ (m-1)/m · mP
    per client group: the paper's m-fold downlink.
  * `mix_streams_shard_map`  — each shard sends its k weighted copies into a
    psum; every shard then selects its assigned stream.  Volume ∝ k·P: the
    paper's group-broadcast protocol, and the §Perf lever.

Both operate on a params pytree whose leaves have leading client dim m
sharded over `axis`; inside shard_map each shard holds m/axis_size clients.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax>=0.4.35 exposes shard_map at jax.shard_map
    from jax import shard_map as _shard_map_mod
    shard_map = _shard_map_mod.shard_map if hasattr(_shard_map_mod, "shard_map") \
        else _shard_map_mod
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

import inspect

# the replication-check kwarg was renamed check_rep -> check_vma in newer jax
_CHECK_KW = ("check_vma" if "check_vma" in
             inspect.signature(shard_map).parameters else "check_rep")
_NO_CHECK = {_CHECK_KW: False}


def _leaf_specs(params: Any, inner_spec_fn) -> Any:
    return jax.tree_util.tree_map(lambda l: inner_spec_fn(l), params)


def mix_unicast_shard_map(mesh, axis: str, params: Any, w: jnp.ndarray) -> Any:
    """θ_i ← Σ_j W[i,j] θ_j via all-gather over `axis` + local mix.

    params leaves: (m, ...) sharded P(axis, ...); w: (m, m) replicated.
    """
    m = w.shape[0]
    size = mesh.shape[axis]
    mm = m // size

    def body(w_rep, p_local):
        idx = jax.lax.axis_index(axis)
        gathered = jax.tree_util.tree_map(
            lambda l: jax.lax.all_gather(l, axis, axis=0, tiled=True), p_local)
        w_rows = jax.lax.dynamic_slice_in_dim(w_rep, idx * mm, mm, 0)  # (mm, m)
        return jax.tree_util.tree_map(
            lambda g: jnp.tensordot(w_rows.astype(jnp.float32),
                                    g.astype(jnp.float32),
                                    axes=(1, 0)).astype(g.dtype), gathered)

    pspec = jax.tree_util.tree_map(
        lambda l: P(axis, *([None] * (l.ndim - 1))), params)
    fn = shard_map(body, mesh=mesh, in_specs=(P(), pspec),
                   out_specs=pspec, **_NO_CHECK)
    return fn(w, params)


def mix_streams_shard_map(mesh, axis: str, params: Any,
                          centroids: jnp.ndarray,
                          assignment: jnp.ndarray) -> Any:
    """θ_i ← θ̂_{a(i)}, θ̂ = Ŵ θ via one psum of k weighted copies.

    centroids: (k, m); assignment: (m,) int32.  Volume ∝ k·P (k streams).
    """
    k, m = centroids.shape
    size = mesh.shape[axis]
    mm = m // size

    def body(w_rep, assign, p_local):
        idx = jax.lax.axis_index(axis)
        w_cols = jax.lax.dynamic_slice_in_dim(w_rep, idx * mm, mm, 1)  # (k, mm)
        contrib = jax.tree_util.tree_map(
            lambda l: jnp.tensordot(w_cols.astype(jnp.float32),
                                    l.astype(jnp.float32), axes=(1, 0)),
            p_local)                                            # (k, ...)
        mixed = jax.lax.psum(contrib, axis)                     # all shards: (k, ...)
        my_assign = jax.lax.dynamic_slice_in_dim(assign, idx * mm, mm, 0)
        return jax.tree_util.tree_map(
            lambda l, ref: jnp.take(l, my_assign, axis=0).astype(ref.dtype),
            mixed, p_local)

    pspec = jax.tree_util.tree_map(
        lambda l: P(axis, *([None] * (l.ndim - 1))), params)
    fn = shard_map(body, mesh=mesh, in_specs=(P(), P(), pspec),
                   out_specs=pspec, **_NO_CHECK)
    return fn(centroids, assignment, params)


MIX_SCHEDULES = ("gspmd", "shard_map_streams", "shard_map_unicast")


def mix_schedule(mesh, axes, params: Any, w: jnp.ndarray, assignment=None, *,
                 schedule: str = "gspmd") -> Any:
    """One entry point for every mixing-collective schedule.

    ``assignment=None`` means ``w`` is a full per-client matrix (one row
    per client, the unicast protocol); otherwise ``w`` is (k, m) centroid
    rules and ``assignment`` maps clients to streams.  ``axes`` are the
    mesh axes carrying the client dim — empty means no mesh placement and
    the einsum baseline is used regardless of ``schedule``.
    """
    if schedule == "gspmd" or not axes:
        return mix_einsum(params, w, assignment)
    axis = axes[0] if len(axes) == 1 else axes
    if schedule == "shard_map_streams":
        if assignment is None:           # full matrix: one stream per client
            assignment = jnp.arange(w.shape[0], dtype=jnp.int32)
        return mix_streams_shard_map(mesh, axis, params, w, assignment)
    if schedule == "shard_map_unicast":
        full_w = w if assignment is None else jnp.take(w, assignment, axis=0)
        return mix_unicast_shard_map(mesh, axis, params, full_w)
    raise ValueError(f"unknown mixing schedule {schedule!r}; "
                     f"one of {sorted(MIX_SCHEDULES)}")


def mix_einsum(params: Any, w: jnp.ndarray, assignment=None) -> Any:
    """pjit/GSPMD baseline: plain einsum mix (+ optional stream selection).
    Inputs stay in the param dtype (collectives move bf16); fp32 accumulate."""
    def leaf(l):
        out = jax.lax.dot_general(w.astype(l.dtype), l,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        return out.astype(l.dtype)
    mixed = jax.tree_util.tree_map(leaf, params)
    if assignment is None:
        return mixed
    return jax.tree_util.tree_map(
        lambda l: jnp.take(l, assignment, axis=0), mixed)
