"""msgpack checkpointing for nested dict/list pytrees of jnp/np arrays.

Arrays are encoded as {"__nd__": {dtype, shape, data-bytes}}; scalars and
strings pass through.  NamedTuple leaves (caches) are not checkpointable by
design — persist params / optimizer state / metadata only.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(obj: Any) -> Any:
    if isinstance(obj, (jnp.ndarray, np.ndarray)):
        arr = np.asarray(obj)
        return {"__nd__": {"dtype": str(arr.dtype), "shape": list(arr.shape),
                           "data": arr.tobytes()}}
    if isinstance(obj, dict):
        return {str(k): _encode(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_encode(v) for v in obj]
    if obj is None or isinstance(obj, (bool, int, float, str, bytes)):
        return obj
    raise TypeError(f"cannot checkpoint leaf of type {type(obj)}")


def _decode(obj: Any) -> Any:
    if isinstance(obj, dict):
        if "__nd__" in obj and set(obj) == {"__nd__"}:
            nd = obj["__nd__"]
            arr = np.frombuffer(nd["data"], dtype=np.dtype(nd["dtype"]))
            return jnp.asarray(arr.reshape(nd["shape"]))
        return {k: _decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v) for v in obj]
    return obj


def save(path: str, tree: Any) -> None:
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(_encode(jax.device_get(tree)), use_bin_type=True))
    os.replace(tmp, path)


def restore(path: str) -> Any:
    with open(path, "rb") as f:
        return _decode(msgpack.unpackb(f.read(), raw=False, strict_map_key=False))


def save_train_state(path: str, step: int, params: Any, opt_state: Any,
                     extra: Any = None) -> None:
    save(path, {"step": step, "params": params, "opt_state": opt_state,
                "extra": extra})


def restore_train_state(path: str):
    t = restore(path)
    return t["step"], t["params"], t["opt_state"], t.get("extra")
