"""msgpack checkpointing for nested dict/list pytrees of jnp/np arrays.

Arrays are encoded as {"__nd__": {dtype, shape, data-bytes}}; scalars and
strings pass through.  NamedTuple leaves (caches) are not checkpointable by
design — persist params / optimizer state / metadata only.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(obj: Any) -> Any:
    if isinstance(obj, (jnp.ndarray, np.ndarray)):
        arr = np.asarray(obj)
        return {"__nd__": {"dtype": str(arr.dtype), "shape": list(arr.shape),
                           "data": arr.tobytes()}}
    if isinstance(obj, dict):
        return {str(k): _encode(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_encode(v) for v in obj]
    if obj is None or isinstance(obj, (bool, int, float, str, bytes)):
        return obj
    raise TypeError(f"cannot checkpoint leaf of type {type(obj)}")


def _decode(obj: Any) -> Any:
    if isinstance(obj, dict):
        if "__nd__" in obj and set(obj) == {"__nd__"}:
            nd = obj["__nd__"]
            arr = np.frombuffer(nd["data"], dtype=np.dtype(nd["dtype"]))
            return jnp.asarray(arr.reshape(nd["shape"]))
        return {k: _decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v) for v in obj]
    return obj


def save(path: str, tree: Any) -> None:
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(_encode(jax.device_get(tree)), use_bin_type=True))
    os.replace(tmp, path)


def restore(path: str) -> Any:
    with open(path, "rb") as f:
        return _decode(msgpack.unpackb(f.read(), raw=False, strict_map_key=False))


def save_train_state(path: str, step: int, params: Any, opt_state: Any,
                     extra: Any = None) -> None:
    save(path, {"step": step, "params": params, "opt_state": opt_state,
                "extra": extra})


def restore_train_state(path: str):
    t = restore(path)
    return t["step"], t["params"], t["opt_state"], t.get("extra")


# ---------------------------------------------------------------------------
# paged-run superstep snapshots (DESIGN.md §3e): the paging engine writes
# one file per checkpointed superstep boundary — client-state store rows,
# engine carry (PRNG key + clock) and the History so far — so a preempted
# paged run resumes mid-sweep bit-identically.

_PAGED_FORMAT = "paged-v1"
_PAGED_PREFIX = "superstep_"


def save_paged_state(directory: str, chunk: int, state: dict) -> str:
    """Atomic snapshot at superstep boundary ``chunk``; returns the path.
    ``state`` is the paging engine's plain-dict payload (key, clock,
    history lists, store rows, meta) — kept schema-free here so this
    module never imports the engine."""
    path = os.path.join(directory, f"{_PAGED_PREFIX}{chunk:06d}.msgpack")
    save(path, dict(state, chunk=int(chunk), format=_PAGED_FORMAT))
    return path


def restore_paged_state(path: str) -> dict:
    t = restore(path)
    if t.get("format") != _PAGED_FORMAT:
        raise ValueError(f"{path} is not a {_PAGED_FORMAT} checkpoint "
                         f"(format={t.get('format')!r})")
    return t


def latest_paged_checkpoint(directory: str):
    """Path of the highest-superstep snapshot in ``directory`` (resume
    entry point), or None when there is nothing to resume from."""
    if not os.path.isdir(directory):
        return None
    best, best_chunk = None, -1
    for name in os.listdir(directory):
        if name.startswith(_PAGED_PREFIX) and name.endswith(".msgpack"):
            try:
                chunk = int(name[len(_PAGED_PREFIX):-len(".msgpack")])
            except ValueError:
                continue
            if chunk > best_chunk:
                best, best_chunk = os.path.join(directory, name), chunk
    return best
