"""msgpack checkpointing for nested dict/list pytrees of jnp/np arrays.

Arrays are encoded as {"__nd__": {dtype, shape, data-bytes}}; scalars and
strings pass through.  NamedTuple leaves (caches) are not checkpointable by
design — persist params / optimizer state / metadata only.

Writes are atomic AND verified (DESIGN.md §3g): the payload lands in a
process-unique temp file, is flushed + fsynced, then `os.replace`d into
place, wrapped in a crc32 envelope checked on every load — a truncated or
bit-flipped file raises `CheckpointCorruptError` instead of silently
restoring garbage.  Pre-envelope files (older runs) still load: the
checksum is simply absent, not wrong.
"""
from __future__ import annotations

import os
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

# outer envelope around the encoded tree: {format, crc32, payload}.  The
# envelope is itself msgpack, so legacy (bare-tree) files are told apart
# by the format marker, not by parse failure.
_CKPT_MAGIC = "ckpt-crc32-v1"


class CheckpointCorruptError(Exception):
    """A checkpoint file failed its integrity check (truncated, bit-rotted
    or not msgpack at all) — callers fall back to an older snapshot."""


def _encode(obj: Any) -> Any:
    if isinstance(obj, (jnp.ndarray, np.ndarray)):
        arr = np.asarray(obj)
        return {"__nd__": {"dtype": str(arr.dtype), "shape": list(arr.shape),
                           "data": arr.tobytes()}}
    if isinstance(obj, dict):
        return {str(k): _encode(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_encode(v) for v in obj]
    if obj is None or isinstance(obj, (bool, int, float, str, bytes)):
        return obj
    raise TypeError(f"cannot checkpoint leaf of type {type(obj)}")


def _decode(obj: Any) -> Any:
    if isinstance(obj, dict):
        if "__nd__" in obj and set(obj) == {"__nd__"}:
            nd = obj["__nd__"]
            arr = np.frombuffer(nd["data"], dtype=np.dtype(nd["dtype"]))
            return jnp.asarray(arr.reshape(nd["shape"]))
        return {k: _decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v) for v in obj]
    return obj


def save(path: str, tree: Any) -> None:
    """Verified atomic write: crc32 envelope, process-unique temp file,
    flush + fsync, then `os.replace` — a crash mid-save leaves either the
    old intact file or the new intact file, never a torn one."""
    payload = msgpack.packb(_encode(jax.device_get(tree)), use_bin_type=True)
    blob = msgpack.packb({"format": _CKPT_MAGIC,
                          "crc32": zlib.crc32(payload),
                          "payload": payload}, use_bin_type=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def restore(path: str) -> Any:
    """Load + integrity-check a checkpoint.  Raises
    `CheckpointCorruptError` on a truncated/bit-rotted file; decodes
    legacy pre-envelope files (no checksum recorded) as-is."""
    with open(path, "rb") as f:
        blob = f.read()
    try:
        outer = msgpack.unpackb(blob, raw=False, strict_map_key=False)
    except Exception as e:
        raise CheckpointCorruptError(
            f"{path}: not a readable msgpack checkpoint (truncated?): "
            f"{e}") from e
    if (isinstance(outer, dict) and outer.get("format") == _CKPT_MAGIC):
        payload = outer.get("payload")
        if not isinstance(payload, bytes):
            raise CheckpointCorruptError(f"{path}: envelope has no payload")
        crc = zlib.crc32(payload)
        if crc != outer.get("crc32"):
            raise CheckpointCorruptError(
                f"{path}: checksum mismatch (stored {outer.get('crc32')}, "
                f"computed {crc}) — the file is corrupt")
        try:
            tree = msgpack.unpackb(payload, raw=False, strict_map_key=False)
        except Exception as e:        # crc passed but payload won't parse
            raise CheckpointCorruptError(
                f"{path}: payload failed to decode: {e}") from e
        return _decode(tree)
    return _decode(outer)       # legacy pre-envelope checkpoint


def save_train_state(path: str, step: int, params: Any, opt_state: Any,
                     extra: Any = None) -> None:
    save(path, {"step": step, "params": params, "opt_state": opt_state,
                "extra": extra})


def restore_train_state(path: str):
    t = restore(path)
    return t["step"], t["params"], t["opt_state"], t.get("extra")


# ---------------------------------------------------------------------------
# paged-run superstep snapshots (DESIGN.md §3e): the paging engine writes
# one file per checkpointed superstep boundary — client-state store rows,
# engine carry (PRNG key + clock) and the History so far — so a preempted
# paged run resumes mid-sweep bit-identically.

_PAGED_FORMAT = "paged-v1"
_PAGED_PREFIX = "superstep_"


def save_paged_state(directory: str, chunk: int, state: dict) -> str:
    """Atomic snapshot at superstep boundary ``chunk``; returns the path.
    ``state`` is the paging engine's plain-dict payload (key, clock,
    history lists, store rows, meta) — kept schema-free here so this
    module never imports the engine."""
    path = os.path.join(directory, f"{_PAGED_PREFIX}{chunk:06d}.msgpack")
    save(path, dict(state, chunk=int(chunk), format=_PAGED_FORMAT))
    return path


def restore_paged_state(path: str) -> dict:
    t = restore(path)
    if t.get("format") != _PAGED_FORMAT:
        raise ValueError(f"{path} is not a {_PAGED_FORMAT} checkpoint "
                         f"(format={t.get('format')!r})")
    return t


def paged_checkpoints(directory: str) -> list:
    """Every superstep snapshot in ``directory``, NEWEST FIRST — the
    resume fallback chain (DESIGN.md §3g): callers try each in turn,
    skipping ones that raise `CheckpointCorruptError`, so one torn or
    bit-rotted latest file costs at most one checkpoint cadence of
    recompute, never the run."""
    if not os.path.isdir(directory):
        return []
    found = []
    for name in os.listdir(directory):
        if name.startswith(_PAGED_PREFIX) and name.endswith(".msgpack"):
            try:
                chunk = int(name[len(_PAGED_PREFIX):-len(".msgpack")])
            except ValueError:
                continue
            found.append((chunk, os.path.join(directory, name)))
    return [path for _, path in sorted(found, reverse=True)]


def latest_paged_checkpoint(directory: str):
    """Path of the highest-superstep snapshot in ``directory`` (resume
    entry point), or None when there is nothing to resume from."""
    chain = paged_checkpoints(directory)
    return chain[0] if chain else None
