from repro.checkpoint.checkpoint import (CheckpointCorruptError,
                                         latest_paged_checkpoint,
                                         paged_checkpoints, restore,
                                         restore_paged_state,
                                         restore_train_state, save,
                                         save_paged_state, save_train_state)

__all__ = ["CheckpointCorruptError", "latest_paged_checkpoint",
           "paged_checkpoints", "restore", "restore_paged_state",
           "restore_train_state", "save", "save_paged_state",
           "save_train_state"]
