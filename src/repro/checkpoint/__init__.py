from repro.checkpoint.checkpoint import (latest_paged_checkpoint, restore,
                                         restore_paged_state,
                                         restore_train_state, save,
                                         save_paged_state, save_train_state)

__all__ = ["latest_paged_checkpoint", "restore", "restore_paged_state",
           "restore_train_state", "save", "save_paged_state",
           "save_train_state"]
