"""StableLM-3B — dense decoder, partial rotary [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    d_ff=6912,
    vocab_size=50304,
    attn=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=80,
                         rope_theta=10000.0, rope_fraction=0.25),
    activation="silu",
    gated_mlp=True,
    norm="layernorm",
    tie_embeddings=False,
    max_seq_len=4096,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    fl_client_axis="data",
    source="hf:stabilityai/stablelm-2-1_6b (family scaled per assignment)",
)
