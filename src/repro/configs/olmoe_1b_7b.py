"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060]."""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    d_ff=1024,                  # per-expert FFN width
    vocab_size=50304,
    attn=AttentionConfig(n_heads=16, n_kv_heads=16, head_dim=128,
                         rope_theta=10000.0, qk_norm=True),
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024,
                  router_aux_coef=0.01),
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    tie_embeddings=False,
    max_seq_len=4096,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    fl_client_axis="data",
    source="arXiv:2409.02060 (OLMoE: Open Mixture-of-Experts Language Models)",
)
