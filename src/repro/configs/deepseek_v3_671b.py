"""DeepSeek-V3-671B — MLA, 1 shared + 256 routed experts top-8 [arXiv:2412.19437].

MTP (multi-token prediction) is exposed as an optional extra head; the main
train_step uses next-token loss + an MTP auxiliary depth-1 head per the paper.
This arch is FSDP-placed: per-client copies are impossible on one pod, so the
FL client axis is "pod" (DESIGN.md §3).
"""
from repro.configs.base import AttentionConfig, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    d_ff=2048,                   # per-routed-expert width
    vocab_size=129280,
    attn=AttentionConfig(
        n_heads=128, n_kv_heads=128, head_dim=128,
        rope_theta=10000.0,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128)),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048,
                  n_shared_experts=1, n_dense_layers=3, dense_d_ff=18432,
                  capacity_factor=1.25, router_aux_coef=0.001),
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    tie_embeddings=False,
    max_seq_len=4096,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    fl_client_axis="pod",
    source="arXiv:2412.19437 (DeepSeek-V3 Technical Report)",
)
