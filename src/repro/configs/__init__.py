from repro.configs.base import (AttentionConfig, EncoderConfig, HybridConfig,
                                MLAConfig, ModelConfig, MoEConfig, SSMConfig,
                                VisionConfig, active_param_count, param_count,
                                reduced)
from repro.configs.registry import ARCH_IDS, all_configs, get_config, get_smoke_config

__all__ = [
    "AttentionConfig", "EncoderConfig", "HybridConfig", "MLAConfig",
    "ModelConfig", "MoEConfig", "SSMConfig", "VisionConfig",
    "active_param_count", "param_count", "reduced",
    "ARCH_IDS", "all_configs", "get_config", "get_smoke_config",
]
