"""Gemma2-27B — local/global alternating attention, logit softcaps [arXiv:2408.00118]."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    d_ff=36864,
    vocab_size=256000,
    attn=AttentionConfig(n_heads=32, n_kv_heads=16, head_dim=128,
                         rope_theta=10000.0,
                         attn_logit_softcap=50.0,
                         window=4096,
                         layer_pattern=("local", "global")),
    activation="geglu",
    gated_mlp=True,
    norm="rmsnorm",
    tie_embeddings=True,
    emb_scale_by_sqrt_dim=True,
    final_logit_softcap=30.0,
    max_seq_len=8192,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    fl_client_axis="data",
    source="arXiv:2408.00118 (Gemma 2: Improving Open LMs at Practical Size)",
)
