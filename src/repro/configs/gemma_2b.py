"""Gemma-2B — GeGLU, head_dim=256, MQA [arXiv:2403.08295]."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    d_ff=16384,
    vocab_size=256000,
    attn=AttentionConfig(n_heads=8, n_kv_heads=1, head_dim=256,
                         rope_theta=10000.0),
    activation="geglu",
    gated_mlp=True,
    norm="rmsnorm",
    tie_embeddings=True,
    emb_scale_by_sqrt_dim=True,
    max_seq_len=8192,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    fl_client_axis="data",
    source="arXiv:2403.08295 (Gemma: Open Models Based on Gemini)",
)
