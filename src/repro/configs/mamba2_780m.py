"""Mamba2-780M — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    d_ff=0,                      # attention-free, no MLP blocks
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=256),
    activation="silu",
    norm="rmsnorm",
    tie_embeddings=True,
    max_seq_len=8192,
    pos_embedding="none",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    fl_client_axis="data",
    source="arXiv:2405.21060 (Transformers are SSMs: Mamba-2 / SSD)",
)
