"""PaliGemma-3B — SigLIP vision frontend (STUB per assignment: input_specs()
provides patch embeddings) + Gemma-2B language backbone.  [arXiv:2407.07726]
"""
from repro.configs.base import AttentionConfig, ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    d_ff=16384,
    vocab_size=257216,
    attn=AttentionConfig(n_heads=8, n_kv_heads=1, head_dim=256,
                         rope_theta=10000.0),
    vision=VisionConfig(n_tokens=256, embed_dim=1152, frontend="stub"),
    activation="geglu",
    gated_mlp=True,
    norm="rmsnorm",
    tie_embeddings=True,
    emb_scale_by_sqrt_dim=True,
    max_seq_len=8192,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    fl_client_axis="data",
    source="arXiv:2407.07726 (PaliGemma: A versatile 3B VLM)",
)
