"""Configuration schema for all supported architectures.

Every architecture in the assigned pool is described by a single `ModelConfig`
dataclass; family-specific blocks (attention / MoE / SSM / encoder / vision) are
optional sub-configs.  Configs are pure data: model code consumes them, the
launcher shards by them, and the FL layer reads `fl_client_axis` to decide
client placement (see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0          # stablelm uses partial rotary
    attn_logit_softcap: Optional[float] = None   # gemma2: 50.0
    # sliding-window pattern: window size for "local" layers; None = full attn.
    window: Optional[int] = None
    # per-layer pattern, cycled: e.g. ("local", "global") for gemma2.
    layer_pattern: Tuple[str, ...] = ("global",)
    mla: Optional[MLAConfig] = None
    qk_norm: bool = False
    # window used when a full-attention arch must serve long_500k (DESIGN.md §6)
    long_context_window: int = 8192
    # decode-time MLA weight absorption (§Perf optimization; naive = faithful)
    mla_absorb: bool = False
    # sequence-parallel decode attention (§Perf): constrain logits to stay
    # sharded on the KV-sequence dim over "data" so GSPMD partitions the
    # softmax (partial max/sum + psum of per-head stats) instead of
    # gathering the cache.  Pairs with the serve_tp seq-sharded cache.
    seq_parallel: bool = False


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared_experts: int = 0
    # first `n_dense_layers` use a dense FFN instead (deepseek-v3: 3)
    n_dense_layers: int = 0
    dense_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # token-group size for GShard-style capacity dispatch (memory knob)
    group_size: int = 1024


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper) models; frontend is a stub."""
    n_layers: int
    n_ctx: int = 1500           # mel-frame positions after conv stub
    frontend: str = "stub"      # per spec: precomputed frame embeddings


@dataclass(frozen=True)
class VisionConfig:
    """Vision frontend for VLMs; a stub per spec (patch embeddings provided)."""
    n_tokens: int = 256
    embed_dim: int = 1152       # SigLIP-So400m width (projected to d_model)
    frontend: str = "stub"


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: shared attention block every `attn_every` SSM layers."""
    attn_every: int = 6
    shared_block: bool = True


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    hybrid: Optional[HybridConfig] = None
    activation: str = "silu"    # silu|geglu|gelu|relu2 (gated unless gelu/relu2)
    gated_mlp: bool = True
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    tie_embeddings: bool = True
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0
    emb_scale_by_sqrt_dim: bool = False          # gemma family
    max_seq_len: int = 8192
    pos_embedding: str = "rope"  # rope | learned | sinusoidal | none
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # FL placement (DESIGN.md §3): which mesh axis carries clients
    fl_client_axis: str = "data"    # "data" | "pod"
    # serving placement (§Perf, beyond-paper): weight-stationary 2D tensor
    # parallelism over ("data","model") for prefill/decode of pod-placed
    # giants — replaces the FSDP weight all-gather (which re-gathers the
    # full shard per decoded token) with tiny activation all-reduces.
    serve_tp: bool = False
    source: str = ""                # citation for the config

    # ---- helpers -------------------------------------------------------
    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def layer_kind(self, i: int) -> str:
        """Block kind at layer i: 'attn' | 'ssm' (hybrid interleave)."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            assert self.hybrid is not None
            every = self.hybrid.attn_every
            return "attn" if (i % every) == (every - 1) else "ssm"
        return "attn"

    def attn_window(self, i: int) -> Optional[int]:
        """Sliding window for attention layer i (None = full)."""
        if self.attn is None:
            return None
        pat = self.attn.layer_pattern
        kind = pat[i % len(pat)]
        return self.attn.window if kind == "local" else None

    def is_moe_layer(self, i: int) -> bool:
        return self.moe is not None and i >= self.moe.n_dense_layers

    def with_dtypes(self, param_dtype: str, compute_dtype: str) -> "ModelConfig":
        return replace(self, param_dtype=param_dtype, compute_dtype=compute_dtype)


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
            vocab: int = 512, max_seq: int = 256) -> ModelConfig:
    """A smoke-test variant of the same family: <=2 layers, d_model<=512, <=4 experts.

    Keeps the family wiring (MoE routing, SSD scan, hybrid pattern, MLA, ...)
    while shrinking every dimension so one forward/train step runs on CPU.
    """
    d_model = min(d_model, 512)
    updates = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, n_layers),
        d_model=d_model,
        d_ff=min(cfg.d_ff, 4 * d_model) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, vocab),
        max_seq_len=min(cfg.max_seq_len, max_seq),
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.attn is not None:
        n_heads = min(cfg.attn.n_heads, 4)
        n_kv = max(1, min(cfg.attn.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        mla = None
        if cfg.attn.mla is not None:
            mla = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                            qk_nope_head_dim=16, qk_rope_head_dim=8,
                            v_head_dim=16)
        head_dim = d_model // n_heads if mla is None else cfg.attn.head_dim
        updates["attn"] = replace(
            cfg.attn, n_heads=n_heads, n_kv_heads=n_kv,
            head_dim=head_dim, mla=mla,
            window=None if cfg.attn.window is None else 64,
            long_context_window=64)
    if cfg.moe is not None:
        updates["moe"] = replace(
            cfg.moe, n_experts=4, top_k=2, d_expert=2 * d_model,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            n_dense_layers=min(cfg.moe.n_dense_layers, 1),
            dense_d_ff=min(cfg.moe.dense_d_ff, 4 * d_model),
            group_size=64)
    if cfg.ssm is not None:
        updates["ssm"] = replace(cfg.ssm, d_state=16, head_dim=16, chunk_size=32)
    if cfg.encoder is not None:
        updates["encoder"] = replace(cfg.encoder, n_layers=min(cfg.encoder.n_layers, 2),
                                     n_ctx=32)
    if cfg.vision is not None:
        updates["vision"] = replace(cfg.vision, n_tokens=8, embed_dim=64)
    if cfg.hybrid is not None:
        updates["hybrid"] = replace(cfg.hybrid, attn_every=2)
    return replace(cfg, **updates)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (used by memory planning + roofline MODEL_FLOPS)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    total = V * d  # embedding
    if not cfg.tie_embeddings:
        total += V * d
    for i in range(L):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            total += _attn_params(cfg)
            total += _ffn_params(cfg, i)
        else:
            total += _ssm_params(cfg)
        total += 2 * d  # two norms
    if cfg.family == "hybrid" and cfg.hybrid and cfg.hybrid.shared_block:
        # shared attention block counted once (above loop counted per use; fix)
        n_attn = sum(1 for i in range(L) if cfg.layer_kind(i) == "attn")
        if n_attn > 1:
            total -= (n_attn - 1) * (_attn_params(cfg) + _ffn_params(cfg, 0))
    if cfg.encoder is not None:
        enc = cfg.encoder.n_layers * (_attn_params(cfg) + _ffn_params(cfg, 0) + 4 * d)
        # cross attention in each decoder layer
        enc += L * _attn_params(cfg)
        total += enc
    if cfg.vision is not None:
        total += cfg.vision.embed_dim * d  # projector
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: only top_k + shared experts count)."""
    if cfg.moe is None:
        return param_count(cfg)
    total = param_count(cfg)
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_expert
    n_moe_layers = sum(1 for i in range(cfg.n_layers) if cfg.is_moe_layer(i))
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return total - inactive


def _attn_params(cfg: ModelConfig) -> int:
    a = cfg.attn
    d = cfg.d_model
    if a is None:
        return 0
    if a.mla is not None:
        mm = a.mla
        qk_dim = mm.qk_nope_head_dim + mm.qk_rope_head_dim
        n = d * mm.q_lora_rank + mm.q_lora_rank * a.n_heads * qk_dim
        n += d * (mm.kv_lora_rank + mm.qk_rope_head_dim)
        n += mm.kv_lora_rank * a.n_heads * (mm.qk_nope_head_dim + mm.v_head_dim)
        n += a.n_heads * mm.v_head_dim * d
        return n
    q = d * a.n_heads * a.head_dim
    kv = 2 * d * a.n_kv_heads * a.head_dim
    o = a.n_heads * a.head_dim * d
    return q + kv + o


def _ffn_params(cfg: ModelConfig, i: int) -> int:
    d = cfg.d_model
    if cfg.moe is not None and cfg.is_moe_layer(i):
        m = cfg.moe
        n = m.n_experts * 3 * d * m.d_expert
        n += m.n_shared_experts * 3 * d * m.d_expert
        n += d * m.n_experts  # router
        return n
    if cfg.moe is not None:
        return 3 * d * cfg.moe.dense_d_ff
    mult = 3 if cfg.gated_mlp else 2
    return mult * d * cfg.d_ff


def _ssm_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    d = cfg.d_model
    if s is None:
        return 0
    d_in = s.expand * d
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    n = d * (2 * d_in + 2 * s.n_groups * s.d_state + n_heads)  # in_proj
    n += conv_dim * s.d_conv                                    # conv1d
    n += 2 * n_heads                                            # A_log, D
    n += n_heads                                                # dt_bias
    n += d_in * d                                               # out_proj
    n += d_in                                                   # gated norm
    return n
