"""Architecture registry: --arch <id> resolution for launchers and tests."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig, reduced

_MODULES = {
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "gemma-2b": "repro.configs.gemma_2b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "paligemma-3b": "repro.configs.paligemma_3b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return reduced(get_config(arch))


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
