"""Whisper-tiny — enc-dec transformer backbone; conv/mel frontend is a STUB
per the assignment: input_specs() provides precomputed frame embeddings
(batch, 1500, 384).  [arXiv:2212.04356]
"""
from repro.configs.base import AttentionConfig, EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                  # decoder layers
    d_model=384,
    d_ff=1536,
    vocab_size=51865,
    attn=AttentionConfig(n_heads=6, n_kv_heads=6, head_dim=64),
    encoder=EncoderConfig(n_layers=4, n_ctx=1500, frontend="stub"),
    activation="gelu",
    gated_mlp=False,
    norm="layernorm",
    tie_embeddings=True,
    pos_embedding="learned",
    max_seq_len=4096,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    fl_client_axis="data",
    source="arXiv:2212.04356 (Robust Speech Recognition / Whisper)",
)
