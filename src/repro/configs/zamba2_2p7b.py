"""Zamba2-2.7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import AttentionConfig, HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    d_ff=10240,                  # shared attention block MLP width
    vocab_size=32000,
    attn=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=80,
                         rope_theta=10000.0),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=256),
    hybrid=HybridConfig(attn_every=6, shared_block=True),
    activation="geglu",
    gated_mlp=True,
    norm="rmsnorm",
    tie_embeddings=True,
    max_seq_len=4096,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    fl_client_axis="data",
    source="arXiv:2411.15242 (Zamba2 suite: hybrid Mamba2+shared-attention)",
)
