"""Nemotron-4-340B — GQA (8 kv heads), squared-ReLU MLP [arXiv:2402.16819].

FSDP-placed giant dense model: FL client axis is "pod" (DESIGN.md §3).
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    d_ff=73728,
    vocab_size=256000,
    attn=AttentionConfig(n_heads=96, n_kv_heads=8, head_dim=192,
                         rope_theta=10000.0),
    activation="relu2",          # squared ReLU, non-gated
    gated_mlp=False,
    norm="layernorm",
    tie_embeddings=False,
    max_seq_len=4096,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    fl_client_axis="pod",
    source="arXiv:2402.16819 (Nemotron-4 340B Technical Report)",
)
