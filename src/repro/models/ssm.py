"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
intra-chunk blocks + a linear inter-chunk state recurrence (lax.scan over
chunks).  Decode is the O(1) state update — the reason SSM archs serve
long_500k with no KV cache at all (DESIGN.md §6).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_apply, dense_init


class SSMCache(NamedTuple):
    conv: jnp.ndarray     # (B, d_conv-1, conv_dim) trailing conv inputs
    state: jnp.ndarray    # (B, nh, head_dim, d_state)


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    s = cfg.ssm
    d_inner, nh, conv_dim = ssm_dims(cfg)
    return SSMCache(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        state=jnp.zeros((batch, nh, s.head_dim, s.d_state), dtype))


def ssm_init(key, cfg: ModelConfig):
    s = cfg.ssm
    d, dt = cfg.d_model, cfg.pdtype
    d_inner, nh, conv_dim = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    # in_proj emits [z, xBC, dt]
    d_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + nh
    a_init = jnp.log(jnp.linspace(1.0, 16.0, nh))
    dt_init = jnp.log(jnp.exp(
        jnp.exp(jax.random.uniform(ks[2], (nh,)) *
                (math.log(s.dt_max) - math.log(s.dt_min)) + math.log(s.dt_min))
        ) - 1.0 + 1e-9)  # inverse softplus of sampled dt
    return {
        "in_proj": dense_init(ks[0], d, d_proj, dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32)
                   / math.sqrt(s.d_conv)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": a_init.astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_init.astype(jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), dt),
        "out_proj": dense_init(ks[3], d_inner, d, dt),
    }


def _causal_conv(x, w, b, carry: Optional[jnp.ndarray]):
    """x: (B,S,C); w: (K,C) depthwise; carry: (B,K-1,C) previous inputs."""
    K = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    new_carry = xp[:, -(K - 1):, :] if K > 1 else carry
    return jax.nn.silu(out + b[None, None, :]), new_carry


def _segsum(dA):
    """dA: (..., c, h) -> L: (..., h, c, c), L[i,j]=exp(sum_{j<k<=i} dA_k), i>=j."""
    cs = jnp.cumsum(dA, axis=-2)                               # (..., c, h)
    cs = jnp.moveaxis(cs, -1, -2)                              # (..., h, c)
    diff = cs[..., :, None] - cs[..., None, :]                 # (..., h, c, c)
    c = dA.shape[-2]
    mask = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_scan(x, dt, A, B, C, chunk: int,
             init_state: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.  x: (b,s,h,p); dt: (b,s,h); A: (h,); B,C: (b,s,g,n).

    Returns (y (b,s,h,p), final_state (b,h,p,n)).  All math in float32.
    """
    b, s, h, p = x.shape
    g = B.shape[2]
    hg = h // g
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        zf = lambda a: jnp.concatenate(
            [a, jnp.zeros((b, pad) + a.shape[2:], a.dtype)], axis=1)
        x, dt, B, C = zf(x), zf(dt), zf(B), zf(C)
    nc = x.shape[1] // c
    xr = x.reshape(b, nc, c, h, p).astype(jnp.float32)
    dtr = dt.reshape(b, nc, c, h).astype(jnp.float32)
    Br = B.reshape(b, nc, c, g, B.shape[-1]).astype(jnp.float32)
    Cr = C.reshape(b, nc, c, g, C.shape[-1]).astype(jnp.float32)

    dA = dtr * A[None, None, None, :]                          # (b,nc,c,h)
    xdt = xr * dtr[..., None]                                  # (b,nc,c,h,p)
    L = _segsum(dA)                                            # (b,nc,h,c,c)
    # intra-chunk: Y[i] = sum_{j<=i} (C_i . B_j) L_ij xdt_j
    xg = xdt.reshape(b, nc, c, g, hg, p)
    Lg = L.reshape(b, nc, g, hg, c, c)                         # b l g k i j
    cb = jnp.einsum("blign,bljgn->bligj", Cr, Br)              # (b,nc,c,g,c)
    y_diag = jnp.einsum("bligj,blgkij,bljgkp->bligkp", cb, Lg, xg)
    # ^ dims: l chunk, i/j intra positions, g group, k head-in-group, p head dim
    y_diag = y_diag.reshape(b, nc, c, h, p)

    # chunk states: S_l = sum_j exp(cs_last - cs_j) xdt_j B_j^T  (b,nc,h,p,n)
    cs = jnp.cumsum(dA, axis=2)
    decay = jnp.exp(cs[:, :, -1:, :] - cs)                     # (b,nc,c,h)
    decay_g = decay.reshape(b, nc, c, g, hg)
    states = jnp.einsum("blcgk,blcgkp,blcgn->blgkpn", decay_g, xg, Br)
    states = states.reshape(b, nc, h, p, states.shape[-1])

    # inter-chunk recurrence
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))                 # (b,nc,h)
    s0 = (jnp.zeros_like(states[:, 0]) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                                      # emit state BEFORE chunk

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)              # (b,nc,h,p,n)

    # inter-chunk output: Y_off[i] = exp(cs_i) C_i . S_prev
    pg = prev_states.reshape(b, nc, g, hg, p, prev_states.shape[-1])
    y_off = jnp.einsum("blign,blgkpn->bligkp", Cr, pg)
    y_off = y_off.reshape(b, nc, c, h, p) * jnp.exp(cs)[..., None]
    y = (y_diag + y_off).reshape(b, nc * c, h, p)
    if pad:
        y = y[:, :s]
    return y, final


def ssd_decode_step(x, dt, A, B, C, state):
    """Single-token state update.  x: (b,h,p); dt: (b,h); B,C: (b,g,n);
    state: (b,h,p,n) -> (y (b,h,p), new_state)."""
    b, h, p = x.shape
    g = B.shape[1]
    hg = h // g
    dA = jnp.exp(dt.astype(jnp.float32) * A[None, :])          # (b,h)
    xdt = (x * dt[..., None]).astype(jnp.float32)
    Bh = jnp.repeat(B.astype(jnp.float32), hg, axis=1)         # (b,h,n)
    Ch = jnp.repeat(C.astype(jnp.float32), hg, axis=1)
    new_state = state.astype(jnp.float32) * dA[..., None, None] \
        + xdt[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y, new_state


def _gated_rmsnorm(y, z, scale):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + 1e-6) * (1.0 + scale.astype(jnp.float32))


def ssm_apply(params, cfg: ModelConfig, x,
              cache: Optional[SSMCache] = None, *, decode: bool = False):
    """Mamba2 block.  x: (B,S,d) -> (y, new_cache)."""
    s, cd = cfg.ssm, cfg.cdtype
    d_inner, nh, conv_dim = ssm_dims(cfg)
    B_, S_, _ = x.shape
    proj = dense_apply(params["in_proj"], x, cd)
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner:d_inner + conv_dim]
    dt_raw = proj[..., d_inner + conv_dim:]
    xBC, new_conv = _causal_conv(xBC, params["conv_w"].astype(cd),
                                 params["conv_b"].astype(cd),
                                 cache.conv if cache is not None else None)
    xs = xBC[..., :d_inner]
    Bc = xBC[..., d_inner:d_inner + s.n_groups * s.d_state]
    Cc = xBC[..., d_inner + s.n_groups * s.d_state:]
    Bc = Bc.reshape(B_, S_, s.n_groups, s.d_state)
    Cc = Cc.reshape(B_, S_, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(B_, S_, nh, s.head_dim)

    if decode:
        assert S_ == 1 and cache is not None
        y, new_state = ssd_decode_step(
            xh[:, 0].astype(jnp.float32), dt[:, 0], A, Bc[:, 0], Cc[:, 0],
            cache.state)
        y = y[:, None]
    else:
        init = cache.state if cache is not None else None
        y, new_state = ssd_scan(xh, dt, A, Bc, Cc, s.chunk_size, init)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, S_, d_inner)
    y = _gated_rmsnorm(y, z, params["norm_scale"]).astype(cd)
    out = dense_apply(params["out_proj"], y, cd)
    new_cache = SSMCache(conv=new_conv, state=new_state.astype(
        cache.state.dtype if cache is not None else jnp.float32))
    return out, new_cache
