"""Decoder-stack assembly for dense / moe / ssm / hybrid / vlm families.

The whisper enc-dec backbone reuses these blocks from ``encdec.py``.  All
entry points are functional and jit/pjit-friendly:

    params                    = init_params(key, cfg)
    logits, aux               = forward(params, cfg, batch)
    loss, metrics             = loss_fn(params, cfg, batch)
    caches                    = make_caches(cfg, batch, cache_len, dtype)
    logits, caches            = prefill(params, cfg, tokens, caches)
    logits, caches            = decode_step(params, cfg, token, caches, pos)
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import KVCache, attn_init, attention, init_cache
from repro.models.layers import (dense_apply, dense_init, embedding_init,
                                 embedding_lookup, mlp_apply, mlp_init,
                                 norm_apply, norm_init, softcap)
from repro.models.moe import moe_apply, moe_init


# ---------------------------------------------------------------------------
# init


def _layer_init(key, cfg: ModelConfig, i: int) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    kind = cfg.layer_kind(i)
    p: Dict[str, Any] = {"norm1": norm_init(cfg.norm, cfg.d_model, cfg.pdtype)}
    if kind == "ssm":
        p["ssm"] = ssm_mod.ssm_init(ks[0], cfg)
        return p
    p["norm2"] = norm_init(cfg.norm, cfg.d_model, cfg.pdtype)
    if cfg.family == "hybrid" and cfg.hybrid.shared_block:
        return p  # attn/mlp weights live in params["shared_attn"]
    p["attn"] = attn_init(ks[0], cfg)
    if cfg.is_moe_layer(i):
        p["moe"] = moe_init(ks[1], cfg)
    elif cfg.moe is not None:   # deepseek dense-first layers
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.moe.dense_d_ff,
                            cfg.gated_mlp, cfg.pdtype)
    elif cfg.d_ff:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                            cfg.pdtype)
    return p


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.family == "audio":
        from repro.models.encdec import encdec_init
        return encdec_init(key, cfg)
    ks = jax.random.split(key, cfg.n_layers + 6)
    p: Dict[str, Any] = {
        "embed": embedding_init(ks[0], cfg.vocab_size, cfg.d_model, cfg.pdtype),
        "layers": [_layer_init(ks[1 + i], cfg, i) for i in range(cfg.n_layers)],
        "final_norm": norm_init(cfg.norm, cfg.d_model, cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[-1], cfg.d_model, cfg.vocab_size,
                                  cfg.pdtype)
    if cfg.family == "hybrid" and cfg.hybrid.shared_block:
        p["shared_attn"] = {
            "attn": attn_init(ks[-2], cfg),
            "mlp": mlp_init(ks[-3], cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                            cfg.pdtype),
        }
    if cfg.family == "vlm":
        p["vision_proj"] = dense_init(ks[-4], cfg.vision.embed_dim,
                                      cfg.d_model, cfg.pdtype)
    if cfg.pos_embedding == "learned":
        p["pos_emb"] = embedding_init(ks[-5], cfg.max_seq_len, cfg.d_model,
                                      cfg.pdtype)
    return p


# ---------------------------------------------------------------------------
# caches


def make_caches(cfg: ModelConfig, batch: int, cache_len: int, dtype, *,
                long_context: bool = False) -> List[Any]:
    """Per-layer decode caches: KVCache for attention, SSMCache for SSM.

    With ``long_context`` every attention layer's cache is bounded by the
    sliding window (ring buffer) — the sub-quadratic long_500k adaptation.
    """
    if cfg.family == "audio":
        from repro.models.encdec import encdec_make_caches
        w = cfg.attn.long_context_window if long_context else cache_len
        return encdec_make_caches(cfg, batch, min(cache_len, w), dtype)
    caches: List[Any] = []
    for i in range(cfg.n_layers):
        if cfg.layer_kind(i) == "ssm":
            caches.append(ssm_mod.init_ssm_cache(cfg, batch, dtype))
        else:
            w = cfg.attn_window(i)
            if long_context:
                w = min(w, cfg.attn.long_context_window) if w \
                    else cfg.attn.long_context_window
            clen = min(cache_len, w) if w is not None else cache_len
            caches.append(init_cache(cfg, batch, clen, dtype))
    return caches


# ---------------------------------------------------------------------------
# blocks


def _block_apply(params, shared, cfg: ModelConfig, i: int, x, positions, *,
                 cache=None, decode=False, prefix_len=0,
                 long_context=False) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
    """Pre-norm residual block.  Returns (x, aux_loss, new_cache)."""
    cd = cfg.cdtype
    aux = jnp.zeros((), jnp.float32)
    kind = cfg.layer_kind(i)
    h = norm_apply(cfg.norm, params["norm1"], x, cd)
    if kind == "ssm":
        y, new_cache = ssm_mod.ssm_apply(params["ssm"], cfg, h, cache,
                                         decode=decode)
        return x + y, aux, new_cache
    attn_params = shared["attn"] if shared is not None else params["attn"]
    window = cfg.attn_window(i)
    if long_context:
        window = min(window, cfg.attn.long_context_window) if window \
            else cfg.attn.long_context_window
    y, new_cache = attention(attn_params, cfg, h, positions, cache=cache,
                             window=window, prefix_len=prefix_len)
    x = x + y
    h = norm_apply(cfg.norm, params["norm2"], x, cd)
    if "moe" in params:
        y, aux = moe_apply(params["moe"], cfg, h)
    else:
        mlp_params = shared["mlp"] if shared is not None else params["mlp"]
        y = mlp_apply(mlp_params, h, cfg.activation, cd)
    return x + y, aux, new_cache


def _embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                  positions=None):
    """Token (+vision) embedding.  Returns (x, positions, prefix_len)."""
    cd = cfg.cdtype
    tokens = batch["tokens"]
    x = embedding_lookup(params["embed"], tokens, cd)
    if cfg.emb_scale_by_sqrt_dim:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cd)
    prefix_len = 0
    if cfg.family == "vlm" and "vision_embeds" in batch:
        v = dense_apply(params["vision_proj"], batch["vision_embeds"].astype(cd), cd)
        x = jnp.concatenate([v, x], axis=1)
        prefix_len = v.shape[1]
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos_embedding == "learned":
        x = x + jnp.take(params["pos_emb"], positions, axis=0).astype(cd)
    return x, positions, prefix_len


def _unembed(params, cfg: ModelConfig, x):
    cd = cfg.cdtype
    x = norm_apply(cfg.norm, params["final_norm"], x, cd)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cd),
                            preferred_element_type=jnp.float32)
    else:
        logits = dense_apply(params["lm_head"], x, jnp.float32)
    return softcap(logits, cfg.final_logit_softcap)


# sequence-chunked cross entropy: full (B,S,V) logits are never live — the
# vocab matmul + log-softmax runs per chunk under remat (V=256k at S=4k
# would otherwise dominate train-step memory).
CE_CHUNK = 512


def chunked_ce(params, cfg: ModelConfig, hidden, targets, *,
               chunk: int = CE_CHUNK):
    """hidden: (B, S, d) pre-final-norm; targets: (B, S) next tokens aligned
    with hidden positions (already shifted).  Returns mean CE."""
    B, S, _ = hidden.shape
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    nch = hidden.shape[1] // chunk
    hs = hidden.reshape(B, nch, chunk, -1).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, nch, chunk).transpose(1, 0, 2)
    S_pad = hidden.shape[1]

    def body(carry, xs):
        h, t, idx = xs
        logits = _unembed(params, cfg, h)
        lps = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lps, t[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        # mask padded tail positions
        pos = idx * chunk + jnp.arange(chunk)[None, :]
        nll = jnp.where(pos < S, nll, 0.0)
        return carry + jnp.sum(nll), None

    idxs = jnp.arange(nch)
    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                            (hs, ts, idxs), unroll=True)
    return total / (B * S)


# ---------------------------------------------------------------------------
# entry points


def forward_hidden(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stack forward up to (but excluding) final norm/unembed."""
    x, positions, prefix_len = _embed_inputs(params, cfg, batch)
    shared = params.get("shared_attn")
    aux_total = jnp.zeros((), jnp.float32)
    for i, lp in enumerate(params["layers"]):
        sh = shared if (shared is not None and cfg.layer_kind(i) == "attn") else None
        x, aux, _ = _block_apply(lp, sh, cfg, i, x, positions,
                                 prefix_len=prefix_len)
        aux_total = aux_total + aux
    return x, aux_total


def forward(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence training forward.  Returns (logits, aux_loss)."""
    x, aux_total = forward_hidden(params, cfg, batch)
    return _unembed(params, cfg, x), aux_total


def _ce_from_hidden(params, cfg: ModelConfig, hidden, tokens):
    """Next-token CE over the text positions of `hidden` (vision prefix
    dropped), sequence-chunked so full logits never materialize."""
    n_text = tokens.shape[1]
    h = hidden[:, -n_text:][:, :-1]
    targets = tokens[:, 1:]
    return chunked_ce(params, cfg, h, targets)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross-entropy (+ MoE aux).  Loss only over text tokens."""
    if cfg.family == "audio":
        from repro.models.encdec import encdec_loss_fn
        return encdec_loss_fn(params, cfg, batch)
    hidden, aux = forward_hidden(params, cfg, batch)
    ce = _ce_from_hidden(params, cfg, hidden, batch["tokens"])
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "loss": loss}


def prefill(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            caches: List[Any], *, long_context: bool = False):
    """Process a full prompt, filling caches.  Returns (last_logits, caches)."""
    if cfg.family == "audio":
        from repro.models.encdec import encdec_prefill
        return encdec_prefill(params, cfg, batch, caches)
    x, positions, prefix_len = _embed_inputs(params, cfg, batch)
    shared = params.get("shared_attn")
    new_caches = []
    for i, lp in enumerate(params["layers"]):
        sh = shared if (shared is not None and cfg.layer_kind(i) == "attn") else None
        x, _, c = _block_apply(lp, sh, cfg, i, x, positions, cache=caches[i],
                               prefix_len=prefix_len, long_context=long_context)
        new_caches.append(c)
    logits = _unembed(params, cfg, x[:, -1:])
    return logits, new_caches


def decode_step(params, cfg: ModelConfig, token, caches: List[Any], pos, *,
                long_context: bool = False):
    """One decode step.  token: (B,1) int32; pos: (B,) absolute position.
    Returns (logits (B,1,V), new_caches)."""
    if cfg.family == "audio":
        from repro.models.encdec import encdec_decode_step
        return encdec_decode_step(params, cfg, token, caches, pos)
    positions = pos[:, None].astype(jnp.int32)
    x, positions, _ = _embed_inputs(params, cfg, {"tokens": token}, positions)
    shared = params.get("shared_attn")
    new_caches = []
    for i, lp in enumerate(params["layers"]):
        sh = shared if (shared is not None and cfg.layer_kind(i) == "attn") else None
        x, _, c = _block_apply(lp, sh, cfg, i, x, positions, cache=caches[i],
                               decode=True, long_context=long_context)
        new_caches.append(c)
    return _unembed(params, cfg, x), new_caches
