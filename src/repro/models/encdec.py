"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, n_ctx, d_model) supplied by input_specs().
Encoder: bidirectional self-attention, sinusoidal positions.  Decoder:
causal self-attention + cross-attention, learned positions.  Cross K/V are
computed once at prefill and cached.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (KVCache, attn_init, attention, init_cache,
                                    _sdpa_chunked, mask_bias)
from repro.models.layers import (dense_apply, embedding_init, mlp_apply,
                                 mlp_init, norm_apply, norm_init,
                                 sinusoidal_positions)
from repro.models.transformer import _unembed


def encdec_init(key, cfg: ModelConfig) -> Dict[str, Any]:
    enc_cfg = cfg.encoder
    # enc layers take 2 keys, dec layers 3 (attn, cross, mlp), +2 embeddings
    ks = jax.random.split(key, 2 * enc_cfg.n_layers + 3 * cfg.n_layers + 4)
    ki = iter(range(len(ks)))
    d, dt = cfg.d_model, cfg.pdtype

    def enc_layer():
        return {
            "norm1": norm_init(cfg.norm, d, dt),
            "attn": attn_init(ks[next(ki)], cfg),
            "norm2": norm_init(cfg.norm, d, dt),
            "mlp": mlp_init(ks[next(ki)], d, cfg.d_ff, cfg.gated_mlp, dt),
        }

    def dec_layer():
        return {
            "norm1": norm_init(cfg.norm, d, dt),
            "attn": attn_init(ks[next(ki)], cfg),
            "norm_x": norm_init(cfg.norm, d, dt),
            "cross": attn_init(ks[next(ki)], cfg, cross=True),
            "norm2": norm_init(cfg.norm, d, dt),
            "mlp": mlp_init(ks[next(ki)], d, cfg.d_ff, cfg.gated_mlp, dt),
        }

    return {
        "embed": embedding_init(ks[next(ki)], cfg.vocab_size, d, dt),
        "pos_emb": embedding_init(ks[next(ki)], cfg.max_seq_len, d, dt),
        "enc_layers": [enc_layer() for _ in range(enc_cfg.n_layers)],
        "enc_final_norm": norm_init(cfg.norm, d, dt),
        "layers": [dec_layer() for _ in range(cfg.n_layers)],
        "final_norm": norm_init(cfg.norm, d, dt),
    }


def encode(params, cfg: ModelConfig, audio_embeds) -> jnp.ndarray:
    """audio_embeds: (B, n_ctx, d) stub frontend output."""
    cd = cfg.cdtype
    x = audio_embeds.astype(cd)
    pos = sinusoidal_positions(x.shape[1], cfg.d_model).astype(cd)
    x = x + pos[None]
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    for lp in params["enc_layers"]:
        h = norm_apply(cfg.norm, lp["norm1"], x, cd)
        y, _ = attention(lp["attn"], cfg, h, positions, mask_kind="full")
        x = x + y
        h = norm_apply(cfg.norm, lp["norm2"], x, cd)
        x = x + mlp_apply(lp["mlp"], h, cfg.activation, cd)
    return norm_apply(cfg.norm, params["enc_final_norm"], x, cd)


def _dec_block(lp, cfg: ModelConfig, x, positions, enc_out, cache, *,
               window=None):
    cd = cfg.cdtype
    h = norm_apply(cfg.norm, lp["norm1"], x, cd)
    y, cache = attention(lp["attn"], cfg, h, positions, cache=cache,
                         window=window)
    x = x + y
    h = norm_apply(cfg.norm, lp["norm_x"], x, cd)
    y, _ = attention(lp["cross"], cfg, h, positions, kv_input=enc_out)
    x = x + y
    h = norm_apply(cfg.norm, lp["norm2"], x, cd)
    return x + mlp_apply(lp["mlp"], h, cfg.activation, cd), cache


def _dec_embed(params, cfg, tokens, positions):
    cd = cfg.cdtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    x = x + jnp.take(params["pos_emb"],
                     jnp.clip(positions, 0, cfg.max_seq_len - 1),
                     axis=0).astype(cd)
    return x


def decode_hidden(params, cfg: ModelConfig, tokens, enc_out) -> jnp.ndarray:
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _dec_embed(params, cfg, tokens, positions)
    for lp in params["layers"]:
        x, _ = _dec_block(lp, cfg, x, positions, enc_out, None)
    return x


def decode(params, cfg: ModelConfig, tokens, enc_out) -> jnp.ndarray:
    return _unembed(params, cfg, decode_hidden(params, cfg, tokens, enc_out))


def encdec_loss_fn(params, cfg: ModelConfig, batch):
    from repro.models.transformer import _ce_from_hidden
    enc_out = encode(params, cfg, batch["audio_embeds"])
    hidden = decode_hidden(params, cfg, batch["tokens"], enc_out)
    ce = _ce_from_hidden(params, cfg, hidden, batch["tokens"])
    return ce, {"ce": ce, "aux": jnp.zeros(()), "loss": ce}


# ---------------------------------------------------------------------------
# serving: cross-KV cached at prefill


def encdec_make_caches(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    enc_ctx = cfg.encoder.n_ctx
    a = cfg.attn
    caches = []
    for _ in range(cfg.n_layers):
        caches.append({
            "self": init_cache(cfg, batch, cache_len, dtype),
            "cross_k": jnp.zeros((batch, enc_ctx, a.n_kv_heads, a.head_dim), dtype),
            "cross_v": jnp.zeros((batch, enc_ctx, a.n_kv_heads, a.head_dim), dtype),
        })
    return caches


def _cross_kv(lp, cfg, enc_out):
    cd = cfg.cdtype
    k = dense_apply(lp["cross"]["wk"], enc_out, cd)
    v = dense_apply(lp["cross"]["wv"], enc_out, cd)
    return k, v


def _cross_attend(lp, cfg, h, ck, cv):
    cd = cfg.cdtype
    q = dense_apply(lp["cross"]["wq"], h, cd)
    B, Sq = h.shape[:2]
    q_pos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    k_pos = jnp.broadcast_to(jnp.arange(ck.shape[1], dtype=jnp.int32),
                             (B, ck.shape[1]))
    out = _sdpa_chunked(q, ck, cv, q_pos, k_pos, kind="full", window=None,
                        prefix_len=0, cap=cfg.attn.attn_logit_softcap,
                        cdtype=cd)
    out = out.reshape(*out.shape[:2], -1)
    return dense_apply(lp["cross"]["wo"], out, cd)


def encdec_prefill(params, cfg: ModelConfig, batch, caches, *,
                   long_context: bool = False):
    """Encode audio, fill cross-KV caches, run prompt tokens through decoder."""
    enc_out = encode(params, cfg, batch["audio_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _dec_embed(params, cfg, tokens, positions)
    cd = cfg.cdtype
    new_caches = []
    window = cfg.attn.long_context_window if long_context else None
    for lp, c in zip(params["layers"], caches):
        ck, cv = _cross_kv(lp, cfg, enc_out)
        h = norm_apply(cfg.norm, lp["norm1"], x, cd)
        y, sc = attention(lp["attn"], cfg, h, positions, cache=c["self"],
                          window=window)
        x = x + y
        h = norm_apply(cfg.norm, lp["norm_x"], x, cd)
        x = x + _cross_attend(lp, cfg, h, ck, cv)
        h = norm_apply(cfg.norm, lp["norm2"], x, cd)
        x = x + mlp_apply(lp["mlp"], h, cfg.activation, cd)
        new_caches.append({"self": sc, "cross_k": ck, "cross_v": cv})
    return _unembed(params, cfg, x[:, -1:]), new_caches


def encdec_decode_step(params, cfg: ModelConfig, token, caches, pos, *,
                       long_context: bool = False):
    positions = pos[:, None].astype(jnp.int32)
    x = _dec_embed(params, cfg, token, positions)
    cd = cfg.cdtype
    new_caches = []
    window = cfg.attn.long_context_window if long_context else None
    for lp, c in zip(params["layers"], caches):
        h = norm_apply(cfg.norm, lp["norm1"], x, cd)
        y, sc = attention(lp["attn"], cfg, h, positions, cache=c["self"],
                          window=window)
        x = x + y
        h = norm_apply(cfg.norm, lp["norm_x"], x, cd)
        x = x + _cross_attend(lp, cfg, h, c["cross_k"], c["cross_v"])
        h = norm_apply(cfg.norm, lp["norm2"], x, cd)
        x = x + mlp_apply(lp["mlp"], h, cfg.activation, cd)
        new_caches.append({"self": sc, "cross_k": c["cross_k"],
                           "cross_v": c["cross_v"]})
    return _unembed(params, cfg, x), new_caches
