"""Shared building blocks: norms, dense layers, activations, RoPE, MLPs.

All modules are functional: ``*_init(key, ...) -> params`` (nested dicts) and
``*_apply(params, x, ...) -> y``.  Params are stored in ``param_dtype`` and
cast to ``compute_dtype`` at use; norm/softmax statistics run in float32.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initializers


def _normal(key, shape, dtype, stddev):
    return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def dense_init(key, d_in: int, d_out, dtype, *, scale: float = 1.0):
    """Truncated-normal-ish fan-in init; d_out may be a tuple (fused heads)."""
    shape = (d_in,) + (d_out if isinstance(d_out, tuple) else (d_out,))
    return _normal(key, shape, dtype, scale / math.sqrt(d_in))


def dense_apply(w, x, cdtype):
    """x @ w where w may have >2 dims: (d_in, a, b, ...) contracts x's last dim."""
    w = w.astype(cdtype)
    x = x.astype(cdtype)
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=cdtype)


# ---------------------------------------------------------------------------
# norms


def norm_init(kind: str, dim: int, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((dim,), dtype)}   # gemma-style (1+scale)
    if kind == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    raise ValueError(kind)


def norm_apply(kind: str, params, x, cdtype):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6)
        y = y * (1.0 + params["scale"].astype(jnp.float32))
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        raise ValueError(kind)
    return y.astype(cdtype)


# ---------------------------------------------------------------------------
# activations


def activation(kind: str, x):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "geglu":          # the gated branch uses gelu
        return jax.nn.gelu(x, approximate=True)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu2":          # squared ReLU (Nemotron / Primer)
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# MLP (gated or plain)


def mlp_init(key, d_model: int, d_ff: int, gated: bool, dtype):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d_model, d_ff, dtype),
         "down": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp_apply(params, x, act: str, cdtype):
    up = dense_apply(params["up"], x, cdtype)
    if "gate" in params:
        gate = activation(act, dense_apply(params["gate"], x, cdtype))
        h = gate * up
    else:
        h = activation(act, up)
    return dense_apply(params["down"], h, cdtype)


# ---------------------------------------------------------------------------
# embeddings / positions


def embedding_init(key, vocab: int, dim: int, dtype):
    return _normal(key, (vocab, dim), dtype, 1.0 / math.sqrt(dim))


def embedding_lookup(table, tokens, cdtype):
    return jnp.take(table, tokens, axis=0).astype(cdtype)


def sinusoidal_positions(n_ctx: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(n_ctx)[:, None].astype(jnp.float32)
    i = jnp.arange(dim // 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def rope_frequencies(head_dim: int, theta: float, fraction: float = 1.0):
    rot_dim = int(head_dim * fraction)
    rot_dim -= rot_dim % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv, rot_dim


def apply_rope(x, positions, theta: float, fraction: float = 1.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    inv, rot_dim = rope_frequencies(head_dim, theta, fraction)
    if rot_dim == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv          # (..., s, rot/2)
    sin = jnp.sin(ang)[..., None, :]                              # (..., s, 1, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = xr[..., : rot_dim // 2], xr[..., rot_dim // 2:]
    r1 = (x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin)
    r2 = (x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin)
    out = jnp.concatenate([r1.astype(x.dtype), r2.astype(x.dtype)], axis=-1)
    return jnp.concatenate([out, xp], axis=-1) if rot_dim < head_dim else out


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
