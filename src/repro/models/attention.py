"""Attention: GQA/MQA, MLA (DeepSeek), logit softcap, sliding windows, caches.

Cache design (DESIGN.md §6): every attention layer's cache is a ring buffer of
``cache_len`` slots with an absolute-position array ``pos`` (-1 = empty).  A
linear cache is the special case ``cache_len >= seq_len``; the long_500k
sliding-window decode uses ``cache_len == window``.  Masks are derived from
stored absolute positions, which makes ring/linear/windowed decode uniform.

MLA caches the *compressed* kv latent (kv_lora_rank + rope head) — the memory
win of the method; decode supports both the naive (re-expand) path and the
absorbed-matmul path (``absorb=True``), the latter being a §Perf optimization.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_apply, dense_init, softcap

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jnp.ndarray            # (B, C, Kh, hd)  or MLA: c_kv (B, C, r)
    v: jnp.ndarray            # (B, C, Kh, hd)  or MLA: k_rope (B, C, rope_dim)
    pos: jnp.ndarray          # (B, C) int32 absolute positions, -1 empty


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> KVCache:
    a = cfg.attn
    if a.mla is not None:
        k = jnp.zeros((batch, cache_len, a.mla.kv_lora_rank), dtype)
        v = jnp.zeros((batch, cache_len, a.mla.qk_rope_head_dim), dtype)
    else:
        k = jnp.zeros((batch, cache_len, a.n_kv_heads, a.head_dim), dtype)
        v = jnp.zeros_like(k)
    pos = jnp.full((batch, cache_len), -1, jnp.int32)
    return KVCache(k, v, pos)


# ---------------------------------------------------------------------------
# parameter init


def attn_init(key, cfg: ModelConfig, *, cross: bool = False):
    a = cfg.attn
    d = cfg.d_model
    dt = cfg.pdtype
    ks = jax.random.split(key, 8)
    if a.mla is not None and not cross:
        m = a.mla
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        return {
            "wq_a": dense_init(ks[0], d, m.q_lora_rank, dt),
            "q_norm": jnp.zeros((m.q_lora_rank,), dt),
            "wq_b": dense_init(ks[1], m.q_lora_rank, (a.n_heads, qk_dim), dt),
            "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dt),
            "kv_norm": jnp.zeros((m.kv_lora_rank,), dt),
            "wkv_b": dense_init(ks[3], m.kv_lora_rank,
                                (a.n_heads, m.qk_nope_head_dim + m.v_head_dim), dt),
            "wo": dense_init(ks[4], a.n_heads * m.v_head_dim, d, dt),
        }
    p = {
        "wq": dense_init(ks[0], d, (a.n_heads, a.head_dim), dt),
        "wk": dense_init(ks[1], d, (a.n_kv_heads, a.head_dim), dt),
        "wv": dense_init(ks[2], d, (a.n_kv_heads, a.head_dim), dt),
        "wo": dense_init(ks[3], a.n_heads * a.head_dim, d, dt),
    }
    if a.qk_norm:
        p["q_scale"] = jnp.zeros((a.head_dim,), dt)
        p["k_scale"] = jnp.zeros((a.head_dim,), dt)
    return p


def _rms(x, scale):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# masks


def mask_bias(q_pos, k_pos, *, kind: str = "causal",
              window: Optional[int] = None, prefix_len: int = 0):
    """(..., Sq, Sk) additive bias from absolute positions.

    kind: causal | full; window restricts to k > q - window; prefix_len makes
    the first `prefix_len` positions bidirectional (PaliGemma prefix-LM).
    k_pos == -1 marks empty cache slots (always masked).
    """
    q = q_pos[..., :, None].astype(jnp.int32)
    k = k_pos[..., None, :].astype(jnp.int32)
    valid = k >= 0
    if kind == "causal":
        ok = k <= q
        if prefix_len:
            ok = ok | (k < prefix_len)
        valid = valid & ok
    if window is not None:
        valid = valid & (k > q - window)
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, bias, cap, cdtype, *, scale=None, seq_axis=None):
    """q: (B,Sq,H,hd) k,v: (B,Sk,Kh,hd') with H % Kh == 0; bias: (B,Sq,Sk).

    seq_axis: mesh axis name carrying the KV-sequence shard (serve_tp
    decode).  Constraining the logits to stay sharded on Sk makes GSPMD
    run a distributed softmax (psum of per-head max/sum stats + the
    (B,H,hd) output partial) instead of all-gathering the cache.
    """
    from jax.sharding import PartitionSpec as P  # local: models stay light
    B, Sq, H, hd = q.shape
    Kh = k.shape[2]
    G = H // Kh
    scale = (1.0 / math.sqrt(hd)) if scale is None else scale
    qg = q.reshape(B, Sq, Kh, G, hd)
    # NOTE (§Perf pair 3): also pinning the k/v operands here makes XLA
    # gather the cache TWICE (300 GiB measured) — the SPMD dot partitioner
    # will not distribute a decode softmax on this einsum; the structural
    # fix is an explicit shard_map flash-decode schedule (the TPU-side
    # role of kernels/flash_attention.py), not a constraint nudge.
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, cap)
    logits = logits + bias[:, None, None, :, :]
    if seq_axis is not None:
        logits = jax.lax.with_sharding_constraint(
            logits, P(None, None, None, None, seq_axis))
    probs = jax.nn.softmax(logits, axis=-1).astype(cdtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v,
                     preferred_element_type=cdtype)
    return out.reshape(B, Sq, H, v.shape[-1])


# q-chunked attention: live memory O(chunk * Sk) instead of O(Sq * Sk).
# This is the XLA-level analogue of the Pallas flash kernel (which is the
# TPU-target implementation of the same hot spot, repro/kernels); prefill_32k
# and train_4k would otherwise materialize petabyte logits.
Q_CHUNK = 1024


def _sdpa_chunked(q, k, v, q_pos, k_pos, *, kind: str, window, prefix_len,
                  cap, cdtype, scale=None, chunk: int = Q_CHUNK,
                  remat: bool = True, seq_axis=None):
    """Same contract as _sdpa but masks are built per q-chunk from positions.
    q: (B,Sq,H,hd); k,v: (B,Sk,Kh,hd'); q_pos: (B,Sq); k_pos: (B,Sk)."""
    B, Sq, H, hd = q.shape
    if Sq <= chunk:
        bias = mask_bias(q_pos, k_pos, kind=kind, window=window,
                         prefix_len=prefix_len)
        return _sdpa(q, k, v, bias, cap, cdtype, scale=scale,
                     seq_axis=seq_axis)
    pad = (-Sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=0)
    nch = q.shape[1] // chunk
    qs = q.reshape(B, nch, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    ps = q_pos.reshape(B, nch, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        qc, pc = xs
        bias = mask_bias(pc, k_pos, kind=kind, window=window,
                         prefix_len=prefix_len)
        out = _sdpa(qc, k, v, bias, cap, cdtype, scale=scale)
        return carry, out

    fn = jax.checkpoint(body) if remat else body
    # unroll: keeps HLO cost analysis exact (while-loop bodies are counted
    # once by XLA); memory stays bounded via the per-chunk checkpoint.
    _, outs = jax.lax.scan(fn, (), (qs, ps), unroll=True)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nch * chunk, H, -1)
    return out[:, :Sq] if pad else out


def _cache_update(cache: KVCache, k_new, v_new, positions) -> KVCache:
    """Write new entries at slot = pos % cache_len (ring buffer).

    Sequences advance in LOCKSTEP (positions identical across the batch —
    true for this serving design; ragged batches would use a paged cache).
    That makes every write a contiguous dynamic_update_slice on the slot
    axis, which GSPMD handles in place for donated buffers — a vmap-scatter
    here materializes full cache copies (measured 100+ GiB at prefill_32k).
    Ring wrap only ever happens in single-token decode (S == 1 <= C).

    S > C (a prefill longer than a sliding-window ring, e.g. gemma2's 4096
    local window under prefill_32k): only the trailing C tokens survive;
    they replace the whole ring, rolled so the ``slot = pos % C`` invariant
    holds for subsequent decode writes.
    """
    C = cache.pos.shape[1]
    S = positions.shape[1]
    if S > C:
        k_new, v_new = k_new[:, -C:], v_new[:, -C:]
        positions = positions[:, -C:]
        shift = (positions[0, 0] % C).astype(jnp.int32)
        roll = lambda a: jnp.roll(a, shift, axis=1)  # noqa: E731
        return KVCache(roll(k_new), roll(v_new),
                       roll(positions.astype(jnp.int32)))
    start = (positions[0, 0] % C).astype(jnp.int32)

    def upd(buf, new):
        return jax.lax.dynamic_update_slice_in_dim(buf, new, start, axis=1)

    return KVCache(upd(cache.k, k_new), upd(cache.v, v_new),
                   upd(cache.pos, positions.astype(jnp.int32)))


# ---------------------------------------------------------------------------
# standard / GQA attention


def attention(params, cfg: ModelConfig, x, positions, *,
              cache: Optional[KVCache] = None,
              window: Optional[int] = None,
              mask_kind: str = "causal",
              prefix_len: int = 0,
              kv_input=None):
    """Self- or cross-attention.  Returns (out, new_cache).

    x: (B, S, d); positions: (B, S) absolute positions of x's tokens.
    kv_input: encoder output for cross-attention (no cache, full mask).
    """
    a = cfg.attn
    cd = cfg.cdtype
    if a.mla is not None and kv_input is None:
        return _mla_attention(params, cfg, x, positions, cache=cache,
                              window=window, absorb=a.mla_absorb)
    q = dense_apply(params["wq"], x, cd)                     # (B,S,H,hd)
    kv_src = x if kv_input is None else kv_input
    k = dense_apply(params["wk"], kv_src, cd)
    v = dense_apply(params["wv"], kv_src, cd)
    if a.qk_norm:
        q = _rms(q, params["q_scale"])
        k = _rms(k, params["k_scale"])
    if kv_input is None and cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, a.rope_theta, a.rope_fraction)
        k = apply_rope(k, positions, a.rope_theta, a.rope_fraction)

    cap = a.attn_logit_softcap
    if kv_input is not None:
        Sk = k.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32),
                                 (x.shape[0], Sk))
        out = _sdpa_chunked(q, k, v, positions, k_pos, kind="full",
                            window=None, prefix_len=0, cap=cap, cdtype=cd)
        new_cache = cache
    elif cache is not None:
        new_cache = _cache_update(cache, k, v, positions)
        if k.shape[1] > cache.pos.shape[1]:
            # Prefill longer than the ring: early queries need keys the ring
            # has already evicted — attend over the in-flight keys (the
            # window mask enforces locality); the ring stores the tail.
            out = _sdpa_chunked(q, k, v, positions, positions,
                                kind=mask_kind, window=window,
                                prefix_len=prefix_len, cap=cap, cdtype=cd)
        else:
            seq_axis = "data" if (a.seq_parallel and q.shape[1] == 1) else None
            out = _sdpa_chunked(q, new_cache.k, new_cache.v, positions,
                                new_cache.pos, kind=mask_kind, window=window,
                                prefix_len=prefix_len, cap=cap, cdtype=cd,
                                seq_axis=seq_axis)
        cache = new_cache
    else:
        out = _sdpa_chunked(q, k, v, positions, positions, kind=mask_kind,
                            window=window, prefix_len=prefix_len, cap=cap,
                            cdtype=cd)
        new_cache = None
    out = out.reshape(*out.shape[:2], -1)
    return dense_apply(params["wo"], out, cd), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)


def _mla_qkv(params, cfg: ModelConfig, x, positions):
    a, m, cd = cfg.attn, cfg.attn.mla, cfg.cdtype
    cq = _rms(dense_apply(params["wq_a"], x, cd), params["q_norm"])
    q = dense_apply(params["wq_b"], cq, cd)                  # (B,S,H,nope+rope)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, a.rope_theta)
    kv = dense_apply(params["wkv_a"], x, cd)                 # (B,S,r+rope)
    c_kv = _rms(kv[..., : m.kv_lora_rank], params["kv_norm"])
    k_rope = apply_rope(kv[..., None, m.kv_lora_rank:], positions, a.rope_theta)
    return q_nope, q_rope, c_kv, k_rope[..., 0, :]


def _mla_attention(params, cfg: ModelConfig, x, positions, *,
                   cache: Optional[KVCache], window: Optional[int],
                   absorb: bool = False):
    a, m, cd = cfg.attn, cfg.attn.mla, cfg.cdtype
    B, S, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, positions)
    if cache is not None:
        in_flight = c_kv.shape[1] > cache.pos.shape[1]
        cache = _cache_update(cache, c_kv, k_rope, positions)
        if in_flight:   # prefill longer than the ring (see attention())
            c_all, r_all, k_pos = c_kv, k_rope, positions
        else:
            c_all, r_all, k_pos = cache.k, cache.v, cache.pos
    else:
        c_all, r_all, k_pos = c_kv, k_rope, positions
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)

    if absorb:
        # Absorbed path (decode): score in latent space, never expand K/V.
        bias = mask_bias(positions, k_pos, kind="causal", window=window)
        wkv = params["wkv_b"].astype(cd)                     # (r,H,nope+v)
        wk = wkv[..., : m.qk_nope_head_dim]                  # (r,H,nope)
        wv = wkv[..., m.qk_nope_head_dim:]                   # (r,H,v)
        q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, wk)     # (B,S,H,r)
        s_nope = jnp.einsum("bqhr,bsr->bhqs", q_lat, c_all,
                            preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bqhp,bsp->bhqs", q_rope, r_all,
                            preferred_element_type=jnp.float32)
        logits = (s_nope + s_rope) * scale + bias[:, None, :, :]
        if a.seq_parallel and S == 1 and cache is not None:
            from jax.sharding import PartitionSpec as P
            logits = jax.lax.with_sharding_constraint(
                logits, P(None, None, None, "data"))
        probs = jax.nn.softmax(logits, axis=-1).astype(cd)
        o_lat = jnp.einsum("bhqs,bsr->bqhr", probs, c_all)   # (B,S,H,r)
        out = jnp.einsum("bqhr,rhv->bqhv", o_lat, wv)
    else:
        # Naive path: expand K/V from the latent (paper-faithful reference).
        kv = dense_apply(params["wkv_b"], c_all, cd)         # (B,Sk,H,nope+v)
        k_nope = kv[..., : m.qk_nope_head_dim]
        v = kv[..., m.qk_nope_head_dim:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(r_all[:, :, None, :],
                                      (*k_nope.shape[:3], m.qk_rope_head_dim))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _sdpa_chunked(q, k, v, positions, k_pos, kind="causal",
                            window=window, prefix_len=0,
                            cap=a.attn_logit_softcap, cdtype=cd, scale=scale)
    out = out.reshape(B, S, a.n_heads * m.v_head_dim)
    return dense_apply(params["wo"], out, cd), cache
