"""Mixture-of-Experts: top-k routing with GShard-style capacity dispatch.

Token groups of ``group_size`` bound the dispatch one-hot to
(G, gs, E, C) with C = ceil(gs * top_k * capacity_factor / E) — the memory
knob that keeps the einsum-based dispatch shardable (groups over the data
axes, experts over the model axis; GSPMD turns the dispatch/combine einsums
into the expert-parallel all-to-all).  Over-capacity tokens are dropped, as
in Switch/GShard; the aux load-balance loss discourages that.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import activation, dense_apply, mlp_apply, mlp_init


def moe_init(key, cfg: ModelConfig):
    m = cfg.moe
    d, dt = cfg.d_model, cfg.pdtype
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": (s * jax.random.normal(ks[0], (d, m.n_experts), jnp.float32)
                   ).astype(dt),
        "w_gate": (s * jax.random.normal(ks[1], (m.n_experts, d, m.d_expert),
                                         jnp.float32)).astype(dt),
        "w_up": (s * jax.random.normal(ks[2], (m.n_experts, d, m.d_expert),
                                       jnp.float32)).astype(dt),
        "w_down": ((1.0 / math.sqrt(m.d_expert)) *
                   jax.random.normal(ks[3], (m.n_experts, m.d_expert, d),
                                     jnp.float32)).astype(dt),
    }
    if m.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, m.n_shared_experts * m.d_expert,
                               cfg.gated_mlp, dt)
    return p


def _dispatch_tensors(gates, idx, n_experts: int, capacity: int, cdtype):
    """GShard top-k dispatch.  gates/idx: (G, gs, k).

    Returns dispatch (G,gs,E,C) in cdtype and combine (G,gs,E,C) in float32.
    Position of a token within its expert buffer accumulates across the k
    routing slots so that slot-1 choices queue behind slot-0 choices.
    """
    G, gs, k = idx.shape
    base_count = jnp.zeros((G, n_experts), jnp.int32)
    dispatch = jnp.zeros((G, gs, n_experts, capacity), jnp.bool_)
    combine = jnp.zeros((G, gs, n_experts, capacity), jnp.float32)
    for j in range(k):
        onehot = jax.nn.one_hot(idx[..., j], n_experts, dtype=jnp.int32)
        prio = jnp.cumsum(onehot, axis=1) - onehot              # tokens ahead
        pos = prio + base_count[:, None, :]                     # (G,gs,E)
        keep = (onehot > 0) & (pos < capacity)
        pos_oh = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)
        sel = keep.astype(jnp.float32)[..., None] * pos_oh      # (G,gs,E,C)
        dispatch = dispatch | (sel > 0)
        combine = combine + gates[..., j][..., None, None].astype(jnp.float32) * sel
        base_count = base_count + jnp.sum(onehot, axis=1)
    return dispatch.astype(cdtype), combine


def moe_apply(params, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss)."""
    m, cd = cfg.moe, cfg.cdtype
    B, S, d = x.shape
    n_tok = B * S
    gs = min(m.group_size, n_tok)
    pad = (-n_tok) % gs
    xt = x.reshape(n_tok, d)
    if pad:
        xt = jnp.concatenate([xt, jnp.zeros((pad, d), xt.dtype)], axis=0)
    G = xt.shape[0] // gs
    xg = xt.reshape(G, gs, d)

    logits = dense_apply(params["router"], xg, jnp.float32)     # (G,gs,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    capacity = max(1, math.ceil(gs * m.top_k * m.capacity_factor / m.n_experts))
    dispatch, combine = _dispatch_tensors(gates, idx, m.n_experts, capacity, cd)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg,
                    preferred_element_type=cd)                  # (G,E,C,d)
    up = jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(cd),
                    preferred_element_type=cd)
    if cfg.gated_mlp:
        gate = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"].astype(cd),
                          preferred_element_type=cd)
        h = activation(cfg.activation, gate) * up
    else:
        h = activation(cfg.activation, up)
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(cd),
                    preferred_element_type=cd)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(cd), ye,
                   preferred_element_type=cd)
    y = y.reshape(-1, d)
    if pad:
        y = y[:n_tok]
    y = y.reshape(B, S, d)

    # Switch-style load-balance aux loss: E * sum_e f_e * p_e
    frac_routed = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32), axis=2),
        axis=(0, 1)) / m.top_k                                   # (E,)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = m.n_experts * jnp.sum(frac_routed * mean_prob) * m.router_aux_coef

    if m.n_shared_experts:
        y = y + mlp_apply(params["shared"], x, cfg.activation, cd)
    return y, aux
