"""LeNet-5 CNN — the paper's model for EMNIST/CIFAR experiments [LeCun 1998].

Functional raw-JAX implementation (lax.conv).  Supports 28x28x1 (EMNIST) and
32x32x3 (CIFAR) inputs via config.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class LeNetConfig:
    in_size: int = 28
    in_channels: int = 1
    n_classes: int = 47
    c1: int = 6
    c2: int = 16
    fc1: int = 120
    fc2: int = 84


def _conv_init(key, k, cin, cout):
    fan_in = k * k * cin
    return (jax.random.normal(key, (cout, cin, k, k), jnp.float32)
            / math.sqrt(fan_in))


def _fc_init(key, din, dout):
    return (jax.random.normal(key, (din, dout), jnp.float32) / math.sqrt(din))


def init_params(key, cfg: LeNetConfig) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 6)
    # spatial size after two (conv5 valid + pool2) stages
    s = cfg.in_size
    s = (s - 4) // 2
    s = (s - 4) // 2
    flat = cfg.c2 * s * s
    return {
        "conv1_w": _conv_init(ks[0], 5, cfg.in_channels, cfg.c1),
        "conv1_b": jnp.zeros((cfg.c1,)),
        "conv2_w": _conv_init(ks[1], 5, cfg.c1, cfg.c2),
        "conv2_b": jnp.zeros((cfg.c2,)),
        "fc1_w": _fc_init(ks[2], flat, cfg.fc1),
        "fc1_b": jnp.zeros((cfg.fc1,)),
        "fc2_w": _fc_init(ks[3], cfg.fc1, cfg.fc2),
        "fc2_b": jnp.zeros((cfg.fc2,)),
        "out_w": _fc_init(ks[4], cfg.fc2, cfg.n_classes),
        "out_b": jnp.zeros((cfg.n_classes,)),
    }


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "OIHW", "NHWC"))
    return y + b[None, None, None, :]


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def apply(params, x):
    """x: (B, H, W, C) float32 -> logits (B, n_classes)."""
    h = jnp.tanh(_conv(x, params["conv1_w"], params["conv1_b"]))
    h = _pool(h)
    h = jnp.tanh(_conv(h, params["conv2_w"], params["conv2_b"]))
    h = _pool(h)
    h = h.reshape(h.shape[0], -1)
    h = jnp.tanh(h @ params["fc1_w"] + params["fc1_b"])
    h = jnp.tanh(h @ params["fc2_w"] + params["fc2_b"])
    return h @ params["out_w"] + params["out_b"]


def loss_fn(params, batch):
    """batch: {"x": (B,H,W,C), "y": (B,)} -> (mean CE, metrics)."""
    logits = apply(params, batch["x"])
    lps = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lps, batch["y"][:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}


def accuracy(params, batch):
    logits = apply(params, batch["x"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
