"""Scan-over-layers execution: O(1)-size HLO for deep stacks + remat.

The per-layer params list is regrouped into a repeating pattern block of
`period` sub-layers (period = attention layer_pattern length, the hybrid
attn_every cycle, or 1), stacked across groups, and driven by lax.scan.
Required for the multi-pod dry-run: a 96-layer python loop over a
512-device SPMD graph is intractable to compile; the scanned form traces
one pattern block (DESIGN.md §7).

`forward` here is numerically identical to transformer.forward (tests
assert allclose); `remat=True` wraps the scan body in jax.checkpoint —
the activation-checkpointing knob used by the launcher for train_4k.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import (_block_apply, _embed_inputs, _unembed)


def layer_grouping(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_prefix, period, n_groups): layers n_prefix..L scan in pattern
    blocks of `period` sub-layers."""
    n_pre = 0
    if cfg.moe is not None and cfg.moe.n_dense_layers:
        n_pre = cfg.moe.n_dense_layers
    if cfg.family == "hybrid":
        period = cfg.hybrid.attn_every
    elif cfg.attn is not None:
        period = len(cfg.attn.layer_pattern)
    else:
        period = 1
    rest = cfg.n_layers - n_pre
    while rest % period:      # fall back to a period that divides
        period -= 1
    return n_pre, period, rest // period


def stack_layer_params(params: Dict[str, Any], cfg: ModelConfig
                       ) -> Dict[str, Any]:
    """Regroup params["layers"] for scanning.  Returns a new params dict with
    "prefix_layers" (list) and "scan_layers" (tuple of `period` pytrees, each
    leaf stacked to leading dim n_groups)."""
    n_pre, period, groups = layer_grouping(cfg)
    layers = params["layers"]
    prefix = layers[:n_pre]
    rest = layers[n_pre:]
    slots = []
    for j in range(period):
        per_group = [rest[g * period + j] for g in range(groups)]
        slots.append(jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls), *per_group))
    out = {k: v for k, v in params.items() if k != "layers"}
    out["prefix_layers"] = prefix
    out["scan_layers"] = tuple(slots)
    return out


def unstack_layer_params(params: Dict[str, Any], cfg: ModelConfig
                         ) -> Dict[str, Any]:
    """Inverse of stack_layer_params."""
    n_pre, period, groups = layer_grouping(cfg)
    layers = list(params["prefix_layers"])
    slots = params["scan_layers"]
    for g in range(groups):
        for j in range(period):
            layers.append(jax.tree_util.tree_map(lambda l: l[g], slots[j]))
    out = {k: v for k, v in params.items()
           if k not in ("prefix_layers", "scan_layers")}
    out["layers"] = layers
    return out


def stack_caches(caches: List[Any], cfg: ModelConfig) -> Dict[str, Any]:
    n_pre, period, groups = layer_grouping(cfg)
    prefix = caches[:n_pre]
    rest = caches[n_pre:]
    slots = []
    for j in range(period):
        per_group = [rest[g * period + j] for g in range(groups)]
        slots.append(jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls), *per_group))
    return {"prefix": prefix, "scan": tuple(slots)}


def _make_body(cfg: ModelConfig, shared, n_pre: int, period: int, *,
               positions, prefix_len: int, decode: bool, long_context: bool,
               with_cache: bool):
    """scan body over one pattern block of `period` sub-layers."""

    def body(x, slices):
        if with_cache:
            param_slices, cache_slices = slices
        else:
            param_slices, cache_slices = slices, (None,) * period
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []
        for j in range(period):
            i = n_pre + j            # representative index (kind is periodic)
            sh = shared if (shared is not None
                            and cfg.layer_kind(i) == "attn") else None
            x, aux, c = _block_apply(
                param_slices[j], sh, cfg, i, x, positions,
                cache=cache_slices[j], decode=decode, prefix_len=prefix_len,
                long_context=long_context)
            aux_total = aux_total + aux
            new_caches.append(c)
        out = (x, aux_total)
        return out, tuple(new_caches) if with_cache else None

    return body


def forward_hidden(params: Dict[str, Any], cfg: ModelConfig,
                   batch: Dict[str, jnp.ndarray], *, remat: bool = False
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scanned stack forward up to (excluding) final norm/unembed."""
    n_pre, period, groups = layer_grouping(cfg)
    x, positions, prefix_len = _embed_inputs(params, cfg, batch)
    shared = params.get("shared_attn")
    aux_total = jnp.zeros((), jnp.float32)
    for i, lp in enumerate(params["prefix_layers"]):
        sh = shared if (shared is not None and cfg.layer_kind(i) == "attn") \
            else None
        x, aux, _ = _block_apply(lp, sh, cfg, i, x, positions,
                                 prefix_len=prefix_len)
        aux_total = aux_total + aux

    body = _make_body(cfg, shared, n_pre, period, positions=positions,
                      prefix_len=prefix_len, decode=False,
                      long_context=False, with_cache=False)

    def scan_fn(carry, slices):
        x, aux = carry
        (x, aux_step), _ = body(x, slices)
        return (x, aux + aux_step), None

    fn = jax.checkpoint(scan_fn) if remat else scan_fn
    (x, aux_total), _ = jax.lax.scan(fn, (x, aux_total),
                                     params["scan_layers"])
    return x, aux_total


def forward(params: Dict[str, Any], cfg: ModelConfig,
            batch: Dict[str, jnp.ndarray], *, remat: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scanned training forward.  Returns (logits, aux_loss)."""
    x, aux_total = forward_hidden(params, cfg, batch, remat=remat)
    return _unembed(params, cfg, x), aux_total


def loss_fn(params: Dict[str, Any], cfg: ModelConfig,
            batch: Dict[str, jnp.ndarray], *, remat: bool = False):
    """Scanned next-token CE loss (mirrors transformer.loss_fn)."""
    from repro.models.transformer import _ce_from_hidden
    hidden, aux = forward_hidden(params, cfg, batch, remat=remat)
    ce = _ce_from_hidden(params, cfg, hidden, batch["tokens"])
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "loss": loss}


def prefill(params, cfg: ModelConfig, batch, caches: Dict[str, Any], *,
            long_context: bool = False):
    """Scanned prefill.  `caches` from stack_caches."""
    x, positions, prefix_len = _embed_inputs(params, cfg, batch)
    shared = params.get("shared_attn")
    n_pre, period, groups = layer_grouping(cfg)
    new_prefix = []
    for i, (lp, c) in enumerate(zip(params["prefix_layers"], caches["prefix"])):
        sh = shared if (shared is not None and cfg.layer_kind(i) == "attn") \
            else None
        x, _, c2 = _block_apply(lp, sh, cfg, i, x, positions, cache=c,
                                prefix_len=prefix_len,
                                long_context=long_context)
        new_prefix.append(c2)

    body = _make_body(cfg, shared, n_pre, period, positions=positions,
                      prefix_len=prefix_len, decode=False,
                      long_context=long_context, with_cache=True)

    def scan_fn(carry, slices):
        (x2, aux), new_c = body(carry[0], slices)
        return (x2, carry[1]), new_c

    (x, _), new_scan = jax.lax.scan(
        scan_fn, (x, jnp.zeros((), jnp.float32)),
        (params["scan_layers"], caches["scan"]))
    logits = _unembed(params, cfg, x[:, -1:])
    return logits, {"prefix": new_prefix, "scan": new_scan}


def decode_step(params, cfg: ModelConfig, token, caches: Dict[str, Any],
                pos, *, long_context: bool = False):
    """Scanned single-token decode."""
    positions = pos[:, None].astype(jnp.int32)
    x, positions, _ = _embed_inputs(params, cfg, {"tokens": token}, positions)
    shared = params.get("shared_attn")
    n_pre, period, groups = layer_grouping(cfg)
    new_prefix = []
    for i, (lp, c) in enumerate(zip(params["prefix_layers"], caches["prefix"])):
        sh = shared if (shared is not None and cfg.layer_kind(i) == "attn") \
            else None
        x, _, c2 = _block_apply(lp, sh, cfg, i, x, positions, cache=c,
                                decode=True, long_context=long_context)
        new_prefix.append(c2)

    body = _make_body(cfg, shared, n_pre, period, positions=positions,
                      prefix_len=0, decode=True, long_context=long_context,
                      with_cache=True)

    def scan_fn(carry, slices):
        (x2, aux), new_c = body(carry[0], slices)
        return (x2, carry[1]), new_c

    (x, _), new_scan = jax.lax.scan(
        scan_fn, (x, jnp.zeros((), jnp.float32)),
        (params["scan_layers"], caches["scan"]))
    return _unembed(params, cfg, x), {"prefix": new_prefix, "scan": new_scan}
