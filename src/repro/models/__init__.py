from repro.models import transformer, lenet
from repro.models.transformer import (decode_step, forward, init_params,
                                      loss_fn, make_caches, prefill)

__all__ = ["transformer", "lenet", "decode_step", "forward", "init_params",
           "loss_fn", "make_caches", "prefill"]
