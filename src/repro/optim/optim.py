"""Minimal functional optimizers (no optax offline): SGD-momentum, AdamW.

    opt = sgd(lr=0.1, momentum=0.9)          # the paper's optimizer
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Learning rates may be floats or schedules (callables step -> lr); state
carries the step counter.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]
LR = Union[float, Schedule]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]   # (grads, state, params) -> (updates, state)


def _lr_at(lr: LR, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def _zeros_like_f32(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd(lr: LR, momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0, state_dtype=None) -> Optimizer:
    """state_dtype: None = float32 momentum; "param" = match the param dtype
    (halves optimizer memory for bf16 giants — launch uses it for FSDP archs)."""
    def init(params):
        if not momentum:
            mu = None
        elif state_dtype == "param":
            mu = jax.tree_util.tree_map(jnp.zeros_like, params)
        else:
            mu = _zeros_like_f32(params)
        return {"mu": mu, "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)

        def eff_grad(g, p):
            g = g.astype(jnp.float32)
            if weight_decay and p is not None:
                g = g + weight_decay * p.astype(jnp.float32)
            return g

        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, g, p: (momentum * m.astype(jnp.float32)
                                 + eff_grad(g, p)).astype(m.dtype),
                state["mu"], grads, params)
            if nesterov:
                updates = jax.tree_util.tree_map(
                    lambda m, g, p: -lr_t * (eff_grad(g, p)
                                             + momentum * m.astype(jnp.float32)),
                    mu, grads, params)
            else:
                updates = jax.tree_util.tree_map(
                    lambda m: -lr_t * m.astype(jnp.float32), mu)
        else:
            mu = None
            updates = jax.tree_util.tree_map(
                lambda g, p: -lr_t * eff_grad(g, p), grads, params)
        return updates, {"mu": mu, "step": step}

    return Optimizer(init, update)


def adamw(lr: LR, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {"m": _zeros_like_f32(params), "v": _zeros_like_f32(params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        m = jax.tree_util.tree_map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        updates = jax.tree_util.tree_map(
            lambda mm, vv, p: -lr_t * (
                (mm / c1) / (jnp.sqrt(vv / c2) + eps)
                + weight_decay * p.astype(jnp.float32)),
            m, v, params)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


# ---------------------------------------------------------------------------
# schedules


def constant(value: float) -> Schedule:
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine_decay(peak: float, total_steps: int, final_frac: float = 0.1
                 ) -> Schedule:
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(math.pi * t))
        return peak * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Schedule:
    cos = cosine_decay(peak, max(total_steps - warmup_steps, 1), final_frac)
    def fn(step):
        s = step.astype(jnp.float32)
        wu = peak * s / max(warmup_steps, 1)
        return jnp.where(s < warmup_steps, wu, cos(step - warmup_steps))
    return fn


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm
