from repro.optim.optim import (Optimizer, adamw, apply_updates,
                               clip_by_global_norm, constant, cosine_decay,
                               global_norm, sgd, warmup_cosine)

__all__ = ["Optimizer", "adamw", "apply_updates", "clip_by_global_norm",
           "constant", "cosine_decay", "global_norm", "sgd", "warmup_cosine"]
