"""Emit the §Roofline markdown table from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.roofline_table [--mesh pod16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "dryrun_artifacts")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, mesh, "*.json"))):
        base = os.path.basename(path)[:-5]
        if "__" not in base:
            continue
        parts = base.split("__")
        if len(parts) != 2:
            continue               # tagged perf-iteration artifacts
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9))
    return rows


def fmt_ms(x):
    return f"{x*1e3:.2f}"


def table(mesh: str) -> str:
    rows = load(mesh)
    out = [f"### Roofline — {mesh} ({rows[0]['chips'] if rows else '?'} chips)",
           "",
           "| arch | shape | compute ms | memory ms | collective ms | "
           "bottleneck | useful-FLOPs ratio | MODEL_FLOPS |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(r['t_compute'])} | "
            f"{fmt_ms(r['t_memory'])} | {fmt_ms(r['t_collective'])} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.3f} | "
            f"{r['model_flops_global']:.2e} |")
    return "\n".join(out)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--mesh", default="pod16x16")
    args = p.parse_args(argv)
    print(table(args.mesh))


if __name__ == "__main__":
    main()
