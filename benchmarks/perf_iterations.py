"""§Perf hillclimb driver: run tagged dry-run variants of the three selected
(arch × shape) pairs and record before/after roofline terms.

Pair selection (from the 40-pair baseline table, EXPERIMENTS.md §Roofline):
  * gemma-2b × train_4k        — paper-representative: the UCFL mixing
    collective dominates (collective-bound, tx 5.44s > tm 4.22s).
  * deepseek-v3-671b × decode_32k — worst useful-FLOPs ratio (0.001):
    naive MLA re-expands K/V from the latent every decoded token.
  * nemotron-4-340b × decode_32k  — most collective-bound (tx 5.4× tm):
    FSDP re-gathers weight shards for every decoded token.

Each iteration is (hypothesis with napkin math, knob change) — the knobs are
real framework features (mixing schedule, stream count, MLA absorption,
serve-time 2D tensor parallelism), not ad-hoc hacks.  Results land as tagged
artifacts next to the baselines and are summarized to
benchmarks/results/perf_iterations.json; EXPERIMENTS.md §Perf is the
narrative log.

    PYTHONPATH=src python -m benchmarks.perf_iterations [--group NAME]
    PYTHONPATH=src python -m benchmarks.perf_iterations --round-engine
    PYTHONPATH=src python -m benchmarks.perf_iterations --paging
    PYTHONPATH=src python -m benchmarks.perf_iterations --async-engine
    PYTHONPATH=src python -m benchmarks.perf_iterations --channel
    PYTHONPATH=src python -m benchmarks.perf_iterations --serve

MUST run standalone: the dry-run groups force 512 host devices (via the
repro.launch.dryrun import) and --round-engine forces 8, both through
XLA_FLAGS set before jax initializes — so jax must not be imported at
module scope here.
"""
from __future__ import annotations

import argparse
import json
import os
import time

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# (name, arch, shape, kwargs, hypothesis)
ITERATIONS = {
    # ------------------------------------------------------------------
    # Pair 1 — gemma-2b train_4k: the paper's own technique.  Baseline =
    # gspmd einsum with k=4 streams: all-gather 188.8 GB/dev, tx 5.44 s.
    # Napkin: P = 2.5B bf16 = 5 GB; client stack m=16 over "data"; the
    # einsum makes GSPMD all-gather the stack (m·P/modelshards ≈ 5 GB ·16/16
    # per dev plus remat recompute doubling).  Explicit streams psum moves
    # only k weighted copies: volume ∝ k·P not m·P → predict tx ↓ ~2×
    # at k=4 and the all-gather component ↓ ≥4×.
    "mixing": [
        ("k4_shardmap_streams", "gemma-2b", "train_4k",
         dict(n_streams=4, schedule="shard_map_streams"),
         "psum of k=4 weighted copies replaces the m=16 client-stack "
         "all-gather: collective bytes ∝ k·P instead of m·P → tx ↓ ~2×"),
        ("k1_fedavg_gspmd", "gemma-2b", "train_4k",
         dict(n_streams=1, schedule="gspmd"),
         "paper-faithful FedAvg (k=1): the collective lower bound the "
         "paper trades against (its Fig.3 left end)"),
        ("k16_unicast_gspmd", "gemma-2b", "train_4k",
         dict(n_streams=16, schedule="gspmd"),
         "full personalization (k=m=16): the paper's m-fold downlink — "
         "collective term should grow toward m× the k=1 mixing volume"),
        ("k16_shardmap_unicast", "gemma-2b", "train_4k",
         dict(n_streams=16, schedule="shard_map_unicast"),
         "explicit all-gather + local-mix at k=m: pins the unicast "
         "protocol; expect ≈ gspmd k=16 volume (same information moves)"),
        ("k4_no_remat", "gemma-2b", "train_4k",
         dict(n_streams=4, schedule="shard_map_streams", remat=False),
         "remat off: memory term ↓ (no recompute re-reads) at the price "
         "of live activations; checks how much of tm is remat traffic"),
    ],
    # ------------------------------------------------------------------
    # Pair 2 — deepseek-v3 decode_32k: worst useful-FLOPs ratio (0.001).
    # Baseline tm = 1589 ms, all-gather 37.7 GB/dev.  Napkin: naive MLA
    # expands kv = wkv_b(c_all) = (128, 32768, 128H, 256) bf16 = 274 GB
    # per layer per step, re-read from HBM; absorbed path scores in the
    # 512-dim latent: touches only c_all (4.3 GB global) → predict
    # tm ↓ ≥10×.  serve_tp kills the FSDP weight gather (params 671B·2B /
    # 256 chips = 5.2 GB stationary) → all-gather ↓ to activation size.
    "mla": [
        ("absorb", "deepseek-v3-671b", "decode_32k",
         dict(overrides={"attn.mla_absorb": True}),
         "absorbed MLA decode scores in latent space: kills the per-step "
         "(B,S,H,256) K/V expansion → memory term ↓ ≥10×"),
        ("absorb_servetp", "deepseek-v3-671b", "decode_32k",
         dict(overrides={"attn.mla_absorb": True, "serve_tp": True}),
         "absorb + weight-stationary 2D TP: FSDP weight all-gather "
         "(37.7 GB/dev) → activation all-reduces (MBs) → collective ↓ ~10×"),
    ],
    # ------------------------------------------------------------------
    # Pair 1, round 2 — the k-sweep REFUTED the first hypothesis: tx moves
    # only 5407→5595 ms from k=1 to k=16, so the mixing is ~3% of tx; the
    # 175 GiB all-gather + 71 GiB all-reduce are tensor-parallel activation
    # collectives of the d_model/head_dim sharding (model axis = 16) that
    # exist even under FedAvg.  New hypothesis: gemma-2b (5 GB params bf16 +
    # 5 GB momentum) fits ONE chip — use client-per-chip placement
    # (fl_client_axis="all": m=256 clients, weights replicated, batch 1
    # seq/client).  TP collectives vanish; tx becomes ~purely the mixing:
    # psum of k weighted copies ≈ 2·k·P = 40 GB at k=4 → predict tx
    # 5445 → <1000 ms and the k-sweep finally traces the paper's trade-off.
    "placement": [
        ("cpc_k4", "gemma-2b", "train_4k",
         dict(n_streams=4, overrides={"fl_client_axis": "all"}),
         "client-per-chip (m=256, replicated weights): TP collectives "
         "vanish; tx ≈ pure k=4 mixing ≈ 2·k·P ≈ 40 GB → tx ↓ ~6×"),
        ("cpc_k1", "gemma-2b", "train_4k",
         dict(n_streams=1, overrides={"fl_client_axis": "all"}),
         "FedAvg under client-per-chip: the mixing lower bound (2·P)"),
        ("cpc_k16", "gemma-2b", "train_4k",
         dict(n_streams=16, overrides={"fl_client_axis": "all"}),
         "k=16 streams under client-per-chip: tx should now scale ~k "
         "(the paper's stream/downlink trade-off, visible at last)"),
    ],
    # ------------------------------------------------------------------
    # Pairs 2+3, round 2 — absorb_servetp REFUTED the serve_tp-alone
    # hypothesis: tx stayed ~920 ms with a 42.7 GiB all-gather.  Diagnosis
    # from the HLO: decode token/pos inputs were replicated (P()), so GSPMD
    # gathered the *batch-sharded cache* (61 layers × 0.6 GiB ≈ 42 GiB) to
    # meet the replicated activations.  Fix: shard token/pos over "data"
    # like the cache (now the default in build_decode_case).  Predict the
    # remaining all-gather collapses to activation size → deepseek tx
    # 919 → <100 ms; nemotron decode tx likewise.
    "inputs": [
        ("deepseek_absorb_fixed", "deepseek-v3-671b", "decode_32k",
         dict(overrides={"attn.mla_absorb": True, "serve_tp": True}),
         "absorb + serve_tp + batch-sharded decode inputs: cache gather "
         "eliminated → tx ↓ ~10×"),
        ("nemotron_servetp_fixed", "nemotron-4-340b", "decode_32k",
         dict(overrides={"serve_tp": True}),
         "serve_tp + batch-sharded decode inputs on the dense giant"),
        ("nemotron_fixed_only", "nemotron-4-340b", "decode_32k",
         dict(),
         "input-sharding fix alone (no serve_tp): separates the two "
         "effects — how much of the 154 GiB gather was the cache vs FSDP"),
    ],
    # ------------------------------------------------------------------
    # Pairs 2+3, round 3 — round 2 refuted the input-sharding hypothesis:
    # the 154 GiB gather is the FSDP *weight* gather over "data" (the
    # cache already propagated batch sharding), and serve_tp alone CONFLICTS
    # with batch-sharded caches (d_ff and batch both want "data": GSPMD
    # re-gathers the 9.7 GB/dev cache every token → 278 GiB).  New layout
    # hypothesis: batch REPLICATED + cache SEQUENCE-sharded over "data" +
    # 2D-TP stationary weights.  Napkin (nemotron): weights/dev 2.7 GB ✓,
    # cache/dev 9.7 GB ✓, per-token collectives = 96 layers × ~3 × 4.7 MB
    # activation all-reduces + attention-softmax stats ≈ 1.4 GB →
    # tx 3320 → ~30 ms (100×), tm 613 → ~20 ms (weights+cache one read).
    "seqshard": [
        ("nemotron_servetp_seq", "nemotron-4-340b", "decode_32k",
         dict(overrides={"serve_tp": True}),
         "2D-TP weights + seq-sharded cache + replicated batch: weight and "
         "cache gathers both eliminated → tx ↓ ~100×, tm ↓ ~30×"),
        ("deepseek_absorb_seq", "deepseek-v3-671b", "decode_32k",
         dict(overrides={"attn.mla_absorb": True, "serve_tp": True}),
         "same layout + absorbed MLA on the MoE giant: remaining 45 GiB "
         "gather (weights over data) eliminated → tx 919 → <100 ms"),
    ],
    # ------------------------------------------------------------------
    # Pairs 2+3, round 4 — round 3 halved nothing: the HLO shows ONE
    # all-gather of f32[128,2048,8,192] (the seq-sharded cache, upcast to
    # f32) per layer — XLA prefers gathering the cache to distributing the
    # softmax.  Fix: `attn.seq_parallel` — a with_sharding_constraint pins
    # the (B,Kh,G,1,S) logits to stay S-sharded, so GSPMD must run the
    # partial-softmax (psum of per-head max/sum stats + the (B,H,hd)
    # output partial ≈ 10 MB/layer).  Predict nemotron tx 3389 → <100 ms.
    "seqpar": [
        ("nemotron_seqpar", "nemotron-4-340b", "decode_32k",
         dict(overrides={"serve_tp": True, "attn.seq_parallel": True}),
         "distributed-softmax decode attention: cache gather (155 GiB) → "
         "per-head stat psums (~1 GB) → tx ↓ ~30×"),
        ("deepseek_seqpar", "deepseek-v3-671b", "decode_32k",
         dict(overrides={"attn.mla_absorb": True, "serve_tp": True,
                         "attn.seq_parallel": True}),
         "same + absorbed MLA: latent cache stays sharded through the "
         "absorbed logits einsum → tx 1518 → <150 ms"),
    ],
    # ------------------------------------------------------------------
    # Extra — HBM-fit for the giants' train_4k (dry-run finding: temp
    # memory 1.74 TB/dev deepseek, 0.93 TB/dev nemotron, ≫ 16 GiB HBM).
    # Napkin: temps are activation/dispatch buffers ∝ tokens-in-flight;
    # accumulating over 16 microbatches cuts tokens-in-flight 16× →
    # predict temp ↓ ~16× (toward fit), flops unchanged, bytes ↑ slightly
    # (weights re-read per slice: + params·(micro−1) ≈ +2.6 GB·15/dev).
    "fit": [
        ("deepseek_micro16", "deepseek-v3-671b", "train_4k",
         dict(microbatch=16),
         "16-way gradient accumulation: activation temps ↓ ~16×, weights "
         "re-read per slice — memory-capacity fix, bandwidth-time cost"),
        ("nemotron_micro16", "nemotron-4-340b", "train_4k",
         dict(microbatch=16),
         "same for nemotron: 0.93 TB/dev temps → ~60 GB/dev "
         "(+ remat already on); remaining gap needs more chips"),
    ],
    # ------------------------------------------------------------------
    # Pair 3 — nemotron-4 decode_32k: most collective-bound (tx 3.32 s =
    # 5.4× tm).  Napkin: params 340B bf16 = 680 GB; FSDP over "data"=16
    # re-gathers every layer's shard per token → ~165 GB/dev.  2D TP
    # shards d_ff=73728 over 256 chips (288/chip) and d_model-contraction
    # dims over "data"; weights never move, per-layer all-reduce = x
    # (128×18432 bf16 = 4.5 MB) ×2 ×96 layers ≈ 0.9 GB → tx ↓ ~100×.
    "decode_tp": [
        ("servetp", "nemotron-4-340b", "decode_32k",
         dict(overrides={"serve_tp": True}),
         "weight-stationary 2D TP: replace per-token FSDP weight gather "
         "with activation all-reduces → collective term ↓ ~100×"),
        ("servetp_long", "nemotron-4-340b", "long_500k",
         dict(overrides={"serve_tp": True}),
         "same placement under the 512k-window single sequence: checks "
         "the win holds when the cache, not the batch, dominates"),
    ],
}


def _dispatch_probe(fed):
    """A deliberately tiny dense model (flatten -> logits) for the round-
    engine bench: on real accelerators the per-round model step is
    microseconds and rounds/sec is governed by per-round ENGINE overhead
    (Python re-entry, jit dispatches, host syncs) — the quantity the
    superstep fuses away.  A LeNet miniature on this CPU container is
    conv-compute-bound and would measure the host, not the engine.
    Returns ``(model_init, loss_fn, acc_fn)`` in the engine's contract
    (loss has aux, like `repro.models.lenet`)."""
    import jax
    import jax.numpy as jnp

    d = int(fed.x.shape[2] * fed.x.shape[3] * fed.x.shape[4])
    n_classes = int(jnp.max(fed.y)) + 1

    def model_init(key):
        # a single leaf: every per-leaf engine op (mix, sgd, donation)
        # then costs exactly one kernel, keeping the probe about the
        # ENGINE's per-round work, not the model's pytree size
        return {"w": 0.01 * jax.random.normal(key, (d, n_classes),
                                              jnp.float32)}

    def apply(p, x):
        return x.reshape((x.shape[0], -1)) @ p["w"]

    def loss_fn(p, batch):
        logits = apply(p, batch["x"])
        lps = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(
            lps, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
        loss = jnp.mean(nll)
        return loss, {"loss": loss}

    def acc_fn(p, batch):
        logits = apply(p, batch["x"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"])
                        .astype(jnp.float32))

    return model_init, loss_fn, acc_fn


def round_engine_bench(rounds: int = 192):
    """Rounds/sec of the federated round engine per placement × schedule,
    eventful loop vs fused superstep (DESIGN.md §3c)
    -> BENCH_round_engine.json.

    Runs an m=8 label-shift miniature with the `_dispatch_probe` model so
    the number measures engine throughput.  The per-run fixed costs
    (strategy.setup, data placement, compiles, the round-0 and final
    evals) are removed by timing the DELTA between a short and a long run
    on the same placement instance: rounds/sec = (R_long − R_short) /
    (t_long − t_short); both lengths are warmed up first so superstep
    scan compiles never pollute the delta.

    Also runs the superstep PARITY ANCHORS (ucfl_k2 + sampler + qsgd:4,
    fused vs eventful) per placement row and RAISES if they diverge —
    CI's bench step doubles as the parity smoke.  The mesh ``gspmd``
    anchor is allclose (XLA owns its einsum partitioning and may
    reassociate the mix inside the scan); the pinned shard_map schedules
    and host are exact.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        # append (not setdefault): a pre-set XLA_FLAGS for unrelated options
        # must not silently drop the 8-device forcing the bench documents
        os.environ["XLA_FLAGS"] = \
            (flags + " --xla_force_host_platform_device_count=8").strip()
    import jax
    import numpy as np
    from repro.core.distributed import MIX_SCHEDULES
    from repro.data.federated import scenario_label_shift
    from repro.fl import (Channel, FLConfig, HostVmap, MeshShardMap, SYSTEMS,
                          UniformFraction, run_federated)

    fed = scenario_label_shift(jax.random.PRNGKey(0), n=800, m=8)
    model_init, loss_fn, acc_fn = _dispatch_probe(fed)
    probe_kw = dict(model_init=model_init, loss_fn=loss_fn, acc_fn=acc_fn)
    # sampler + analytic clock: the sweep-driver configuration (every
    # paper figure carries a time axis).  The eventful loop pays one
    # blocking mask pull per round for the clock's participant set — the
    # superstep returns all masks as a single stacked transfer per chunk
    kw = dict(sampler=UniformFraction(0.5), system=SYSTEMS["wired"],
              **probe_kw)

    # many marginal rounds: a fused round costs well under a millisecond,
    # so the short/long delta needs a long lever arm to clear run-to-run
    # fixed-cost noise (setup, placement, evals)
    r_short, r_long = 2, rounds + 2

    def fl_for(r):
        # one small momentum-less local step: the round is then ~pure
        # engine overhead, which is what the probe is for (the
        # fused/eventful compute term is identical either way — only the
        # overhead differs); eval_every past r_long so no mid-run eval
        # pollutes the short/long delta
        return FLConfig(rounds=r, local_steps=1, batch_size=4,
                        momentum=0.0, eval_every=10 * r_long)
    configs = [("host_vmap", None)] + \
        [("mesh_shard_map", s) for s in MIX_SCHEDULES]
    rows = []
    for name, schedule in configs:
        placement = HostVmap() if schedule is None else \
            MeshShardMap(schedule=schedule)
        rps = {}
        for fuse in (False, True):
            # compile warmup — both scan lengths for the fused engine
            # (one executable per chunk length); the eventful jits are
            # round-count independent, one short run warms them
            for r in ((r_short, r_long) if fuse else (r_short,)):
                run_federated("ucfl_k2", fed, fl=fl_for(r),
                              placement=placement, superstep=fuse, **kw)

            def timed(r):
                # best-of-3: the per-run fixed costs (setup, placement,
                # evals) fluctuate by more than a fused round costs
                best = float("inf")
                for _ in range(3):
                    t0 = time.perf_counter()
                    run_federated("ucfl_k2", fed, fl=fl_for(r),
                                  placement=placement, superstep=fuse,
                                  **kw)
                    best = min(best, time.perf_counter() - t0)
                return best

            delta = timed(r_long) - timed(r_short)
            # noisy runner can make the short run cost more than the
            # marginal long-run rounds; record null, not a bogus number
            rps[fuse] = (r_long - r_short) / delta if delta > 0 else None

        # parity anchor: fused vs eventful must agree on this placement
        fl_p = FLConfig(rounds=4, local_steps=2, batch_size=16,
                        eval_every=2)
        pkw = dict(fl=fl_p, sampler=UniformFraction(0.5),
                   channel=Channel(codec="qsgd:4"), placement=placement,
                   system=SYSTEMS["wired"], **probe_kw)
        h_ev = run_federated("ucfl_k2", fed, superstep=False, **pkw)
        h_ss = run_federated("ucfl_k2", fed, superstep=True, **pkw)
        exact = schedule != "gspmd" or len(jax.devices()) == 1
        acc_ok = (h_ss.mean_acc == h_ev.mean_acc if exact else
                  bool(np.allclose(h_ss.mean_acc, h_ev.mean_acc,
                                   atol=1e-5)))
        parity_ok = (acc_ok and h_ss.time == h_ev.time
                     and h_ss.comm == h_ev.comm
                     and h_ss.comm_bits == h_ev.comm_bits)
        if not parity_ok:
            raise RuntimeError(
                f"superstep parity anchor diverged on {name}"
                f"/{schedule or '-'}: eventful {h_ev.mean_acc} vs fused "
                f"{h_ss.mean_acc} (time {h_ev.time} vs {h_ss.time})")

        speedup = (rps[True] / rps[False]
                   if rps[True] and rps[False] else None)
        rows.append({"placement": name, "schedule": schedule,
                     "m": fed.m, "devices": len(jax.devices()),
                     "rounds": r_long - r_short, "model": "dispatch_probe",
                     "rounds_per_sec": rps[False],
                     "rounds_per_sec_superstep": rps[True],
                     "superstep_speedup": speedup,
                     "parity": "exact" if exact else "allclose"})
        fmt = lambda v: f"{v:8.2f}" if v else "   noise"
        print(f"{name:16s} schedule={schedule or '-':20s} "
              f"eventful={fmt(rps[False])} r/s  "
              f"superstep={fmt(rps[True])} r/s  "
              + (f"({speedup:4.1f}x)" if speedup else ""))
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_round_engine.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print("saved", path)
    return rows


def paging_bench(rounds: int = 64, seed: int = 0):
    """Cohort paging engine (DESIGN.md §3e) -> BENCH_paging.json:
    paged-vs-resident rounds/sec at EQUAL cohort, per placement, across
    population sizes — plus the analytic device-memory claim.

    The paged engine's promise is that device state scales with the
    cohort while the population lives in the host store — at the price of
    per-superstep gather/stage/scatter traffic.  Each row times a paged
    run (population n, sweep schedule, cohort 8) against the RESIDENT
    superstep engine on an m=8 federation — the same compiled superstep,
    so the ratio isolates the paging overhead.  Timing uses the
    round-engine bench's short/long delta (best-of-3, warmed up); the
    dispatch-probe model keeps the number about the engine, not convs.

    Before any timing, the §3e parity anchor runs IN-BENCH per placement
    and RAISES on divergence: a paged `FixedCohort` run over the
    population must be bit-identical (history AND final cohort rows) to a
    resident run on that sub-federation — a throughput number can never
    ship from an engine that pages wrong bits.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            (flags + " --xla_force_host_platform_device_count=8").strip()
    import jax
    import numpy as np
    from repro.data.federated import FederatedData, scenario_label_shift
    from repro.fl import (FLConfig, FixedCohort, HostVmap, MeshShardMap,
                          PagingConfig, SYSTEMS, run_federated,
                          sub_federated)

    cohort = 8
    fed8 = scenario_label_shift(jax.random.PRNGKey(seed), n=800, m=cohort)
    model_init, loss_fn, acc_fn = _dispatch_probe(fed8)
    probe_kw = dict(model_init=model_init, loss_fn=loss_fn, acc_fn=acc_fn)

    def tile(fed, reps):
        # population = `reps` copies of the m=8 federation: identical row
        # shapes, so resident-on-fed8 is the equal-cohort reference
        return FederatedData(*[jnp_concat(l, reps) for l in fed])

    def jnp_concat(leaf, reps):
        import jax.numpy as jnp
        return jnp.concatenate([leaf] * reps)

    # eval cadence IS the superstep boundary, i.e. the paging cadence:
    # every 4 rounds the paged engine gathers, stages and scatters a
    # fresh cohort — identical cadence on the resident reference, so the
    # delta compares equal work plus the paging traffic.  local_steps=8 x
    # batch 16 gives each round the local-epoch-scale compute the paper's
    # configs run — the double buffer needs real device work to hide the
    # staging behind; a 1-step batch-4 round is all engine and no client,
    # and nothing can hide multi-MB cohort traffic behind it
    def fl_for(r):
        return FLConfig(rounds=r, local_steps=8, batch_size=16,
                        momentum=0.0, eval_every=4)

    r_short, r_long = 8, 8 + rounds

    def timed(run):
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            run()
            best = min(best, time.perf_counter() - t0)
        return best

    def delta_rps(run_for):
        for r in (r_short, r_long):        # warm both scan-length sets
            run_for(r)
        d = timed(lambda: run_for(r_long)) - timed(lambda: run_for(r_short))
        return (r_long - r_short) / d if d > 0 else None

    placements = [("host_vmap", HostVmap),
                  ("mesh_shard_map",
                   lambda: MeshShardMap(schedule="shard_map_streams"))]
    rows = []
    for pname, pfac in placements:
        placement = pfac()
        # ---- §3e parity anchor (raises): paged == resident, bit for bit
        pop = tile(fed8, 8)
        idx = np.arange(cohort) * (pop.m // cohort)
        fl_p = FLConfig(rounds=6, local_steps=2, batch_size=16,
                        eval_every=2)
        akw = dict(fl=fl_p, system=SYSTEMS["wired"], placement=placement,
                   keep_state=True, **probe_kw)
        h_res = run_federated("ucfl_k2", sub_federated(pop, idx),
                              superstep=True, **akw)
        h_pag = run_federated("ucfl_k2", pop,
                              paging=PagingConfig(schedule=FixedCohort(idx)),
                              **akw)
        rows_ok = all(
            np.array_equal(np.asarray(lp)[idx], np.asarray(lr))
            for lp, lr in zip(
                jax.tree_util.tree_leaves(h_pag.final_params),
                jax.tree_util.tree_leaves(h_res.final_params)))
        if not (h_pag.mean_acc == h_res.mean_acc
                and h_pag.time == h_res.time
                and h_pag.comm == h_res.comm and rows_ok):
            raise RuntimeError(
                f"§3e paging parity anchor diverged on {pname}: "
                f"paged {h_pag.mean_acc} vs resident {h_res.mean_acc} "
                f"(rows_ok={rows_ok})")

        # ---- resident reference: the same cohort, never paged
        res_rps = delta_rps(lambda r: run_federated(
            "fedavg", fed8, fl=fl_for(r), placement=placement,
            superstep=True, **probe_kw))

        for reps in (8, 64):               # populations 64 and 512
            popn = tile(fed8, reps)
            paging = PagingConfig(cohort=cohort, schedule="sweep")
            pag_rps = delta_rps(lambda r: run_federated(
                "fedavg", popn, fl=fl_for(r), placement=placement,
                paging=paging, **probe_kw))
            h = run_federated("fedavg", popn, fl=fl_for(r_short),
                              placement=placement, paging=paging,
                              **probe_kw)
            pg = h.extra["paging"]
            bpc = pg["store_bytes"] // pg["population"]
            ratio = (res_rps / pag_rps if res_rps and pag_rps else None)
            rows.append({
                "placement": pname, "population": pg["population"],
                "cohort": cohort, "devices": len(jax.devices()),
                "rounds": r_long - r_short, "model": "dispatch_probe",
                "rounds_per_sec_resident": res_rps,
                "rounds_per_sec_paged": pag_rps,
                "resident_over_paged": ratio,
                "store_bytes": pg["store_bytes"],
                "bytes_per_client": bpc,
                # double-buffered device footprint: two cohorts of rows
                # in flight vs the whole population resident
                "device_state_bytes_paged": bpc * cohort * 2,
                "device_state_bytes_resident": bpc * pg["population"],
                "parity": "exact",
            })
            fmt = lambda v: f"{v:8.2f}" if v else "   noise"
            print(f"{pname:16s} n={pg['population']:4d} m={cohort} "
                  f"resident={fmt(res_rps)} r/s  paged={fmt(pag_rps)} r/s"
                  + (f"  ({ratio:4.2f}x)" if ratio else ""))
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_paging.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print("saved", path)
    return rows


def async_engine_bench(rounds_sync: int = 16, events_async: int = 48,
                       seed: int = 0):
    """Time-to-target-accuracy: sync vs buffered-async per strategy
    -> BENCH_async.json.

    Paper-shaped miniature (LeNet, m=8 label-shift clients) under the
    unreliable wireless system (inv_mu=1, rho=4): the synchronous engine
    charges every round the analytic straggler maximum (H_m/μ) while the
    async runtime's virtual clock waits only for the K-th earliest arrival
    (K = m/2).  Per strategy the sync run's final mean accuracy is the
    TARGET; the async run records the virtual-clock time of the first eval
    that reaches it.  ``async_wins`` = reached the target at lower clock
    time than the sync run's end.
    """
    import jax
    from repro.data.federated import scenario_label_shift
    from repro.fl import AsyncConfig, FLConfig, SYSTEMS, run_federated

    fed = scenario_label_shift(jax.random.PRNGKey(seed), n=800, m=8)
    system = SYSTEMS["wireless_slow"]
    async_cfg = AsyncConfig(buffer_k=fed.m // 2, max_staleness=None,
                            staleness_discount=0.9)
    specs = ["fedavg", "local", "oracle", "ucfl", "ucfl_k2", "cfl",
             "fedfomo"]
    fl_sync = FLConfig(rounds=rounds_sync, local_steps=4, batch_size=32,
                       eval_every=2, cfl_min_rounds=4)
    fl_async = FLConfig(rounds=events_async, local_steps=4, batch_size=32,
                        eval_every=2, cfl_min_rounds=4)
    rows = []
    for spec in specs:
        hs = run_federated(spec, fed, fl=fl_sync, system=system, seed=seed)
        target, t_sync = hs.mean_acc[-1], hs.time[-1]
        ha = run_federated(spec, fed, fl=fl_async, system=system, seed=seed,
                           async_cfg=async_cfg)
        hit = next(((t, a) for t, a in zip(ha.time, ha.mean_acc)
                    if a >= target), None)
        rows.append({
            "strategy": spec, "m": fed.m, "system": system.name,
            "buffer_k": async_cfg.buffer_k,
            "staleness_discount": async_cfg.staleness_discount,
            "sync_rounds": rounds_sync, "async_events": events_async,
            "target_mean_acc": target, "sync_time": t_sync,
            "async_time_to_target": None if hit is None else hit[0],
            "async_final_acc": ha.mean_acc[-1],
            "async_final_time": ha.time[-1],
            "async_wins": hit is not None and hit[0] < t_sync,
        })
        print(f"{spec:10s} target={target:.3f} sync_t={t_sync:7.1f} "
              + (f"async_t={hit[0]:7.1f} wins={hit[0] < t_sync}"
                 if hit else "async: target not reached"))
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_async.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print("saved", path)
    return rows


def channel_bench(rounds: int = 16, seed: int = 0):
    """Accuracy vs CUMULATIVE DOWNLINK BITS per (strategy × codec)
    -> BENCH_channel.json (the §3b bits axis of the paper's trade-off).

    Paper-shaped miniature (LeNet, m=8 covariate-shift clients — a
    scenario whose 16-round curve is still climbing, so the target is not
    degenerate).  Per strategy the uncompressed (identity-codec) run's
    final mean accuracy is the TARGET; each compressed run gets a 1.5×
    round budget (compression trades rounds for bits) and records the
    cumulative downlink bits of its first eval reaching the target.
    ``wins`` = reached the target with strictly fewer cumulative downlink
    bits than the identity run spent in total — the compression side of
    the trade-off the paper buys with stream reduction.  (Downlink bits
    are the §3b accounting projection: the engines compress uplink values
    only and charge the broadcast at compressed-model bits; see the
    EXPERIMENTS §Channel caveat.)
    """
    import jax
    from repro.data.federated import scenario_covariate_shift
    from repro.fl import Channel, FLConfig, run_federated

    fed = scenario_covariate_shift(jax.random.PRNGKey(seed), n=1500, m=8)

    def fl_for(r):
        return FLConfig(rounds=r, local_steps=2, batch_size=32,
                        eval_every=2, cfl_min_rounds=4)

    specs = ["fedavg", "ucfl_k2", "ucfl"]
    codecs = ["identity", "qsgd:8", "qsgd:4", "topk:0.25"]
    rows = []
    for spec in specs:
        target = None
        id_total = None
        for codec in codecs:
            r_budget = rounds if codec == "identity" else rounds * 3 // 2
            h = run_federated(spec, fed, fl=fl_for(r_budget), seed=seed,
                              channel=Channel(codec=codec))
            per_round = [c.dl_bits for c in h.comm_bits]
            cum_bits = [sum(per_round[:r + 1]) for r in h.rounds]
            total = sum(per_round)
            if codec == "identity":
                target, id_total = h.mean_acc[-1], total
            hit = next((b for b, a in zip(cum_bits, h.mean_acc)
                        if a >= target), None)
            wins = (codec != "identity" and hit is not None
                    and hit < id_total)
            rows.append({
                "strategy": spec, "codec": codec, "m": fed.m,
                "rounds": r_budget,
                "payload_bits": h.extra["channel"]["payload_bits"],
                "model_bits": h.extra["channel"]["model_bits"],
                "mean_acc": h.mean_acc, "cum_dl_bits": cum_bits,
                "final_acc": h.mean_acc[-1], "dl_bits_total": total,
                "target_acc": target,
                "dl_bits_to_target": hit,
                "wins": wins,
            })
            print(f"{spec:8s} {codec:10s} final={h.mean_acc[-1]:.3f} "
                  f"dl_total={total/1e6:7.1f} Mbit "
                  + (f"to_target={hit/1e6:7.1f} Mbit wins={wins}"
                     if hit is not None else "target not reached"))
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_channel.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print("saved", path)
    return rows


def serve_bench(requests: int = 128, reps: int = 3, max_batch: int = 16,
                seed: int = 0):
    """Personalized-model serving plane (DESIGN.md §3d)
    -> BENCH_serve.json: QPS / per-batch latency / at-rest store bytes per
    (placement × codec).

    One FULL-personalization ucfl run (LeNet, m=8 label-shift clients,
    keep_state=True — every user ends with a DISTINCT model) feeds every
    cell; the store keys the users against the scenario's k ground-truth
    cluster bases (`assignment=fed.group`), so every per-user delta is
    genuinely nonzero — the deployment shape the §3d store exists for
    (stream-reduced runs like ucfl_k2 end with members bit-identical to
    their stream base, i.e. all-zero deltas).  The §3d parity anchor runs
    IN-BENCH before any timing — served output must be bit-identical to a
    direct forward pass through the store's reference reconstruction
    (`check_parity` raises on divergence), and the identity store must
    reconstruct the trained personalized params exactly, so a QPS number
    can never ship from a store that serves the wrong model.
    """
    import jax
    import numpy as np
    from repro.data.federated import scenario_label_shift
    from repro.fl import (DeltaStore, FLConfig, HostVmap, MeshShardMap,
                          ServeEngine, check_parity, run_federated)
    from repro.fl.channel import stacked_ravel
    from repro.models import lenet

    fed = scenario_label_shift(jax.random.PRNGKey(seed), n=1000, m=8)
    fl = FLConfig(rounds=6, local_steps=2, batch_size=32, eval_every=3)
    h = run_federated("ucfl", fed, fl=fl, seed=seed, keep_state=True)
    true_flat = np.asarray(stacked_ravel(h.final_params), np.float32)
    asn = np.asarray(fed.group, np.int64)
    print(f"trained ucfl m={fed.m}: final acc={h.mean_acc[-1]:.3f}")

    def apply_one(p_, x):
        return lenet.apply(p_, x[None])[0]

    rng = np.random.default_rng(seed)
    users = rng.integers(0, fed.m, requests)
    xs_all = np.asarray(fed.x_val)[users, 0]
    probe = list(range(fed.m))
    xs_probe = np.asarray(fed.x_val)[probe, 0]

    placements = [("host_vmap", HostVmap()),
                  ("mesh_shard_map", MeshShardMap(schedule="shard_map_streams"))]
    rows = []
    for pname, pl in placements:
        for codec in ["identity", "qsgd:4", "topk:0.25"]:
            store = DeltaStore.build(h.final_params, assignment=asn,
                                     codec=codec, backend=pl.codec_backend)
            if codec == "identity" and not np.array_equal(
                    np.asarray(store.params_flat()), true_flat):
                raise RuntimeError(
                    "identity DeltaStore is not lossless — §3d anchor")
            eng = ServeEngine(store, apply_one, placement=pl,
                              max_batch=max_batch)
            check_parity(eng, probe, xs_probe)       # raises on divergence
            # warmup: compile the (gather, forward) pair for max_batch
            for u, x in zip(users[:max_batch], xs_all[:max_batch]):
                eng.submit(int(u), x)
            eng.flush()
            lat = []
            t0 = time.perf_counter()
            for _ in range(reps):
                for u, x in zip(users, xs_all):
                    eng.submit(int(u), x)
                eng.flush()
                lat += eng.last_stats["latency_s"]
            dt = time.perf_counter() - t0
            qps = reps * requests / dt
            row = {
                "placement": pname, "codec": codec,
                "m": fed.m, "k": store.k, "d": store.d,
                "requests": reps * requests, "max_batch": max_batch,
                "qps": qps,
                "batch_p50_ms": float(np.percentile(lat, 50) * 1e3),
                "batch_p99_ms": float(np.percentile(lat, 99) * 1e3),
                "store_bytes": int(store.bits.total_bytes),
                "base_bits": int(store.bits.base_bits),
                "delta_bits": int(store.bits.delta_bits.sum()),
                "dense_bytes": (fed.m * store.d * 32 + 7) // 8,
                "max_recon_err": float(store.recon_err.max()),
                "parity": "ok",
            }
            rows.append(row)
            print(f"{pname:15s} {codec:10s} qps={qps:7.1f} "
                  f"p50={row['batch_p50_ms']:6.1f}ms "
                  f"p99={row['batch_p99_ms']:6.1f}ms "
                  f"store={row['store_bytes']/1e6:.2f}MB "
                  f"(dense {row['dense_bytes']/1e6:.2f}MB) parity=ok")
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print("saved", path)
    return rows


def hierarchy_bench(rounds: int = 12, seed: int = 0):
    """Flat vs two-level time-to-target under tiered device links
    (DESIGN.md §3f) -> BENCH_hierarchy.json.

    The §3f FLAT-PARITY ANCHOR RUNS IN-BENCH FIRST, on both placements,
    for both benchmarked strategies: a ``devices_per_user=1`` hierarchy
    (identity edge codec, mean aggregation, zero latency) must reproduce
    the flat engine bit-for-bit — accuracy history, clock, comm_bits AND
    final params — and the bench RAISES on any divergence, so a headline
    number can never ship from an edge tier that changed the math.

    Then per strategy: the flat run's final mean accuracy is the TARGET;
    the two-level run (ragged 2–4-device fleets, qsgd:4 edge codec under
    a tiered:4 device link, 0.5 T_dl edge latency) charges BOTH hops on
    the analytic clock and records the virtual time of its first eval
    reaching the target — the cost of user-side fleets under the paper's
    user→server round left unchanged.  The two-level run gets a 1.5×
    round budget (the channel-bench convention): the edge qsgd hop
    trades rounds for device-side bits, so the question is the CLOCK
    price of the target, not same-round accuracy.
    """
    import jax
    import numpy as np
    from repro.data.federated import scenario_covariate_shift
    from repro.fl import (FLConfig, HierarchyConfig, HostVmap, MeshShardMap,
                          SYSTEMS, run_federated)

    fed = scenario_covariate_shift(jax.random.PRNGKey(seed), n=1500, m=8)
    fl = FLConfig(rounds=rounds, local_steps=2, batch_size=32, eval_every=2)
    specs = ["fedavg", "ucfl_k2"]
    flat_cfg = HierarchyConfig(devices_per_user=1)
    placements = [("host_vmap", HostVmap),
                  ("mesh_shard_map",
                   lambda: MeshShardMap(schedule="shard_map_streams"))]

    for pname, pfn in placements:
        for spec in specs:
            kw = dict(fl=fl, seed=seed, system=SYSTEMS["wired"],
                      placement=pfn(), keep_state=True)
            h0 = run_federated(spec, fed, **kw)
            h1 = run_federated(spec, fed, hierarchy=flat_cfg, **kw)
            if (h0.mean_acc != h1.mean_acc or h0.worst_acc != h1.worst_acc
                    or h0.time != h1.time or h0.comm_bits != h1.comm_bits):
                raise RuntimeError(
                    f"§3f flat-parity anchor FAILED ({spec} on {pname}): "
                    "devices_per_user=1 diverged from the flat engine")
            for la, lb in zip(jax.tree_util.tree_leaves(h0.final_params),
                              jax.tree_util.tree_leaves(h1.final_params)):
                if not np.array_equal(np.asarray(la), np.asarray(lb)):
                    raise RuntimeError(
                        f"§3f flat-parity anchor FAILED ({spec} on "
                        f"{pname}): final params diverged")
            print(f"flat-parity anchor ok: {spec} on {pname}")

    two_cfg = HierarchyConfig(devices_per_user="ragged:2-4",
                              edge_codec="qsgd:4", edge_link="tiered:4",
                              edge_latency=0.5, seed=seed)
    rows = []
    for spec in specs:
        h_flat = run_federated(spec, fed, fl=fl, seed=seed,
                               system=SYSTEMS["wired"])
        target = h_flat.mean_acc[-1]
        fl_two = FLConfig(rounds=int(rounds * 1.5), local_steps=2,
                          batch_size=32, eval_every=2)
        h_two = run_federated(spec, fed, fl=fl_two, seed=seed,
                              system=SYSTEMS["wired"], hierarchy=two_cfg)
        hit = next((t for t, a in zip(h_two.time, h_two.mean_acc)
                    if a >= target), None)
        ex = h_two.extra["hierarchy"]
        rows.append({
            "strategy": spec, "m": fed.m, "rounds": rounds,
            "rounds_two_level": fl_two.rounds,
            "devices_per_user": ex["devices_per_user"],
            "edge_codec": ex["edge_codec"],
            "edge_link": ex["edge_link"],
            "edge_latency": ex["edge_latency"],
            "target_acc": target,
            "flat_time": h_flat.time[-1],
            "two_level_final_acc": h_two.mean_acc[-1],
            "two_level_time": h_two.time[-1],
            "time_to_target": hit,
            "slowdown_at_end": h_two.time[-1] / h_flat.time[-1],
            "edge_dl_bits_total": ex["edge_dl_bits_total"],
            "edge_ul_bits_total": ex["edge_ul_bits_total"],
            "server_dl_bits_total": sum(c.dl_bits for c in h_two.comm_bits),
            "server_ul_bits_total": sum(c.ul_bits for c in h_two.comm_bits),
            "parity": "ok",
        })
        print(f"{spec:8s} target={target:.3f} "
              f"flat_t={h_flat.time[-1]:7.1f} "
              f"two_t={h_two.time[-1]:7.1f} "
              + (f"to_target={hit:7.1f}" if hit is not None
                 else "target not reached")
              + f" edge_ul={ex['edge_ul_bits_total']/1e6:7.1f} Mbit")
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_hierarchy.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print("saved", path)
    return rows


def faults_bench(rounds: int = 12, seed: int = 0):
    """Accuracy-under-attack: Byzantine sweep × robust aggregators
    (DESIGN.md §3g) -> BENCH_faults.json.

    The §3g FAULTS-OFF PARITY ANCHOR RUNS IN-BENCH FIRST, on both
    placements and on the fused, eventful and async engines, for both
    benchmarked strategies: a zero-rate fault spec with robust_agg="none"
    must reproduce the clean engine bit-for-bit — accuracy history,
    clock AND final params — and the bench RAISES on any divergence, so
    a headline number can never ship from a fault layer that changed the
    math of the clean path.

    Then per strategy × defense: 25% of clients turn sign-flip Byzantine
    (−10·Δ, the gradient-ascent attack) and the table records HONEST-
    client mean accuracy (the Byzantine-FL convention: adversaries'
    personal eval is excluded — their data legitimately never
    contributes) against the clean run.  ``none`` must demonstrably
    degrade and at least one robust rule must recover ≥90% of the clean
    accuracy, or the bench fails loudly instead of shipping the table.
    """
    import jax
    import numpy as np
    from repro.data.federated import scenario_covariate_shift
    from repro.fl import (AsyncConfig, FLConfig, HostVmap, MeshShardMap,
                          SYSTEMS, run_federated)
    from repro.models import lenet

    fed = scenario_covariate_shift(jax.random.PRNGKey(seed), n=1500, m=8)
    fl = FLConfig(rounds=rounds, local_steps=2, batch_size=32, eval_every=2)
    specs = ["fedavg", "ucfl_k2"]
    placements = [("host_vmap", HostVmap),
                  ("mesh_shard_map",
                   lambda: MeshShardMap(schedule="shard_map_streams"))]
    off = dict(faults="crash:0,byz:0,nan:0,bitrot:0", robust_agg="none")

    def check(tag, h0, h1):
        if (h0.mean_acc != h1.mean_acc or h0.worst_acc != h1.worst_acc
                or h0.time != h1.time):
            raise RuntimeError(f"§3g faults-off parity anchor FAILED "
                               f"({tag}): history diverged")
        for la, lb in zip(jax.tree_util.tree_leaves(h0.final_params),
                          jax.tree_util.tree_leaves(h1.final_params)):
            if not np.array_equal(np.asarray(la), np.asarray(lb)):
                raise RuntimeError(f"§3g faults-off parity anchor FAILED "
                                   f"({tag}): final params diverged")
        print(f"faults-off parity anchor ok: {tag}")

    for pname, pfn in placements:
        for spec in specs:
            kw = dict(fl=fl, seed=seed, system=SYSTEMS["wired"],
                      placement=pfn(), keep_state=True)
            check(f"{spec} fused on {pname}",
                  run_federated(spec, fed, **kw),
                  run_federated(spec, fed, **off, **kw))
            check(f"{spec} eventful on {pname}",
                  run_federated(spec, fed, superstep=False, **kw),
                  run_federated(spec, fed, superstep=False, **off, **kw))
            acfg = AsyncConfig(buffer_k=4)
            aoff = dict(off, async_cfg=AsyncConfig(buffer_k=4,
                                                   max_retries=7,
                                                   retry_backoff=3.0))
            check(f"{spec} async on {pname}",
                  run_federated(spec, fed, async_cfg=acfg, **kw),
                  run_federated(spec, fed, **aoff, **kw))

    attack = "byz:0.25:sign_flip"
    defenses = ["none", "trimmed_mean:0.25", "krum:0.25", "median"]
    peracc = jax.jit(jax.vmap(
        lambda p, x, y: lenet.accuracy(p, {"x": x, "y": y})))

    def honest_acc(h, byz):
        accs = np.asarray(peracc(h.final_params, fed.x_val, fed.y_val))
        keep = np.ones(len(accs), bool)
        keep[list(byz)] = False
        return float(accs[keep].mean())

    rows = []
    for spec in specs:
        kw = dict(fl=fl, seed=seed, system=SYSTEMS["wired"],
                  placement=HostVmap(), keep_state=True)
        h_clean = run_federated(spec, fed, **kw)
        byz = None
        for defense in defenses:
            h = run_federated(spec, fed, faults=attack, robust_agg=defense,
                              **kw)
            fx = h.extra["faults"]
            byz = fx["byzantine_clients"]
            clean_acc = honest_acc(h_clean, byz)
            acc = honest_acc(h, byz)
            rows.append({
                "strategy": spec, "m": fed.m, "rounds": rounds,
                "faults": fx["faults"], "robust_agg": defense,
                "byzantine_clients": byz,
                "clean_honest_acc": clean_acc,
                "honest_acc": acc,
                "mean_acc": h.mean_acc[-1],
                "recovery": acc / clean_acc if clean_acc else None,
                "quarantined_total": fx["quarantined_total"],
                "parity": "ok",
            })
            print(f"{spec:8s} {defense:18s} honest={acc:.3f} "
                  f"clean={clean_acc:.3f} recovery={acc / clean_acc:.2f}")
        by_def = {r["robust_agg"]: r for r in rows
                  if r["strategy"] == spec}
        if by_def["none"]["recovery"] >= 0.6:
            raise RuntimeError(
                f"§3g attack too weak ({spec}): undefended recovery "
                f"{by_def['none']['recovery']:.2f} >= 0.6 — the Byzantine "
                "sweep demonstrates nothing")
        best = max(by_def[d]["recovery"]
                   for d in ("trimmed_mean:0.25", "krum:0.25"))
        if best < 0.9:
            raise RuntimeError(
                f"§3g defense too weak ({spec}): best robust recovery "
                f"{best:.2f} < 0.9")
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_faults.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print("saved", path)
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--group", choices=tuple(ITERATIONS) + ("all",),
                   default="all")
    p.add_argument("--round-engine", action="store_true",
                   help="benchmark the federated round engine per "
                        "placement × schedule instead of dry-run variants")
    p.add_argument("--paging", action="store_true",
                   help="paged-vs-resident rounds/sec at equal cohort "
                        "across population sizes — the §3e paging "
                        "benchmark (runs the parity anchor in-bench)")
    p.add_argument("--async-engine", action="store_true",
                   help="time-to-target-accuracy of the buffered-async "
                        "runtime vs the sync engine, per strategy")
    p.add_argument("--channel", action="store_true",
                   help="accuracy vs cumulative downlink bits per "
                        "(strategy × codec) — the §3b channel benchmark")
    p.add_argument("--serve", action="store_true",
                   help="personalized serving QPS/latency/store-bytes per "
                        "(placement × codec) — the §3d serve benchmark")
    p.add_argument("--hierarchy", action="store_true",
                   help="flat vs two-level time-to-target under tiered "
                        "device links — the §3f hierarchy benchmark (runs "
                        "the flat-parity anchor in-bench, raises on "
                        "divergence)")
    p.add_argument("--faults", action="store_true",
                   help="accuracy-under-attack: Byzantine sweep × robust "
                        "aggregators — the §3g faults benchmark (runs the "
                        "faults-off parity anchor in-bench on every "
                        "engine × placement, raises on divergence)")
    args = p.parse_args(argv)
    if args.round_engine:
        round_engine_bench()
        return
    if args.paging:
        paging_bench()
        return
    if args.async_engine:
        async_engine_bench()
        return
    if args.channel:
        channel_bench()
        return
    if args.serve:
        serve_bench()
        return
    if args.hierarchy:
        hierarchy_bench()
        return
    if args.faults:
        faults_bench()
        return
    # dryrun import must precede everything jax-touching (sets XLA_FLAGS)
    from repro.launch.dryrun import run_case
    os.makedirs(RESULTS, exist_ok=True)
    groups = list(ITERATIONS) if args.group == "all" else [args.group]
    path = os.path.join(RESULTS, "perf_iterations.json")
    summary = []
    if os.path.exists(path):
        with open(path) as f:
            summary = json.load(f)
    done = {(s["group"], s["name"]) for s in summary}
    for g in groups:
        for name, arch, shape, kw, hypothesis in ITERATIONS[g]:
            if (g, name) in done:
                print(f"skip {g}/{name} (already recorded)")
                continue
            print(f"--- {g}/{name}: {hypothesis}")
            res = run_case(arch, shape, tag=f"{g}_{name}", **kw)
            summary.append({"group": g, "name": name, "arch": arch,
                            "shape": shape, "hypothesis": hypothesis,
                            "result": {k: res[k] for k in
                                       ("t_compute", "t_memory",
                                        "t_collective", "bottleneck",
                                        "collectives",
                                        "useful_flops_ratio")}})
            with open(path, "w") as f:
                json.dump(summary, f, indent=1)
    print("saved", path)


if __name__ == "__main__":
    main()
