"""Paper experiment reproduction: Fig.2 (accuracy vs rounds, 3 scenarios),
Table I (worst-user accuracy), Fig.3 (accuracy vs wall-clock in 3 systems).

Synthetic-data reruns of the paper's protocols (DESIGN.md §1): numbers are
validated as ORDERINGS, not absolute accuracies.  Results are dumped to
benchmarks/results/*.json for EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.paper_experiments [--quick] [--trials N]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.data.federated import SCENARIOS
from repro.fl import (FLConfig, SYSTEMS, UniformFraction, get_strategy,
                      run_federated)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

ALGS = ["local", "fedavg", "oracle", "cfl", "fedfomo",
        "ucfl_k2", "ucfl_k4", "ucfl"]


def scenario_params(quick: bool):
    if quick:
        return {
            "emnist_label_shift": dict(n=1600, m=8),
            "emnist_covariate_shift": dict(n=1600, m=8),
            "cifar_concept_shift": dict(n=1600, m=8),
        }, FLConfig(rounds=12, local_steps=5, batch_size=32, eval_every=3)
    # paper: 20 users (100 for covariate shift), 5 trials; CPU-gated here to
    # 20 users / 2 trials / 30 rounds — orderings are the validated claim.
    return {
        "emnist_label_shift": dict(n=6000, m=20),
        "emnist_covariate_shift": dict(n=6000, m=20),
        "cifar_concept_shift": dict(n=5000, m=20),
    }, FLConfig(rounds=30, local_steps=5, batch_size=32, eval_every=3)


def _system_time_axes(comm_log, eval_rounds, n_participants: int) -> dict:
    """Fig.3 time axes for every SystemModel from one run's per-round
    (n_streams, n_unicasts) log — the accuracy trace is system-independent,
    only the clock differs, so no re-run is needed.  ``n_participants`` is
    the per-round cohort size: a round waits for H_|S| stragglers."""
    axes = {}
    for sysname, sysm in SYSTEMS.items():
        t, cum = 0.0, []
        for ns, nu in comm_log:
            t += sysm.round_time(n_participants, n_streams=ns, n_unicasts=nu)
            cum.append(t)
        axes[sysname] = [cum[r] for r in eval_rounds]
    return axes


def run_scenario(name: str, params: dict, fl: FLConfig, trials: int,
                 algs=None, participation: float = 1.0) -> dict:
    algs = algs or ALGS
    sampler = (UniformFraction(participation) if participation != 1.0
               else None)
    out = {"scenario": name, "params": params, "rounds": fl.rounds,
           "participation": participation, "algorithms": {}}
    for alg in algs:
        strategy = get_strategy(alg)
        t0 = time.time()
        runs = []
        for t in range(trials):
            key = jax.random.PRNGKey(100 + t)
            fed = SCENARIOS[name](key, seed=t, **params)
            h = run_federated(strategy=strategy, fed=fed, fl=fl, seed=t,
                              sampler=sampler)
            runs.append(h)
        out["algorithms"][alg] = {
            "rounds": runs[0].rounds,
            "mean_acc": np.mean([r.mean_acc for r in runs], 0).tolist(),
            "worst_acc": np.mean([r.worst_acc for r in runs], 0).tolist(),
            "time_by_system": _system_time_axes(
                runs[0].extra["comm_per_round"], runs[0].rounds,
                max(1, int(round(participation * params["m"])))),
            "final_mean": float(np.mean([r.mean_acc[-1] for r in runs])),
            "final_worst": float(np.mean([r.worst_acc[-1] for r in runs])),
            "wall_seconds": time.time() - t0,
        }
        a = out["algorithms"][alg]
        print(f"  {name} {alg:10s} mean={a['final_mean']:.3f} "
              f"worst={a['final_worst']:.3f} ({a['wall_seconds']:.0f}s)")
    return out


def comm_efficiency_view(scenario_result: dict) -> dict:
    """Fig.3 from the covariate-shift runs: per system, the accuracy each
    algorithm reaches by a fixed time budget (analytic clock)."""
    out = {}
    algs = ["fedavg", "ucfl_k4", "ucfl", "fedfomo", "cfl"]
    for sysname in SYSTEMS:
        rows = {}
        budget = None
        for alg in algs:
            a = scenario_result["algorithms"].get(alg)
            if a is None:
                continue
            times = a["time_by_system"][sysname]
            budget = min(budget, times[-1]) if budget else times[-1]
        for alg in algs:
            a = scenario_result["algorithms"].get(alg)
            if a is None:
                continue
            times, accs = a["time_by_system"][sysname], a["mean_acc"]
            acc_at = max((acc for t_, acc in zip(times, accs) if t_ <= budget),
                         default=accs[0])
            rows[alg] = {"acc_at_budget": acc_at, "budget": budget,
                         "final_time": times[-1], "final_mean": accs[-1]}
        out[sysname] = {"algorithms": rows}
    return out


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    p.add_argument("--trials", type=int, default=1)
    p.add_argument("--skip-comm", action="store_true")
    p.add_argument("--participation", type=float, default=1.0,
                   help="uniform fraction of clients sampled per round")
    args = p.parse_args(argv)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    params, fl = scenario_params(args.quick)
    tag = "quick" if args.quick else "full"

    results = {}
    for name in SCENARIOS:
        print(f"== scenario {name} ==")
        results[name] = run_scenario(name, params[name], fl, args.trials,
                                     participation=args.participation)
        with open(os.path.join(RESULTS_DIR, f"paper_{tag}.json"), "w") as f:
            json.dump(results, f, indent=1)
    if not args.skip_comm:
        print("== comm efficiency (Fig.3, analytic view) ==")
        results["comm_efficiency"] = comm_efficiency_view(
            results["emnist_covariate_shift"])
    with open(os.path.join(RESULTS_DIR, f"paper_{tag}.json"), "w") as f:
        json.dump(results, f, indent=1)
    print("saved", os.path.join(RESULTS_DIR, f"paper_{tag}.json"))
    return results


if __name__ == "__main__":
    main()
