"""Benchmark harness entry point — one function per paper table/figure plus
kernel microbenches.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--full]

Quick mode (default) keeps everything CPU-tractable; --full matches the
paper's scale (see benchmarks/paper_experiments.py) and takes ~1h on one
core.  The roofline table (dry-run derived) is emitted by
``python -m benchmarks.roofline_table``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


def bench_kernels(rows):
    """Kernel microbenches: oracle (jnp, XLA-compiled — the measurable
    number on CPU) and the Pallas kernel in interpret mode (correctness
    path; TPU is the perf target)."""
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(0)
    w = jax.random.uniform(key, (4, 16))
    theta = jax.random.normal(key, (16, 1 << 20))
    us, _ = timeit(jax.jit(ref.mixing_aggregate_ref), w, theta)
    rows.append(("kernel.mixing_aggregate.ref_16x1M", us,
                 f"GBps={theta.nbytes/us*1e6/1e9:.1f}"))
    g = jax.random.normal(key, (16, 1 << 18))
    us, _ = timeit(jax.jit(ref.pairwise_sqdist_ref), g)
    rows.append(("kernel.pairwise_sqdist.ref_16x256k", us,
                 f"GBps={g.nbytes/us*1e6/1e9:.1f}"))
    q = jax.random.normal(key, (1, 8, 1024, 64))
    k = jax.random.normal(key, (1, 8, 1024, 64))
    v = jax.random.normal(key, (1, 8, 1024, 64))
    us, _ = timeit(jax.jit(lambda a, b, c: ref.flash_attention_ref(
        a, b, c, causal=True)), q, k, v)
    flops = 4 * 8 * 1024 * 1024 * 64 / 2
    rows.append(("kernel.flash_attention.ref_1k", us,
                 f"GFLOPs={flops/us*1e6/1e9:.1f}"))
    # interpret-mode kernel (small shape): correctness-path latency
    us, _ = timeit(lambda: ops.mixing_aggregate(w, theta[:, :4096]),
                   warmup=1, iters=1)
    rows.append(("kernel.mixing_aggregate.pallas_interpret_4k", us,
                 "interpret=True"))


def bench_fl_round(rows):
    """Steady-state FL round latency (paper's simulation engine)."""
    from repro.data.federated import scenario_label_shift
    from repro.fl import FLConfig, UniformFraction, get_strategy, run_federated
    key = jax.random.PRNGKey(0)
    fed = scenario_label_shift(key, n=800, m=8)
    fl = FLConfig(rounds=2, local_steps=5, batch_size=32, eval_every=10)
    t0 = time.time()
    run_federated(strategy=get_strategy("fedavg"), fed=fed, fl=fl)
    rows.append(("fl.round.fedavg_m8", (time.time() - t0) / 2 * 1e6,
                 "incl_compile"))
    t0 = time.time()
    run_federated(strategy=get_strategy("fedavg"), fed=fed, fl=fl,
                  sampler=UniformFraction(0.5))
    rows.append(("fl.round.fedavg_m8_frac50", (time.time() - t0) / 2 * 1e6,
                 "participation=0.5"))


def bench_paper_tables(rows, full: bool):
    """Fig.2 / Table I / Fig.3 quick reproductions -> derived = accuracies."""
    from benchmarks.paper_experiments import main as paper_main
    argv = [] if full else ["--quick", "--skip-comm"]
    results = paper_main(argv)
    for scen, data in results.items():
        if scen == "comm_efficiency":
            for sysname, sdata in data.items():
                best = max(sdata["algorithms"],
                           key=lambda a: sdata["algorithms"][a]["final_mean"])
                rows.append((f"fig3.{sysname}.best_alg", 0.0, best))
            continue
        algs = data["algorithms"]
        for alg, a in algs.items():
            rows.append((f"fig2.{scen}.{alg}", a["wall_seconds"] * 1e6,
                         f"mean={a['final_mean']:.3f}"))
            rows.append((f"table1.{scen}.{alg}", 0.0,
                         f"worst={a['final_worst']:.3f}"))


def bench_train_step(rows):
    """Mesh train-step latency on host mesh (smoke config)."""
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import (build_train_step, init_stacked_params,
                                    make_optimizer)
    cfg = get_smoke_config("stablelm-3b")
    mesh = make_host_mesh()
    m = 4
    key = jax.random.PRNGKey(0)
    params = init_stacked_params(key, cfg, m)
    opt = make_optimizer(cfg)
    opt_state = opt.init(params)
    batch = {"tokens": jax.random.randint(key, (m, 2, 64), 0, cfg.vocab_size)}
    w = jnp.full((1, m), 1.0 / m)
    assign = jnp.zeros((m,), jnp.int32)
    step = jax.jit(build_train_step(cfg, mesh, remat=False))
    us, out = timeit(lambda: step(params, opt_state, batch, w, assign)[2])
    rows.append(("launch.train_step.smoke_m4", us,
                 f"loss={float(out['loss']):.3f}"))


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    args = p.parse_args(argv)
    rows = []
    bench_kernels(rows)
    bench_train_step(rows)
    bench_fl_round(rows)
    bench_paper_tables(rows, args.full)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
