"""Regenerate EXPERIMENTS.md data sections from benchmark artifacts.

Reads benchmarks/dryrun_artifacts/*/*.json, benchmarks/results/paper_*.json,
benchmarks/results/perf_iterations.json and
benchmarks/results/BENCH_*.json; rewrites the §Paper, §Dry-run,
§Roofline, §Channel, §Serve and §Hierarchy bodies of EXPERIMENTS.md
between the AUTOGEN markers (a marker skeleton is created if
EXPERIMENTS.md is missing).
§Perf is narrative (hand-written hypothesis log) and is left untouched.

    PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import glob
import json
import os
import re

HERE = os.path.dirname(__file__)
ARTIFACT_DIR = os.path.join(HERE, "dryrun_artifacts")
RESULTS_DIR = os.path.join(HERE, "results")
EXPERIMENTS = os.path.join(HERE, "..", "EXPERIMENTS.md")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["olmoe-1b-7b", "gemma-2b", "mamba2-780m", "zamba2-2.7b",
              "stablelm-3b", "deepseek-v3-671b", "gemma2-27b",
              "nemotron-4-340b", "whisper-tiny", "paligemma-3b"]


def _key(r):
    return (ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER else 99,
            SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 9)


def load_mesh(mesh: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, mesh, "*.json"))):
        base = os.path.basename(path)[:-5]
        if len(base.split("__")) != 2:
            continue  # tagged perf artifacts
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=_key)
    return rows


def _ms(x):
    return f"{x*1e3:.2f}"


def _gb(x):
    return f"{x/2**30:.2f}"


def dryrun_section() -> str:
    out = ["Every (arch × shape) lowers **and** compiles on both production "
           "meshes; `memory_analysis()` / `cost_analysis()` per case are in "
           "`benchmarks/dryrun_artifacts/<mesh>/<arch>__<shape>.json`.",
           "",
           "Peak mem = argument+output+temp from `memory_analysis()`.  Cases "
           "over the 16 GiB v5e HBM budget are real findings, not compile "
           "failures: train_4k for the giants (deepseek-v3-671b, "
           "nemotron-4-340b) needs gradient-accumulation microbatching or "
           "more chips (DeepSeek-V3 itself trained on 2048 devices — our "
           "256/512-chip mesh is the assignment's, so the dry-run records "
           "the overshoot honestly; see §Perf for the microbatching knob).",
           ""]
    for mesh, label in (("pod16x16", "single-pod 16×16 (256 chips)"),
                        ("pod2x16x16", "multi-pod 2×16×16 (512 chips)")):
        rows = load_mesh(mesh)
        out += [f"### {label} — {len(rows)}/40 compiled", "",
                "| arch | shape | compile s | peak mem GiB/dev | "
                "dominant collective (GiB/dev) |",
                "|---|---|---|---|---|"]
        for r in rows:
            coll = r.get("collectives", {})
            top = max(coll, key=coll.get) if coll else "-"
            top_s = f"{top} ({_gb(coll[top])})" if coll and coll[top] else "—"
            peak = r.get("peak_memory_per_device")
            out.append(
                f"| {r['arch']} | {r['shape']} | "
                f"{r.get('compile_seconds', 0):.0f} | "
                f"{_gb(peak) if peak else '?'} | {top_s} |")
        out.append("")
    return "\n".join(out)


def roofline_section() -> str:
    rows = load_mesh("pod16x16")
    out = ["Terms per §Roofline spec: `t = X / (chips × peak)` with "
           "197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI per chip; "
           "cost_analysis() is per-device post-GSPMD.  MODEL_FLOPS = "
           "6·N_active·D (train) / 2·N_active·D (serve).", "",
           "| arch | shape | compute ms | memory ms | collective ms | "
           "bottleneck | useful-FLOPs ratio |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {_ms(r['t_compute'])} | "
            f"{_ms(r['t_memory'])} | {_ms(r['t_collective'])} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.3f} |")
    bott = {}
    for r in rows:
        bott[r["bottleneck"]] = bott.get(r["bottleneck"], 0) + 1
    out += ["", f"Bottleneck census: {bott}. Decode shapes are "
            "memory-bound (weights+cache re-read per token), train/prefill "
            "of small-TP-friendly archs go collective-bound — the mixing "
            "and activation all-reduces dominate; see §Perf."]
    return "\n".join(out)


def paper_section() -> str:
    path = os.path.join(RESULTS_DIR, "paper_full.json")
    if not os.path.exists(path):
        return "(paper_full.json not yet produced)"
    with open(path) as f:
        res = json.load(f)
    out = ["Synthetic-data reruns of the paper's three scenarios "
           "(DESIGN.md §1: orderings are the claim, not absolute digits). "
           "m=20 users, 30 rounds, 2 trials (paper: 5).", ""]
    fracs = {s.get("participation", 1.0) for s in res.values()
             if isinstance(s, dict) and "algorithms" in s}
    if fracs - {1.0}:
        out += [f"Client participation per round: uniform fraction "
                f"{sorted(fracs)} (DESIGN.md §6 sampler).", ""]
    scen_names = {
        "emnist_label_shift": "EMNIST label shift (Dirichlet 0.4)",
        "emnist_covariate_shift": "EMNIST label+covariate shift (4 rotations)",
        "cifar_concept_shift": "CIFAR concept shift (4 label permutations)"}
    out += ["| scenario | local | fedavg | oracle | cfl | fedfomo | "
            "ucfl k=2 | ucfl k=4 | ucfl full |", "|---|" + "---|" * 8]
    algs = ["local", "fedavg", "oracle", "cfl", "fedfomo",
            "ucfl_k2", "ucfl_k4", "ucfl"]
    for scen, title in scen_names.items():
        if scen not in res:
            continue
        a = res[scen]["algorithms"]
        cells = [f"{a[x]['final_mean']:.3f}" if x in a else "—" for x in algs]
        out.append(f"| {title} (mean) | " + " | ".join(cells) + " |")
        cells = [f"{a[x]['final_worst']:.3f}" if x in a else "—" for x in algs]
        out.append(f"| {title} (worst user, Table I) | " +
                   " | ".join(cells) + " |")
    if "comm_efficiency" in res:
        out += ["", "Fig.3 (accuracy at equal analytic time budget; "
                "ρ/straggler model per system):", "",
                "| system | " + " | ".join(
                    ["fedavg", "ucfl_k4", "ucfl", "fedfomo", "cfl"]) + " |",
                "|---|" + "---|" * 5]
        for sysname, data in res["comm_efficiency"].items():
            row = [f"{data['algorithms'][a]['acc_at_budget']:.3f}"
                   if a in data["algorithms"] else "—"
                   for a in ["fedavg", "ucfl_k4", "ucfl", "fedfomo", "cfl"]]
            out.append(f"| {sysname} | " + " | ".join(row) + " |")
    return "\n".join(out)


def channel_section() -> str:
    """Personalization/communication trade-off on the BITS axis next to
    the legacy T_dl axis (DESIGN.md §3b; BENCH_channel.json)."""
    path = os.path.join(RESULTS_DIR, "BENCH_channel.json")
    if not os.path.exists(path):
        return ("(BENCH_channel.json not yet produced — run "
                "`python -m benchmarks.perf_iterations --channel`)")
    with open(path) as f:
        rows = json.load(f)
    out = ["Accuracy vs cumulative DOWNLINK payload per (strategy × codec) "
           "— the same trade-off the paper draws in T_dl broadcast units, "
           "re-measured in bits.  The legacy axis charges every stream one "
           "full model (T_dl = payloads × model); the bits axis charges the "
           "codec-compressed payload.  `to target` = cumulative downlink "
           "bits at the first eval reaching the uncompressed run's final "
           "accuracy (its round budget is 1.5× — compression trades rounds "
           "for bits).  Caveat: the engines compress only the UPLINK "
           "values; the downlink bits assume a server-side codec twin "
           "(ROADMAP follow-on) and are an accounting projection, exact "
           "for qsgd (the mixed model quantizes the same way) but "
           "optimistic for topk (a dense mix is not k-sparse).", "",
           "| strategy | codec | final acc | downlink Mbit | legacy axis "
           "(T_dl) | Mbit to target | beats uncompressed budget |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        tdl = r["dl_bits_total"] / r["payload_bits"]
        hit = r["dl_bits_to_target"]
        out.append(
            f"| {r['strategy']} | {r['codec']} | {r['final_acc']:.3f} | "
            f"{r['dl_bits_total']/1e6:.1f} | {tdl:.0f} | "
            + (f"{hit/1e6:.1f} | " if hit is not None else "— | ")
            + ("**yes**" if r["wins"] else
               ("baseline" if r["codec"] == "identity" else "no")) + " |")
    wins = sorted({r["codec"] for r in rows
                   if r["strategy"] == "ucfl_k2" and r["wins"]})
    if wins:
        out += ["", f"ucfl_k2 reaches its uncompressed target accuracy "
                f"with strictly fewer downlink bits under: {', '.join(wins)}."]
    return "\n".join(out)


def serve_section() -> str:
    """Personalized serving QPS/latency vs at-rest store bytes per codec
    (DESIGN.md §3d; BENCH_serve.json)."""
    path = os.path.join(RESULTS_DIR, "BENCH_serve.json")
    if not os.path.exists(path):
        return ("(BENCH_serve.json not yet produced — run "
                "`python -m benchmarks.perf_iterations --serve`)")
    with open(path) as f:
        rows = json.load(f)
    out = ["Serving throughput vs at-rest store size per (placement × "
           "codec): one ucfl_k2 run (m=8, keep_state=True) ingested into a "
           "`DeltaStore` (k stream base models + per-user codec-encoded "
           "deltas), served through the `ServeEngine` micro-batcher.  "
           "`dense` = storing all m full models; the identity store can "
           "EXCEED it (k bases + m dense deltas) — it buys bit-exactness, "
           "the lossy codecs buy the compression.  Every row passed the "
           "§3d parity anchor (served output ≡ direct forward through the "
           "reconstructed params) before timing.", "",
           "| placement | codec | QPS | batch p50 ms | batch p99 ms | "
           "store MB | vs dense | max recon err |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        ratio = r["store_bytes"] / r["dense_bytes"]
        out.append(
            f"| {r['placement']} | {r['codec']} | {r['qps']:.0f} | "
            f"{r['batch_p50_ms']:.1f} | {r['batch_p99_ms']:.1f} | "
            f"{r['store_bytes']/1e6:.2f} | {ratio:.2f}× | "
            f"{r['max_recon_err']:.1e} |")
    best = min((r for r in rows if r["codec"] != "identity"),
               key=lambda r: r["store_bytes"])
    out += ["", f"Smallest store: {best['codec']} at "
            f"{best['store_bytes']/1e6:.2f} MB "
            f"({best['store_bytes']/best['dense_bytes']:.2f}× dense) while "
            f"serving {best['qps']:.0f} QPS on {best['placement']}."]
    return "\n".join(out)


def hierarchy_section() -> str:
    """Flat vs two-level time-to-target under tiered device links
    (DESIGN.md §3f; BENCH_hierarchy.json)."""
    path = os.path.join(RESULTS_DIR, "BENCH_hierarchy.json")
    if not os.path.exists(path):
        return ("(BENCH_hierarchy.json not yet produced — run "
                "`python -m benchmarks.perf_iterations --hierarchy`)")
    with open(path) as f:
        rows = json.load(f)
    out = ["Two-level rounds (per-user device fleets with an edge "
           "aggregation hop) vs the flat engine, same strategies, same "
           "user→server round.  Each user runs an edge sub-round over a "
           "ragged 2–4-device fleet: per-device local updates, qsgd:4 "
           "uplinks over a tiered:4 device link, mean edge aggregation, "
           "then the user pseudo-update enters the unchanged server round. "
           " The analytic clock charges BOTH hops (edge latency + slowest "
           "participating device, then the user uplink), so `time` is "
           "end-to-end virtual seconds.  `to target` = virtual time of the "
           "first eval reaching the flat run's final accuracy (the "
           "two-level run gets a 1.5× round budget — the edge hop trades "
           "rounds for clock time, so `slowdown` compares full-budget end "
           "times, not equal rounds).  The §3f "
           "flat-parity anchor (devices_per_user=1 ≡ flat engine, "
           "bit-exact incl. final params, both placements) ran in-bench "
           "before any row below was recorded.", "",
           "| strategy | fleets | edge codec | target acc | flat time | "
           "two-level time | time to target | slowdown | edge UL Mbit |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        hit = r["time_to_target"]
        out.append(
            f"| {r['strategy']} | {r['devices_per_user']} | "
            f"{r['edge_codec']}@{r['edge_link']} | {r['target_acc']:.3f} | "
            f"{r['flat_time']:.1f} | {r['two_level_time']:.1f} | "
            + (f"{hit:.1f} | " if hit is not None else "— | ")
            + f"{r['slowdown_at_end']:.2f}× | "
            f"{r['edge_ul_bits_total']/1e6:.1f} |")
    hits = [r for r in rows if r["time_to_target"] is not None]
    if hits:
        worst = max(hits, key=lambda r: r["time_to_target"] / r["flat_time"])
        out += ["", f"All listed strategies still reach their flat target "
                f"accuracy two-level; the worst clock inflation to target "
                f"is {worst['time_to_target']/worst['flat_time']:.2f}× "
                f"({worst['strategy']}) — the price of the extra hop under "
                f"a 4-tier device link, with the edge qsgd:4 codec keeping "
                f"the per-device payload at 4 bits/coordinate."]
    return "\n".join(out)


def faults_section() -> str:
    """Accuracy-under-attack: Byzantine sweep × robust aggregators
    (DESIGN.md §3g; BENCH_faults.json)."""
    path = os.path.join(RESULTS_DIR, "BENCH_faults.json")
    if not os.path.exists(path):
        return ("(BENCH_faults.json not yet produced — run "
                "`python -m benchmarks.perf_iterations --faults`)")
    with open(path) as f:
        rows = json.load(f)
    out = ["Accuracy under a 25% sign-flip Byzantine attack (−10·Δ, the "
           "gradient-ascent adversary; static client set drawn from the "
           "fault seed), per strategy × robust aggregator.  `honest acc` "
           "is mean final accuracy over the NON-Byzantine clients (the "
           "Byzantine-FL convention — the adversaries' personal eval is "
           "excluded since their data legitimately never contributes); "
           "`recovery` is that accuracy as a fraction of the clean "
           "(attack-free) run's honest accuracy.  The §3g faults-off "
           "parity anchor (zero-rate spec + robust_agg=none ≡ the clean "
           "engine, bit-exact incl. final params, on the fused, eventful "
           "AND async engines × both placements) ran in-bench before any "
           "row below was recorded, and the bench refuses to write the "
           "table unless `none` demonstrably degrades and a robust rule "
           "recovers ≥90%.", "",
           "| strategy | defense | honest acc | clean honest acc | "
           "recovery | quarantined |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['strategy']} | {r['robust_agg']} | "
            f"{r['honest_acc']:.3f} | {r['clean_honest_acc']:.3f} | "
            f"{r['recovery']:.2f}× | {r['quarantined_total']} |")
    by = {(r["strategy"], r["robust_agg"]): r for r in rows}
    strategies = sorted({r["strategy"] for r in rows})
    lines = []
    for s in strategies:
        none = by.get((s, "none"))
        best = max((r for r in rows if r["strategy"] == s
                    and r["robust_agg"] != "none"),
                   key=lambda r: r["recovery"], default=None)
        if none and best:
            lines.append(
                f"{s}: undefended collapses to {none['recovery']:.2f}× of "
                f"clean; {best['robust_agg']} recovers "
                f"{best['recovery']:.2f}×.")
    if lines:
        out += ["", " ".join(lines)]
    return "\n".join(out)


MARKERS = {"Paper": paper_section, "Dry-run": dryrun_section,
           "Roofline": roofline_section, "Channel": channel_section,
           "Serve": serve_section, "Hierarchy": hierarchy_section,
           "Faults": faults_section}

SKELETON = "# EXPERIMENTS\n\n" + "\n".join(
    f"## §{name}\n\n<!-- AUTOGEN {name} -->\n<!-- /AUTOGEN {name} -->\n"
    for name in MARKERS)


def main():
    if not os.path.exists(EXPERIMENTS):
        with open(EXPERIMENTS, "w") as f:
            f.write(SKELETON)
        print("EXPERIMENTS.md missing — created a marker skeleton")
    with open(EXPERIMENTS) as f:
        text = f.read()
    for name, fn in MARKERS.items():
        begin, end = f"<!-- AUTOGEN {name} -->", f"<!-- /AUTOGEN {name} -->"
        if begin not in text:
            continue
        body = fn()
        pattern = re.compile(re.escape(begin) + ".*?" + re.escape(end),
                             re.DOTALL)
        text = pattern.sub(f"{begin}\n{body}\n{end}", text)
    with open(EXPERIMENTS, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md sections regenerated")


if __name__ == "__main__":
    main()
