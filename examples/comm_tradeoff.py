"""Communication/learning trade-off (paper §III-B + Fig.3): sweep the number
of personalized streams, print accuracy AND the two communication axes —
wall-clock time under the three system models (the paper's T_dl units) and
cumulative downlink BITS from the channel subsystem (DESIGN.md §3b) —
plus the silhouette guidance for picking m_t.

Each sweep point is a registered Strategy (DESIGN.md §4); the per-round
downlink cost comes from the run's own `History.comm` / `History.comm_bits`
records rather than a hand-maintained table.  The identity-codec channel
is bit-exact with the channel-less engine, so attaching it only adds the
bits axis.  The last block re-runs the ucfl_k2 point through the lossy
codecs: same trade-off, cheaper bits.

    PYTHONPATH=src python examples/comm_tradeoff.py
"""
import jax
import numpy as np

from repro.core import kmeans, mixing_matrix, silhouette_score
from repro.data.federated import scenario_covariate_shift
from repro.fl import Channel, FLConfig, SYSTEMS, get_strategy, run_federated


def main():
    key = jax.random.PRNGKey(1)
    m = 12
    fed = scenario_covariate_shift(key, n=2000, m=m)
    fl = FLConfig(rounds=12, local_steps=5, batch_size=32, eval_every=11)

    print("streams  mean_acc  worst_acc   t/round (slow-UL, fast-UL, wired)"
          "   DL Mbit/round  cum DL Mbit")
    hist = {}
    for spec, k in [("fedavg", 1), ("ucfl_k2", 2), ("ucfl_k4", 4),
                    ("ucfl", m)]:
        h = run_federated(strategy=get_strategy(spec), fed=fed, fl=fl,
                          channel=Channel())     # identity: bits axis only
        hist[spec] = h
        cost = h.comm[-1]
        times = [s.round_time(m, n_streams=cost.n_streams,
                              n_unicasts=cost.n_unicasts)
                 for s in SYSTEMS.values()]
        dl_round = h.comm_bits[-1].dl_bits
        dl_total = sum(c.dl_bits for c in h.comm_bits)
        print(f"{k:7d}  {h.mean_acc[-1]:.3f}     {h.worst_acc[-1]:.3f}     "
              + "  ".join(f"{t:5.1f}" for t in times)
              + f"        {dl_round/1e6:8.2f}     {dl_total/1e6:8.2f}")

    # the same ucfl_k2 point on the bits axis, through the lossy codecs:
    # compression moves along the OTHER lever of the same trade-off
    print("\nucfl_k2 under uplink compression (error feedback on):")
    print("codec      mean_acc  worst_acc  DL Mbit/round  cum DL Mbit")
    for codec in ["identity", "qsgd:8", "qsgd:4", "topk:0.25"]:
        # the identity row IS the stream-sweep run above (bit-parity
        # anchor) — no need to train it twice
        h = hist["ucfl_k2"] if codec == "identity" else \
            run_federated("ucfl_k2", fed, fl=fl,
                          channel=Channel(codec=codec))
        dl_round = h.comm_bits[-1].dl_bits
        dl_total = sum(c.dl_bits for c in h.comm_bits)
        print(f"{codec:10s} {h.mean_acc[-1]:.3f}     {h.worst_acc[-1]:.3f}"
              f"      {dl_round/1e6:8.2f}     {dl_total/1e6:8.2f}")

    # silhouette-guided m_t (paper: silhouette over the w_i rows)
    w = hist["ucfl"].extras.mixing_matrix
    print("\nsilhouette score by k (pick the max):")
    for k in (2, 3, 4, 6):
        plan = kmeans(jax.numpy.asarray(w), k, key=key)
        s = silhouette_score(jax.numpy.asarray(w), plan.assignment, k)
        print(f"  k={k}: {float(s):.3f}")


if __name__ == "__main__":
    main()
