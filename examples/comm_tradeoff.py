"""Communication/learning trade-off (paper §III-B + Fig.3): sweep the number
of personalized streams, print accuracy AND wall-clock time under the three
system models, plus the silhouette guidance for picking m_t.

Each sweep point is a registered Strategy (DESIGN.md §4); the per-round
downlink cost comes from the run's own `History.comm` record rather than a
hand-maintained table.

    PYTHONPATH=src python examples/comm_tradeoff.py
"""
import jax
import numpy as np

from repro.core import kmeans, mixing_matrix, silhouette_score
from repro.data.federated import scenario_covariate_shift
from repro.fl import FLConfig, SYSTEMS, get_strategy, run_federated


def main():
    key = jax.random.PRNGKey(1)
    m = 12
    fed = scenario_covariate_shift(key, n=2000, m=m)
    fl = FLConfig(rounds=12, local_steps=5, batch_size=32, eval_every=11)

    print("streams  mean_acc  worst_acc   t/round (slow-UL, fast-UL, wired)")
    hist = {}
    for spec, k in [("fedavg", 1), ("ucfl_k2", 2), ("ucfl_k4", 4),
                    ("ucfl", m)]:
        h = run_federated(strategy=get_strategy(spec), fed=fed, fl=fl)
        hist[spec] = h
        cost = h.comm[-1]
        times = [s.round_time(m, n_streams=cost.n_streams,
                              n_unicasts=cost.n_unicasts)
                 for s in SYSTEMS.values()]
        print(f"{k:7d}  {h.mean_acc[-1]:.3f}     {h.worst_acc[-1]:.3f}     "
              + "  ".join(f"{t:5.1f}" for t in times))

    # silhouette-guided m_t (paper: silhouette over the w_i rows)
    w = hist["ucfl"].extras.mixing_matrix
    print("\nsilhouette score by k (pick the max):")
    for k in (2, 3, 4, 6):
        plan = kmeans(jax.numpy.asarray(w), k, key=key)
        s = silhouette_score(jax.numpy.asarray(w), plan.assignment, k)
        print(f"  k={k}: {float(s):.3f}")


if __name__ == "__main__":
    main()
