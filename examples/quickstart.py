"""Quickstart: the paper's technique in ~60 lines.

Five clients with heterogeneous linear-regression data; run the similarity
pre-round, build the Eq.6 mixing matrix, reduce to 2 personalized streams,
and compare FedAvg vs user-centric aggregation on one round of local models.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (kmeans, mixing_matrix, silhouette_score,
                        similarity_round, stream_aggregate,
                        user_centric_aggregate, fedavg_weights)

key = jax.random.PRNGKey(0)

# --- five clients, two latent groups (w* = +w or -w) ----------------------
m, d, n_i = 5, 16, 200
w_true = jax.random.normal(key, (d,))
groups = jnp.array([0, 0, 0, 1, 1])
datasets = []
for i in range(m):
    ki = jax.random.fold_in(key, i)
    x = jax.random.normal(ki, (n_i, d))
    sign = 1.0 if int(groups[i]) == 0 else -1.0
    y = x @ (sign * w_true) + 0.1 * jax.random.normal(ki, (n_i,))
    datasets.append({"x": x, "y": y})


def loss_fn(params, data):
    pred = data["x"] @ params["w"]
    return jnp.mean((pred - data["y"]) ** 2)


# --- paper §III-A: similarity pre-round ------------------------------------
probe = {"w": jnp.zeros((d,))}
delta, sigma2, n = similarity_round(loss_fn, probe, datasets)
W = mixing_matrix(delta, sigma2, n)
print("mixing matrix W (row-stochastic):")
print(np.round(np.asarray(W), 3))

# --- paper §III-B: stream reduction ----------------------------------------
plan = kmeans(W, 2, key=key)
print("\nstream assignment:", np.asarray(plan.assignment),
      " true groups:", np.asarray(groups))
print("silhouette(k=2):",
      float(silhouette_score(W, plan.assignment, 2)))

# --- one round: local models then aggregation -------------------------------
def local_model(data):
    xtx = data["x"].T @ data["x"] + 1e-3 * jnp.eye(d)
    return jnp.linalg.solve(xtx, data["x"].T @ data["y"])

locals_ = {"w": jnp.stack([local_model(ds) for ds in datasets])}
fedavg = user_centric_aggregate(locals_, fedavg_weights(n))
ucfl = stream_aggregate(locals_, plan)

def client_mse(stacked):
    return [float(loss_fn({"w": stacked["w"][i]}, datasets[i]))
            for i in range(m)]

print("\nper-client MSE:")
print("  fedavg:", np.round(client_mse(fedavg), 3))
print("  ucfl-2:", np.round(client_mse(ucfl), 3))
print("\nFedAvg averages the two conflicting groups away; the user-centric"
      "\nstreams recover per-group models from the gradient similarity.")
