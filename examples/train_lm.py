"""End-to-end driver (deliverable b): federated LM training on an assigned
architecture through the SAME step builder the production dry-run lowers.

Default runs a CPU-sized preset; --preset lm-100m trains a ~100M-param model
(hardware permitting) and --preset full the assigned config.

    PYTHONPATH=src python examples/train_lm.py --arch stablelm-3b --steps 30
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.exit(0 if main() is not None else 1)
