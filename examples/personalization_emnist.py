"""Paper experiment walk-through: EMNIST-like covariate+label shift, the
scenario of Fig.2b — run FedAvg, UCFL (k streams), and the oracle, then
print the accuracy-vs-rounds table and worst-user comparison (Table I).

    PYTHONPATH=src python examples/personalization_emnist.py [--rounds 24]
"""
import argparse

import jax
import numpy as np

from repro.data.federated import scenario_covariate_shift
from repro.fl import FLConfig, run_federated


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--rounds", type=int, default=18)
    p.add_argument("--clients", type=int, default=12)
    p.add_argument("--samples", type=int, default=2400)
    args = p.parse_args()

    key = jax.random.PRNGKey(0)
    fed = scenario_covariate_shift(key, n=args.samples, m=args.clients)
    fl = FLConfig(rounds=args.rounds, local_steps=5, batch_size=32,
                  eval_every=max(args.rounds // 6, 1))

    results = {}
    for alg in ["local", "fedavg", "ucfl_k4", "oracle"]:
        h = run_federated(alg, fed, fl=fl)
        results[alg] = h
        print(f"{alg:10s} rounds={h.rounds} mean_acc="
              f"{np.round(h.mean_acc, 3).tolist()}")

    print("\nTable-I-style worst-user accuracy:")
    for alg, h in results.items():
        print(f"  {alg:10s} mean={h.mean_acc[-1]:.3f} "
              f"worst={h.worst_acc[-1]:.3f}")
    uc, oa = results["ucfl_k4"], results["oracle"]
    print(f"\nUCFL k=4 reaches {uc.mean_acc[-1]/max(oa.mean_acc[-1],1e-9):.0%}"
          " of the oracle (4 true rotation groups).")


if __name__ == "__main__":
    main()
