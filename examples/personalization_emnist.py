"""Paper experiment walk-through: EMNIST-like covariate+label shift, the
scenario of Fig.2b — run FedAvg, UCFL (k streams), and the oracle via the
Strategy API, then print the accuracy-vs-rounds table and worst-user
comparison (Table I).  `--participation 0.5` samples half the clients per
round (DESIGN.md §6); `--placement mesh` runs the identical experiment
with clients sharded over the available devices (DESIGN.md §3).

    PYTHONPATH=src python examples/personalization_emnist.py [--rounds 24]
"""
import argparse

import jax
import numpy as np

from repro.data.federated import scenario_covariate_shift
from repro.fl import (FLConfig, MeshShardMap, UniformFraction, get_strategy,
                      run_federated)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--rounds", type=int, default=18)
    p.add_argument("--clients", type=int, default=12)
    p.add_argument("--samples", type=int, default=2400)
    p.add_argument("--participation", type=float, default=1.0)
    p.add_argument("--placement", default="host", choices=("host", "mesh"))
    p.add_argument("--schedule", default="gspmd",
                   choices=("gspmd", "shard_map_streams",
                            "shard_map_unicast"))
    args = p.parse_args()

    key = jax.random.PRNGKey(0)
    fed = scenario_covariate_shift(key, n=args.samples, m=args.clients)
    fl = FLConfig(rounds=args.rounds, local_steps=5, batch_size=32,
                  eval_every=max(args.rounds // 6, 1))
    sampler = (UniformFraction(args.participation)
               if args.participation != 1.0 else None)

    # one placement instance for the whole sweep: its cached mixing
    # executables are reused across strategies
    placement = (MeshShardMap(schedule=args.schedule)
                 if args.placement == "mesh" else None)
    results = {}
    for spec in ["local", "fedavg", "ucfl_k4", "oracle"]:
        h = run_federated(strategy=get_strategy(spec), fed=fed, fl=fl,
                          sampler=sampler, placement=placement)
        results[spec] = h
        print(f"{spec:10s} rounds={h.rounds} mean_acc="
              f"{np.round(h.mean_acc, 3).tolist()}")

    print("\nTable-I-style worst-user accuracy:")
    for spec, h in results.items():
        print(f"  {spec:10s} mean={h.mean_acc[-1]:.3f} "
              f"worst={h.worst_acc[-1]:.3f}")
    uc, oa = results["ucfl_k4"], results["oracle"]
    print(f"\nUCFL k=4 reaches {uc.mean_acc[-1]/max(oa.mean_acc[-1],1e-9):.0%}"
          " of the oracle (4 true rotation groups).")


if __name__ == "__main__":
    main()
